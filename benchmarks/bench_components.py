"""Paper Fig 7 / Fig 8 / Table 2: component micro-benchmarks.

Absolute numbers are Python/numpy on 1 CPU core (paper: C++ on 2x64-core
EPYC), so the deliverable is the *relative* structure the paper reports:
NBF >> DBF, skip-LUT >> trial parse >> zlib-trial, marker replacement >>
decompression, bit-reader bandwidth rising with bits/read.
"""

from __future__ import annotations

import io
import zlib

import numpy as np

from repro.core import BitReader, SharedFileReader
from repro.core.block_finder import (
    find_dynamic_skiplut,
    find_dynamic_trial,
    find_dynamic_zlib,
    scan_dynamic_candidates,
    scan_stored_candidates,
)
from repro.core.markers import replace_markers, replacement_table

from .common import DataGen, emit, scale, timeit


def bench_bitreader(gen: DataGen) -> None:
    """Fig 7: bandwidth vs bits per read call."""
    data = gen.random(scale(1 << 18))
    total_bits = len(data) * 8
    for bits in (1, 2, 4, 8, 16, 24, 32, 48, 63):
        def run():
            br = BitReader(data)
            n = total_bits // bits
            read = br.read
            for _ in range(n):
                read(bits)

        best, _ = timeit(run, repeats=3, warmup=1)
        bw = len(data) / best
        emit(f"fig7_bitreader_{bits}bits", best * 1e6, f"{bw/1e6:.1f}MB/s")


def bench_filereader(gen: DataGen, tmpdir: str) -> None:
    """Fig 8: strided parallel pread (1 core: overhead/correctness check)."""
    import concurrent.futures as cf
    import os

    path = os.path.join(tmpdir, "shared.bin")
    blob = gen.random(scale(64 << 20, floor=1 << 20))
    with open(path, "wb") as f:
        f.write(blob)
    chunk = 128 << 10
    for threads in (1, 2, 4, 8):
        reader = SharedFileReader(path)

        def worker(tid):
            total = 0
            off = tid * chunk
            while off < len(blob):
                total += len(reader.pread(off, chunk))
                off += threads * chunk
            return total

        def run():
            with cf.ThreadPoolExecutor(threads) as pool:
                assert sum(pool.map(worker, range(threads))) == len(blob)

        best, _ = timeit(run, repeats=3, warmup=1)
        reader.close()
        emit(f"fig8_filereader_{threads}threads", best * 1e6, f"{len(blob)/best/1e9:.2f}GB/s")


def bench_blockfinders(gen: DataGen) -> None:
    """Table 2: DBF zlib / trial / skip-LUT / vectorized, NBF, marker repl."""
    blob = gen.random(scale(192 << 10, floor=16 << 10))
    bits = len(blob) * 8

    small = blob[: 2 << 10]  # zlib trial is极slow — tiny input, same metric
    best, _ = timeit(lambda: list(find_dynamic_zlib(small, 0, len(small) * 8)), repeats=1, warmup=0)
    emit("table2_dbf_zlib", best * 1e6, f"{len(small)/best/1e6:.4f}MB/s")

    small2 = blob[: 8 << 10]
    best, _ = timeit(lambda: list(find_dynamic_trial(small2, 0, len(small2) * 8)), repeats=1, warmup=0)
    emit("table2_dbf_trial", best * 1e6, f"{len(small2)/best/1e6:.4f}MB/s")

    mid = blob[: 32 << 10]
    best, _ = timeit(lambda: list(find_dynamic_skiplut(mid, 0, len(mid) * 8)), repeats=1, warmup=1)
    emit("table2_dbf_skiplut", best * 1e6, f"{len(mid)/best/1e6:.4f}MB/s")

    best, _ = timeit(lambda: list(scan_dynamic_candidates(blob, 0, bits)), repeats=3, warmup=1)
    emit("table2_dbf_vectorized", best * 1e6, f"{len(blob)/best/1e6:.4f}MB/s")

    best, _ = timeit(lambda: list(scan_stored_candidates(blob, 0, bits)), repeats=3, warmup=1)
    emit("table2_nbf", best * 1e6, f"{len(blob)/best/1e6:.4f}MB/s")

    # marker replacement (numpy host path — the Pallas kernel's oracle)
    syms = gen.rng.integers(0, 256 + 32768, scale(4 << 20), dtype=np.uint16)
    window = gen.random(32768)
    best, _ = timeit(lambda: replace_markers(syms, window), repeats=5, warmup=1)
    emit("table2_marker_replacement", best * 1e6, f"{syms.nbytes/2/best/1e6:.1f}MB/s")

    data = gen.text(scale(4 << 20))
    best, _ = timeit(lambda: np.frombuffer(data, np.uint8).sum(), repeats=3, warmup=1)
    emit("table2_count_bytes_baseline", best * 1e6, f"{len(data)/best/1e6:.1f}MB/s")


def bench_filter_stats(gen: DataGen) -> None:
    """Table 1: empirical filter frequencies of the DBF cascade."""
    from repro.core.block_finder import FilterStats

    blob = gen.random(scale(1 << 20))  # 8.4M bit positions (full mode)
    stats = FilterStats()
    list(scan_dynamic_candidates(blob, 0, len(blob) * 8, stats=stats))
    d = stats.as_dict()
    tested = max(1, d["tested"])
    for key in ("invalid_final", "invalid_type", "invalid_hlit",
                "invalid_precode_histogram", "invalid_precode_data",
                "invalid_distance", "invalid_literal", "valid"):
        emit(f"table1_{key}", 0.0, f"{d[key]}({d[key]/tested:.2e})")


def main(tmpdir: str) -> None:
    gen = DataGen()
    bench_bitreader(gen)
    bench_filereader(gen, tmpdir)
    bench_blockfinders(gen)
    bench_filter_stats(gen)
