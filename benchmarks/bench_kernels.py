"""Pallas kernel benchmarks: interpret-mode correctness throughput + the
jnp-oracle throughput (the XLA-fused upper bound this container can run)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.marker_replace import TILE_COLS, TILE_ROWS, marker_replace_tiles
from repro.kernels.ref import make_replacement_table, marker_replace_ref, precode_check_ref
from repro.kernels.precode_check import BLOCK, precode_check_blocks

from . import common
from .common import DataGen, emit, timeit


def bench_marker_replace(gen: DataGen) -> None:
    window = gen.rng.integers(0, 256, 32768, dtype=np.uint8)
    table = jnp.asarray(make_replacement_table(window))
    n_tiles = 4 if common.SMOKE else 64
    syms = jnp.asarray(
        gen.rng.integers(0, 33024, (n_tiles, TILE_ROWS, TILE_COLS), dtype=np.int64).astype(np.int32)
    )
    nbytes = n_tiles * TILE_ROWS * TILE_COLS

    ref = jax.jit(marker_replace_ref)
    ref(syms, table).block_until_ready()
    best, _ = timeit(lambda: ref(syms, table).block_until_ready(), repeats=5)
    emit("kernel_marker_replace_jnp", best * 1e6, f"{nbytes/best/1e6:.0f}MB/s")

    out = marker_replace_tiles(syms[:2], table, interpret=True)
    out.block_until_ready()
    best, _ = timeit(
        lambda: marker_replace_tiles(syms[:2], table, interpret=True).block_until_ready(),
        repeats=3,
    )
    emit("kernel_marker_replace_pallas_interpret", best * 1e6,
         f"{2*TILE_ROWS*TILE_COLS/best/1e6:.1f}MB/s(interpret-mode)")


def bench_precode(gen: DataGen) -> None:
    n_blocks = 4 if common.SMOKE else 32
    bits = jnp.asarray(gen.rng.integers(0, 2, ((n_blocks + 1), BLOCK), dtype=np.int64).astype(np.int32))
    n_offsets = n_blocks * BLOCK

    fn = jax.jit(lambda b: precode_check_blocks(b, interpret=True))
    fn(bits).block_until_ready()
    best, _ = timeit(lambda: fn(bits).block_until_ready(), repeats=3)
    emit("kernel_precode_pallas_interpret", best * 1e6,
         f"{n_offsets/8/best/1e6:.2f}MB/s(bit-offsets/8)")

    flat = bits.reshape(-1)
    ref = jax.jit(precode_check_ref)
    ref(flat).block_until_ready()
    best, _ = timeit(lambda: ref(flat).block_until_ready(), repeats=3)
    emit("kernel_precode_jnp", best * 1e6, f"{(flat.shape[0]-74)/8/best/1e6:.2f}MB/s")


def main() -> None:
    gen = DataGen()
    bench_marker_replace(gen)
    bench_precode(gen)
