"""Pallas kernel benchmarks: interpret-mode correctness throughput + the
jnp-oracle throughput (the XLA-fused upper bound this container can run).

The ``kernel_engine_*`` rows sweep batched vs per-chunk dispatch through the
DeviceDecodeEngine (batch 1/4/16/64) — the numbers ``engine.derive_crossover``
reads back out of the committed ``BENCH_kernels.json`` to place the
CPU/device routing threshold."""

from __future__ import annotations

import zlib as _zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.marker_replace import TILE_COLS, TILE_ROWS, marker_replace_tiles
from repro.kernels.ref import make_replacement_table, marker_replace_ref, precode_check_ref
from repro.kernels.precode_check import BLOCK, precode_check_blocks

from . import common
from .common import DataGen, emit, timeit


def bench_marker_replace(gen: DataGen) -> None:
    window = gen.rng.integers(0, 256, 32768, dtype=np.uint8)
    table = jnp.asarray(make_replacement_table(window))
    n_tiles = 4 if common.SMOKE else 64
    syms = jnp.asarray(
        gen.rng.integers(0, 33024, (n_tiles, TILE_ROWS, TILE_COLS), dtype=np.int64).astype(np.int32)
    )
    nbytes = n_tiles * TILE_ROWS * TILE_COLS

    ref = jax.jit(marker_replace_ref)
    ref(syms, table).block_until_ready()
    best, _ = timeit(lambda: ref(syms, table).block_until_ready(), repeats=5)
    emit("kernel_marker_replace_jnp", best * 1e6, f"{nbytes/best/1e6:.0f}MB/s")

    out = marker_replace_tiles(syms[:2], table, interpret=True)
    out.block_until_ready()
    best, _ = timeit(
        lambda: marker_replace_tiles(syms[:2], table, interpret=True).block_until_ready(),
        repeats=3,
    )
    emit("kernel_marker_replace_pallas_interpret", best * 1e6,
         f"{2*TILE_ROWS*TILE_COLS/best/1e6:.1f}MB/s(interpret-mode)")


def bench_precode(gen: DataGen) -> None:
    n_blocks = 4 if common.SMOKE else 32
    bits = jnp.asarray(gen.rng.integers(0, 2, ((n_blocks + 1), BLOCK), dtype=np.int64).astype(np.int32))
    n_offsets = n_blocks * BLOCK

    fn = jax.jit(lambda b: precode_check_blocks(b, interpret=True))
    fn(bits).block_until_ready()
    best, _ = timeit(lambda: fn(bits).block_until_ready(), repeats=3)
    emit("kernel_precode_pallas_interpret", best * 1e6,
         f"{n_offsets/8/best/1e6:.2f}MB/s(bit-offsets/8)")

    flat = bits.reshape(-1)
    ref = jax.jit(precode_check_ref)
    ref(flat).block_until_ready()
    best, _ = timeit(lambda: ref(flat).block_until_ready(), repeats=3)
    emit("kernel_precode_jnp", best * 1e6, f"{(flat.shape[0]-74)/8/best/1e6:.2f}MB/s")


def bench_engine(gen: DataGen) -> None:
    """Batched vs per-chunk dispatch through the DeviceDecodeEngine.

    The per-chunk baseline is the pre-engine hot path: one
    ``ops.marker_replace`` per chunk (per-call table build + upload + device
    round trip). The batched path submits the same chunks to one engine and
    waits for the coalesced dispatch. One tile per chunk models the
    seeking-heavy serving shape — many small concurrent reads — where
    per-dispatch overhead dominates and batching pays most.

    Dispatches are slabbed at 16 tiles: interpret mode unrolls the grid at
    trace time, so larger single dispatches go super-linear in this
    container (a tracing artifact, not a device property).
    """
    from repro.core.markers import replace_markers as cpu_replace
    from repro.kernels import ops as kops
    from repro.kernels.engine import DeviceDecodeEngine

    import time as _time

    def best_of(fn, repeats: int = 3) -> float:
        """Best-of-N seconds, independent of smoke mode: these rows feed the
        crossover derivation and the batched/per-chunk ratio, and a single
        cold sample is dominated by thread-handoff jitter, not dispatch
        cost. N stays small enough that smoke mode is still quick."""
        fn()  # warmup (compile + caches)
        best = float("inf")
        for _ in range(repeats):
            t0 = _time.perf_counter()
            fn()
            best = min(best, _time.perf_counter() - t0)
        return best

    chunk_syms = TILE_ROWS * TILE_COLS  # one tile per chunk
    windows = [
        gen.rng.integers(0, 256, 32768, dtype=np.uint8).tobytes() for _ in range(4)
    ]

    def make_chunk() -> np.ndarray:
        return gen.rng.integers(0, 33024, chunk_syms, dtype=np.int64).astype(np.uint16)

    # CPU gather reference — the "cpu" input of the crossover derivation.
    syms0 = make_chunk()
    t_cpu = best_of(lambda: cpu_replace(syms0, windows[0]), repeats=5)
    emit("kernel_engine_cpu_replace", t_cpu * 1e6, f"{chunk_syms/t_cpu/1e6:.0f}MB/s")

    t_single = None  # batch-1 engine time: the single-chunk-dispatch baseline
    for B in (1, 4, 16, 64):
        chunks = [make_chunk() for _ in range(B)]
        wins = [windows[i % len(windows)] for i in range(B)]

        def per_chunk():
            for c, w in zip(chunks, wins):
                kops.marker_replace(c, w)

        t_pc = best_of(per_chunk)
        emit(f"kernel_engine_per_chunk_b{B}", t_pc * 1e6,
             f"{B*chunk_syms/t_pc/1e6:.1f}MB/s")

        eng = DeviceDecodeEngine(
            force_device=True, crossover=None,
            max_batch_tiles=min(B, 16), max_delay_s=0.05,
        )

        def batched():
            futs = [eng.submit_replace(c, w) for c, w in zip(chunks, wins)]
            for f in futs:
                f.result()

        t_b = best_of(batched)
        if t_single is None:
            t_single = t_b  # B == 1
        # x_vs_single: batched throughput over dispatching the same chunks
        # one at a time through the engine (B * t_single); x_vs_per_chunk:
        # over the pre-engine ops.marker_replace loop.
        emit(f"kernel_engine_batched_b{B}", t_b * 1e6,
             f"{B*chunk_syms/t_b/1e6:.1f}MB/s;{t_pc/t_b:.2f}x_vs_per_chunk"
             f";{B*t_single/t_b:.2f}x_vs_single")
        eng.shutdown()

    # CRC: zlib reference vs batched device dispatch (crossover inputs).
    # Fixed-tiny payloads: interpret mode executes the kernel's per-byte
    # fori_loop step by step (~ms each), so cost scales with seg_len and
    # anything larger stalls the section. The derivation only needs the
    # *sign* of the cpu-vs-device comparison, which tiny data settles.
    crc_nbytes = 8 << 10
    datas = [gen.random(crc_nbytes) for _ in range(8)]
    t_zc = best_of(lambda: _zlib.crc32(datas[0]), repeats=5)
    emit("kernel_engine_cpu_crc", t_zc * 1e6, f"{crc_nbytes/t_zc/1e6:.0f}MB/s")
    for B in (1, 8):
        eng = DeviceDecodeEngine(
            force_device=True, crossover=None,
            max_crc_requests=B, max_delay_s=0.05,
        )

        def crc_batched():
            futs = [eng.submit_crc(d) for d in datas[:B]]
            for f in futs:
                f.result()

        t_c = best_of(crc_batched, repeats=1)
        emit(f"kernel_engine_crc_batched_b{B}", t_c * 1e6,
             f"{B*crc_nbytes/t_c/1e6:.1f}MB/s")
        eng.shutdown()

    # Interactive scenario: default routing policy on THIS host (crossover
    # derived from the committed artifact). Singleton requests must take the
    # CPU path — the row's derived field records the engine's own fallback
    # count as proof.
    eng = DeviceDecodeEngine()
    t_i = best_of(lambda: eng.replace_markers(syms0, windows[0]), repeats=5)
    stats = eng.stats()
    emit("kernel_engine_interactive_singleton", t_i * 1e6,
         f"fallbacks={stats['fallbacks']['replace']};batches={stats['batches']}")
    eng.shutdown()


def main() -> None:
    gen = DataGen()
    bench_marker_replace(gen)
    bench_precode(gen)
    bench_engine(gen)
