"""Shared benchmark utilities: timing, CSV emission, data generation."""

from __future__ import annotations

import gzip as _gzip
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

#: Smoke mode (``python -m benchmarks.run --smoke``): every section shrinks
#: its data through :func:`scale` so the whole harness finishes in <60 s —
#: a CI-grade "do all benchmarks still execute" check, not a measurement.
SMOKE = False
SMOKE_DIVISOR = 32


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


def scale(n: int, floor: int = 1 << 12) -> int:
    """Benchmark size ``n``, shrunk in smoke mode (never below ``floor``)."""
    return max(floor, n // SMOKE_DIVISOR) if SMOKE else n


def timeit(fn: Callable, *, repeats: int = 5, warmup: int = 1) -> Tuple[float, float]:
    """Returns (best_seconds, mean_seconds). Smoke mode: 1 repeat, no warmup."""
    if SMOKE:
        repeats, warmup = 1, 0
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times), float(np.mean(times))


#: Results accumulated by :func:`emit` since the last :func:`drain_results`
#: call — the run.py harness drains this after each section to persist the
#: section's rows as ``BENCH_<section>.json`` alongside the CSV stdout.
RESULTS: List[Dict[str, object]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    RESULTS.append({"name": name, "value_us": round(us_per_call, 3), "derived": derived})


def drain_results() -> List[Dict[str, object]]:
    """Return and clear the rows emitted since the previous drain."""
    out = list(RESULTS)
    RESULTS.clear()
    return out


class DataGen:
    def __init__(self, seed: int = 0xBEEF):
        self.rng = np.random.default_rng(seed)

    def text(self, n: int) -> bytes:
        words = [b"the", b"quick", b"brown", b"fox", b"jumps", b"over", b"lazy",
                 b"dog", b"rapidgzip", b"parallel", b"deflate", b"window",
                 b"chunk", b"prefetch", b"cache", b"marker"]
        idx = self.rng.integers(0, len(words), size=max(8, n // 5))
        return b" ".join(words[i] for i in idx)[:n]

    def base64(self, n: int) -> bytes:
        import base64

        raw = self.rng.integers(0, 256, (n * 3) // 4 + 3, dtype=np.uint8).tobytes()
        return base64.b64encode(raw)[:n]

    def random(self, n: int) -> bytes:
        return self.rng.integers(0, 256, n, dtype=np.uint8).tobytes()

    def silesia_like(self, n: int) -> bytes:
        """Mixed corpus stand-in: text + structured binary + low-entropy runs."""
        parts = []
        per = max(1, n // 4)
        parts.append(self.text(per))
        # structured little-endian ints with small deltas (db-like)
        base = np.cumsum(self.rng.integers(0, 16, per // 4, dtype=np.int64)).astype("<u4")
        parts.append(base.tobytes())
        parts.append(self.random(per // 2))  # incompressible section
        parts.append((b"ABCD" * (per // 4 + 1))[:per])  # runs
        out = b"".join(parts)
        return out[:n]

    def fastq_like(self, n: int) -> bytes:
        """FASTQ records: @id / sequence / + / quality."""
        out = []
        size = 0
        i = 0
        bases = np.frombuffer(b"ACGT", np.uint8)
        quals = np.arange(33, 74, dtype=np.uint8)
        while size < n:
            seq = bases[self.rng.integers(0, 4, 100)].tobytes()
            qual = quals[self.rng.integers(0, len(quals), 100)].tobytes()
            rec = b"@SRR0000." + str(i).encode() + b"\n" + seq + b"\n+\n" + qual + b"\n"
            out.append(rec)
            size += len(rec)
            i += 1
        return b"".join(out)[:n]


def gzip_bytes(data: bytes, level: int = 6) -> bytes:
    return _gzip.compress(data, compresslevel=level)
