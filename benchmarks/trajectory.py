"""Cross-PR benchmark trajectory: diff fresh rows against committed artifacts.

Every ``python -m benchmarks.run`` persists one ``BENCH_<section>.json`` per
section; those artifacts are committed, so the repo's history carries the
performance trajectory PR by PR. This module closes the loop: given the rows
a fresh run just produced and the artifact the previous PR committed, it
flags per-row slowdowns beyond a threshold (default >25%) so a PR that
quietly regresses a benchmark gets called out at run time instead of at
archaeology time. ``run.py --check`` wires it in.

Comparisons are only meaningful like-for-like: a smoke artifact against a
smoke run (sizes differ 32x between modes), and the same machine class.
Mode mismatches are reported as skips, never as regressions.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Mapping, Optional, Sequence

#: A row counts as regressed when ``new/old > 1 + REGRESSION_THRESHOLD``.
#: 25% is deliberately loose — these are wall-clock microbenchmarks on a
#: shared machine; the checker is for step changes, not 5% noise.
REGRESSION_THRESHOLD = 0.25


def load_artifact(root: str, section: str) -> Optional[Dict[str, Any]]:
    """The committed ``BENCH_<section>.json`` payload, or None if absent
    or unreadable (first run of a new section is not an error)."""
    path = os.path.join(root, "BENCH_%s.json" % section)
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return None
    return payload if isinstance(payload, dict) else None


def compare_rows(
    old_rows: Sequence[Mapping[str, Any]],
    new_rows: Sequence[Mapping[str, Any]],
    *,
    threshold: float = REGRESSION_THRESHOLD,
) -> Dict[str, List]:
    """Row-by-row diff keyed on ``name``.

    Returns ``{"regressions": [...], "improvements": [...], "added": [names],
    "removed": [names]}``. Regression/improvement entries are dicts with
    ``name``/``old_us``/``new_us``/``ratio``. Rows with non-positive old
    values are skipped (nothing sound to divide by).
    """
    old_by = {r.get("name"): r for r in old_rows if r.get("name")}
    new_by = {r.get("name"): r for r in new_rows if r.get("name")}
    out: Dict[str, List] = {
        "regressions": [],
        "improvements": [],
        "added": sorted(set(new_by) - set(old_by)),
        "removed": sorted(set(old_by) - set(new_by)),
    }
    for name in sorted(set(old_by) & set(new_by)):
        try:
            old_us = float(old_by[name]["value_us"])
            new_us = float(new_by[name]["value_us"])
        except (KeyError, TypeError, ValueError):
            continue
        if old_us <= 0.0:
            continue
        ratio = new_us / old_us
        entry = {
            "name": name,
            "old_us": old_us,
            "new_us": new_us,
            "ratio": round(ratio, 3),
        }
        if ratio > 1.0 + threshold:
            out["regressions"].append(entry)
        elif ratio < 1.0 / (1.0 + threshold):
            out["improvements"].append(entry)
    return out


def check_section(
    root: str,
    section: str,
    new_rows: Sequence[Mapping[str, Any]],
    *,
    smoke: bool,
    threshold: float = REGRESSION_THRESHOLD,
) -> Dict[str, Any]:
    """Compare a section's fresh rows against its committed artifact.

    Returns a report dict: ``status`` is one of ``"ok"``, ``"regressed"``,
    ``"no-baseline"``, or ``"mode-mismatch"`` (committed artifact was
    recorded in the other smoke/full mode — sizes are incomparable).
    """
    artifact = load_artifact(root, section)
    if artifact is None:
        return {"section": section, "status": "no-baseline"}
    if bool(artifact.get("smoke")) != bool(smoke):
        return {
            "section": section,
            "status": "mode-mismatch",
            "artifact_smoke": bool(artifact.get("smoke")),
        }
    diff = compare_rows(
        artifact.get("results", []), new_rows, threshold=threshold
    )
    diff["section"] = section
    diff["status"] = "regressed" if diff["regressions"] else "ok"
    return diff


def format_report(report: Mapping[str, Any]) -> List[str]:
    """Human-readable lines (``# ``-prefixed to stay CSV-transparent)."""
    section = report.get("section", "?")
    status = report.get("status")
    lines: List[str] = []
    if status == "no-baseline":
        return ["# trajectory[%s]: no committed baseline, skipping" % section]
    if status == "mode-mismatch":
        return [
            "# trajectory[%s]: committed artifact is %s, this run is not"
            " — skipping" % (
                section,
                "smoke" if report.get("artifact_smoke") else "full",
            )
        ]
    for r in report.get("regressions", []):
        lines.append(
            "# REGRESSION %s/%s: %.1fus -> %.1fus (%.2fx)"
            % (section, r["name"], r["old_us"], r["new_us"], r["ratio"])
        )
    for r in report.get("improvements", []):
        lines.append(
            "# improvement %s/%s: %.1fus -> %.1fus (%.2fx)"
            % (section, r["name"], r["old_us"], r["new_us"], r["ratio"])
        )
    for name in report.get("added", []):
        lines.append("# trajectory[%s]: new row %s" % (section, name))
    for name in report.get("removed", []):
        lines.append("# trajectory[%s]: row %s disappeared" % (section, name))
    if not lines:
        lines.append("# trajectory[%s]: ok" % section)
    return lines
