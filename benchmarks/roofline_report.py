"""§Roofline report: reads results/dryrun.json, prints the per-cell table
with the three terms, dominant bottleneck, 6ND-useful-flops ratio, and a
one-line improvement note per cell."""

from __future__ import annotations

import json
import os
from typing import Dict

from repro.configs import SHAPES, all_configs
from repro.launch.roofline import model_flops

NOTES = {
    "t_compute": "compute-bound: raise MXU utilization (larger per-chip tiles, fewer pad heads)",
    "t_memory": "memory-bound: fuse attention score traffic (flash-style), shrink fp32 intermediates, better remat policy",
    "t_collective": "collective-bound: re-shard to cut all-gathers (embedding/CE path), overlap collectives with compute",
}


def load(path: str = "results/dryrun.json") -> Dict[str, dict]:
    with open(path) as f:
        return json.load(f)


def report(path: str = "results/dryrun.json", mesh: str = "16x16") -> None:
    results = load(path)
    print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "roofline_fraction,useful_flops_ratio,collective_bytes,note")
    for key in sorted(results):
        c = results[key]
        if c.get("mesh") != mesh:
            continue
        if c["status"] == "skipped":
            print(f"{c['arch']},{c['shape']},{mesh},,,,skipped-by-design,,,,{c['reason']}")
            continue
        if c["status"] != "ok":
            print(f"{c['arch']},{c['shape']},{mesh},,,,ERROR,,,,{c.get('error','')}")
            continue
        r = c["roofline"]
        cal = c.get("calibrated", {})
        if isinstance(cal, dict) and "roofline" in cal:
            r = cal["roofline"]  # unrolled per-layer extrapolation (exact)
        cfg = all_configs()[c["arch"]]
        shape = SHAPES[c["shape"]]
        # recompute 6ND with the (fixed) exact param counts
        mf = model_flops(cfg, shape) / c["n_chips"]
        useful = mf / r["flops"] if r["flops"] else 0.0
        print(
            f"{c['arch']},{c['shape']},{mesh},"
            f"{r['t_compute']:.3e},{r['t_memory']:.3e},{r['t_collective']:.3e},"
            f"{r['dominant']},{r['roofline_fraction']:.3f},{useful:.3f},"
            f"{r['collective_bytes']:.3e},{NOTES[r['dominant']]}"
        )


def main() -> None:
    for path, tag in (("results/dryrun.json", "baseline"), ("results/dryrun_opt.json", "optimized")):
        if not os.path.exists(path):
            print(f"# {path} missing — run: python -m repro.launch.dryrun")
            continue
        for mesh in ("16x16", "2x16x16"):
            print(f"# {tag} mesh {mesh}")
            report(path, mesh=mesh)


if __name__ == "__main__":
    main()
