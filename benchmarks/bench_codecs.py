"""Codec comparison: random-access latency across deflate, BGZF, and zstd.

The same logical corpus is archived under each codec and probed with the
same random positional reads, cold (no index: deflate pays the speculative
first pass; BGZF and zstd build an exact index from framing metadata alone)
and warm (finalized index imported: all three serve lock-free). The derived
column records how much speculative work the cold open actually did —
BGZF's whole point (paper §3.4.4) is that ``nominal_tasks`` stays 0.

Zstd rows appear only when a zstd library is importable (stdlib
``compression.zstd`` on 3.14+, else the optional ``zstandard`` extra); a
bare container prints a comment and benchmarks the other two.
"""

from __future__ import annotations

import numpy as np

from repro.core import ParallelGzipReader
from repro.core.codec import have_zstd
from repro.core.synth import bgzf_compress, gzip_compress

from .common import DataGen, emit, scale

_N_READS = 60
_REQ_SIZE = 16 << 10


def _percentile_us(lats, q):
    return float(np.percentile(np.asarray(lats) * 1e6, q))


def _random_access(reader, total: int, n_reads: int, seed: int):
    rng = np.random.default_rng(seed)
    offs = rng.integers(0, max(1, total - _REQ_SIZE), size=n_reads)
    lats = []
    import time

    for off in offs:
        t0 = time.perf_counter()
        reader.pread(int(off), _REQ_SIZE)
        lats.append(time.perf_counter() - t0)
    return lats


def _bench_one(tag: str, comp: bytes, total: int) -> None:
    # Chunk size shrinks with the corpus so even a smoke run spans several
    # chunks — otherwise deflate's cold open degenerates to a single exact
    # chunk and the speculative-work contrast with BGZF disappears.
    chunk = scale(512 << 10, floor=32 << 10)
    # Cold: fresh reader, no index. The first preads race the index build
    # (speculative for deflate, metadata-walk for BGZF/zstd).
    with ParallelGzipReader(comp, parallelization=4, chunk_size=chunk) as r:
        lats = _random_access(r, total, _N_READS, seed=7)
        st = r.stats()
        nominal = st["fetcher"]["nominal_tasks"]
        emit(
            "codecs.%s.cold_pread_p50" % tag,
            _percentile_us(lats, 50),
            "nominal_tasks=%d" % nominal,
        )
        emit("codecs.%s.cold_pread_p99" % tag, _percentile_us(lats, 99))
        r.read()  # complete coverage so the exported index is finalized
        index_blob = r.index.to_bytes()

    # Warm: import the finalized index — every codec is lock-free here.
    with ParallelGzipReader(
        comp, parallelization=4, chunk_size=chunk, index=index_blob
    ) as r:
        lats = _random_access(r, total, _N_READS, seed=11)
        st = r.stats()
        emit(
            "codecs.%s.warm_pread_p50" % tag,
            _percentile_us(lats, 50),
            "lock_acquires=%d" % st["frontier"]["lock_acquires"],
        )
        emit("codecs.%s.warm_pread_p99" % tag, _percentile_us(lats, 99))


def main() -> None:
    gen = DataGen(0xC0DEC)
    total = scale(8 << 20, floor=256 << 10)
    data = gen.text(total // 2) + gen.base64(total - total // 2)

    archives = [
        ("deflate", gzip_compress(data, 6)),
        ("bgzf", bgzf_compress(data, 6)),
    ]
    if have_zstd():
        from repro.core.synth import zstd_seekable_compress

        archives.append(("zstd", zstd_seekable_compress(data, 3)))
    else:
        print("# codecs: no zstd library importable — zstd rows skipped")

    for tag, comp in archives:
        emit(
            "codecs.%s.compressed_ratio" % tag,
            0.0,
            "%.3f" % (len(comp) / max(1, len(data))),
        )
        _bench_one(tag, comp, len(data))


if __name__ == "__main__":
    main()
