"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (see DESIGN.md §8 for the
table/figure mapping). ``python -m benchmarks.run [--only sections] [--smoke]
[--check]``. ``--check`` diffs each section's fresh rows against the
committed ``BENCH_<section>.json`` before overwriting it and flags >25%
per-row regressions (benchmarks/trajectory.py) — the cross-PR trajectory
gate.

``--smoke`` shrinks every section to tiny sizes (common.scale) so the whole
harness completes in a couple of minutes — a CI check that each benchmark
still runs, not a measurement. The service section includes the concurrent-reader
scaling scenario (locked cursor vs lock-free pread vs async front-end), so
every smoke run records that trajectory; the matching tier-2 correctness
suite is ``pytest -m stress`` (threaded/async consistency with timeouts).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
import traceback

from . import common, trajectory

#: BENCH_<section>.json lands next to the repo's other BENCH_* artifacts.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _persist_section(name: str, rows, elapsed_s: float, smoke: bool) -> None:
    """One JSON artifact per section: the same rows as the CSV stdout, plus
    enough context (smoke flag, wall time, timestamp) to compare runs."""
    payload = {
        "section": name,
        "smoke": smoke,
        "elapsed_s": round(elapsed_s, 3),
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "results": rows,
    }
    out = os.path.join(_REPO_ROOT, "BENCH_%s.json" % name)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", default="",
        help="comma list: components,decomp,kernels,roofline,codecs,service,"
             "remote,gateway,fleet,transcode,obs",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny sizes, 1 repeat: verify every section runs in <60 s total",
    )
    ap.add_argument(
        "--check", action="store_true",
        help="diff fresh rows against the committed BENCH_<section>.json"
             " before overwriting it; flag >25%% per-row regressions",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.smoke:
        common.set_smoke(True)

    sections = []
    if only is None or "components" in only:
        from . import bench_components

        sections.append(("components", lambda: bench_components.main(tempfile.mkdtemp())))
    if only is None or "decomp" in only:
        from . import bench_decompression

        sections.append(("decompression", bench_decompression.main))
    if only is None or "kernels" in only:
        from . import bench_kernels

        sections.append(("kernels", bench_kernels.main))
    if only is None or "roofline" in only:
        from . import roofline_report

        sections.append(("roofline", roofline_report.main))
    if only is None or "codecs" in only:
        from . import bench_codecs

        # Same logical corpus under deflate/BGZF/zstd: cold vs warm
        # random-access p50, with the cold row recording nominal_tasks
        # (BGZF must show 0 — exact index from framing metadata alone).
        sections.append(("codecs", bench_codecs.main))
    if only is None or "service" in only:
        from . import bench_service

        sections.append(("service", bench_service.main))
    if only is None or "remote" in only:
        from . import bench_service as _bench_remote_mod

        # Hermetic: latency-injected loopback HTTP server, no external
        # network — safe under --smoke in CI.
        sections.append(("remote", _bench_remote_mod.bench_remote))
    if only is None or "gateway" in only:
        from . import bench_service as _bench_gateway_mod

        # Hermetic: in-process loopback GatewayServer — wire overhead vs
        # in-process, chunked streaming, and the flood-isolation acceptance.
        sections.append(("gateway", _bench_gateway_mod.bench_gateway))
    if only is None or "fleet" in only:
        from . import bench_service as _bench_fleet_mod

        # Hermetic: 3 loopback gateways behind a FleetRouter — routed vs
        # direct read latency, failover recovery, index-exchange warm open.
        sections.append(("fleet", _bench_fleet_mod.bench_fleet))
    if only is None or "obs" in only:
        from . import bench_obs

        # Tracing overhead: warm pread p50/p99 traced vs untraced (the ≤5%
        # acceptance bar) and the disabled-path noop span cost.
        sections.append(("obs", bench_obs.main))
    if only is None or "transcode" in only:
        from . import bench_transcode

        # Seek-hostile archive cold random access before vs after the
        # background twin install — the acceptance bar is a >=5x p99 win.
        sections.append(("transcode", lambda: bench_transcode.main(tempfile.mkdtemp())))

    failures = 0
    regressed_sections = 0
    t_start = time.perf_counter()
    for name, fn in sections:
        print(f"# === {name} ===")
        common.drain_results()  # a failed prior section must not leak rows
        t_section = time.perf_counter()
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# section {name} FAILED", file=sys.stderr)
            traceback.print_exc()
        else:
            rows = common.drain_results()
            if args.check:
                # Diff against the committed artifact *before* _persist_section
                # overwrites it — this is the cross-PR trajectory gate.
                report = trajectory.check_section(
                    _REPO_ROOT, name, rows, smoke=args.smoke
                )
                for line in trajectory.format_report(report):
                    print(line)
                if report.get("status") == "regressed":
                    regressed_sections += 1
            _persist_section(
                name, rows, time.perf_counter() - t_section, args.smoke,
            )
    if args.smoke:
        print(f"# smoke total: {time.perf_counter() - t_start:.1f}s")
    if args.check:
        print(f"# trajectory: {regressed_sections} section(s) with regressions")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
