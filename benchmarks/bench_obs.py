"""Observability overhead: traced vs untraced warm preads, noop-span cost.

The tracing subsystem's contract (docs/observability.md) is two-tier:

  * **disabled** — a ``span()`` call is one module-flag check returning a
    shared no-op object; the cost must be *unmeasurable* against a warm
    pread (hundreds of nanoseconds vs tens of microseconds). Measured
    directly here as ``obs_noop_span_ns``.
  * **enabled** — a service boundary allocates one Span, stamps two
    clocks, and appends one tuple to the ring buffer. The acceptance bar
    (ISSUE 10) is ≤5% added latency on the **warm request path** — the
    ``obs_wire_*`` rows: a warm 4 MiB pread through the gateway loopback,
    the bulk-serving shape the tentpole instruments end to end (5 spans
    per request: client range-GET root, gateway.request, admission wait,
    bridge hop, server.read_range). The in-process ``obs_warm_pread_*``
    rows report the per-span cost in *absolute* terms (paired-delta µs
    per read): a tight single-thread `read_range` loop is a denominator
    an in-process tracer cannot hide behind, so that row exists for
    transparency about the per-span price, not as the 5% gate.

Methodology notes, hard-won on a 2-core virtualized host:

  * The wire client runs in a **subprocess**. Client and server sharing
    one interpreter share one GIL, so server-side span work bills itself
    to the *client's* ``conn.request()`` wall time and roughly doubles
    the apparent overhead. A separate process measures what a real
    caller sees.
  * Both comparisons are **paired/blocked**: A-B-B-A blocks of reads
    with tracing toggled per block, pairing block medians. Sequential
    A-then-B runs are hopeless for µs-scale effects — host drift moves
    p50 by more per minute than tracing costs per read.
  * The gateway client is built with a single-block cache and
    block-aligned offsets; otherwise its own block cache serves repeat
    reads locally and the "wire" rows measure a dict lookup.
"""

from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.obs import hist as obs_hist
from repro.obs import trace as obs_trace
from repro.service import ArchiveServer

from .common import DataGen, emit, gzip_bytes, scale


def _percentiles(samples_s):
    arr = np.sort(np.asarray(samples_s))
    return (
        float(arr[int(0.50 * (len(arr) - 1))]) * 1e6,
        float(arr[int(0.99 * (len(arr) - 1))]) * 1e6,
    )


def _paired_ab(read_fn, offsets, n_pairs):
    """Median paired delta + per-mode samples for traced-vs-untraced.

    Calls ``read_fn(offset)`` with tracing toggled around it — the same
    offset read back to back in both modes, alternating which mode goes
    first; returns (off_samples, on_samples, deltas) in seconds.
    """
    off_samples: list = []
    on_samples: list = []
    deltas: list = []
    for i in range(n_pairs):
        off = int(offsets[i % len(offsets)])
        first_traced = bool(i & 1)
        pair = {}
        for traced in (first_traced, not first_traced):
            if traced:
                obs_trace.enable_tracing()
            else:
                obs_trace.disable_tracing()
            t0 = time.perf_counter()
            read_fn(off)
            pair[traced] = time.perf_counter() - t0
        on_samples.append(pair[True])
        off_samples.append(pair[False])
        deltas.append(pair[True] - pair[False])
    obs_trace.disable_tracing()
    return off_samples, on_samples, deltas


def bench_noop_span() -> None:
    """Cost of `span()` while tracing is disabled: the always-paid tax."""
    obs_trace.disable_tracing()
    n = scale(200_000, floor=20_000)
    span = obs_trace.span
    t0 = time.perf_counter()
    for _ in range(n):
        with span("bench.noop"):
            pass
    per_call = (time.perf_counter() - t0) / n
    emit("obs_noop_span_ns", per_call * 1e3, "%.0fns disabled span()" % (per_call * 1e9))


def bench_traced_pread() -> None:
    """Absolute per-read span cost: in-process warm read_range, paired."""
    gen = DataGen(0x0B5E)
    data = gen.text(scale(8 << 20, floor=2 << 20))
    comp = gzip_bytes(data, 6)
    # One gateway stream chunk: the serving path preads up to `stream_span`
    # per await, so a warm 1 MiB read is the in-process unit of work the
    # traced request path repeats.
    req_size = 1 << 20
    n_pairs = scale(1200, floor=250)
    rng = np.random.default_rng(42)
    offsets = rng.integers(0, max(1, len(data) - req_size), 64)

    with ArchiveServer(
        cache_budget_bytes=64 << 20, max_workers=4, slow_request_s=None
    ) as server:
        h = server.open(comp)
        server.read_range(h, 0, len(data))  # fully warm the chunk cache
        for _ in range(3):  # reach allocator/clock steady state
            for off in offsets:
                server.read_range(h, int(off), req_size)

        off_s, on_s, deltas = _paired_ab(
            lambda off: server.read_range(h, off, req_size), offsets, n_pairs
        )
        stats = obs_trace.tracing_stats()
        obs_trace.reset_tracing()
        obs_hist.reset_histograms()

    off_p50, off_p99 = _percentiles(off_s)
    on_p50, on_p99 = _percentiles(on_s)
    delta_p50 = float(np.median(deltas)) * 1e6
    emit("obs_warm_pread_p50_untraced", off_p50, "p99=%.1fus" % off_p99)
    emit(
        "obs_warm_pread_p50_traced", on_p50,
        "p99=%.1fus spans=%d paired_delta=%+.2fus/read"
        " (absolute span cost; the 5%% gate is the wire rows)"
        % (on_p99, stats["recorded_total"], delta_p50),
    )


#: Benchmark client run in a separate interpreter (own GIL): reads the
#: requested offsets through the gateway, toggling its *own* tracing per
#: block, and reports per-read wall times over stdout. Protocol:
#: ``b <on|off> <off1> <off2> ...`` -> space-joined seconds; ``q`` -> exit.
_WIRE_CHILD = r'''
import sys, time
from repro.obs import trace as obs_trace
from repro.service.gateway import GatewayClient

url, path, req = sys.argv[1], sys.argv[2], int(sys.argv[3])
cl = GatewayClient(url, source=path, block_size=req, cache_blocks=1)
print("ready", flush=True)
for line in sys.stdin:
    parts = line.split()
    if not parts or parts[0] == "q":
        break
    if parts[0] == "b":
        if parts[1] == "on":
            obs_trace.enable_tracing()
        else:
            obs_trace.disable_tracing()
        out = []
        for tok in parts[2:]:
            t0 = time.perf_counter()
            cl.pread(int(tok), req)
            out.append("%.9f" % (time.perf_counter() - t0))
        print(" ".join(out), flush=True)
cl.close()
'''


def bench_traced_wire() -> None:
    """The acceptance measurement: warm 4 MiB preads through the gateway.

    This is the end-to-end path the tentpole instruments — client range
    GET → gateway accept → admission → bridge → read_range — and
    therefore the path whose latency the ≤5% bar protects. The client
    lives in a subprocess (see the module docstring: sharing the server's
    GIL inflates the apparent overhead ~2x), tracing is toggled on both
    sides per block of reads, and blocks alternate A-B-B-A so linear
    host drift cancels out of the paired block-median deltas.
    """
    import repro
    from repro.service.gateway import GatewayServer

    req_size = 4 << 20
    reads_per_block = 8
    n_super = scale(24, floor=12)  # super-block = off,on,on,off blocks
    gen = DataGen(0x0B5E)
    data = gen.text(scale(48 << 20, floor=24 << 20))
    n_blocks = len(data) // req_size - 1
    rng = np.random.default_rng(7)
    offsets = [int(x) * req_size for x in rng.permutation(n_blocks - 1)[:12]]

    src_dir = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )

    with tempfile.TemporaryDirectory(prefix="bench_obs_") as tmpdir:
        path = os.path.join(tmpdir, "obs.gz")
        with open(path, "wb") as f:
            f.write(gzip_bytes(data, 6))
        with ArchiveServer(
            cache_budget_bytes=160 << 20, max_workers=4, slow_request_s=None
        ) as server:
            with GatewayServer(server, front_end_threads=4) as gw:
                child = subprocess.Popen(
                    [sys.executable, "-u", "-c", _WIRE_CHILD,
                     gw.url, path, str(req_size)],
                    stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                    text=True, env=env,
                )
                try:
                    if child.stdout.readline().strip() != "ready":
                        raise RuntimeError("wire bench child failed to start")

                    def block(mode, i0):
                        offs = [
                            offsets[(i0 + j) % len(offsets)]
                            for j in range(reads_per_block)
                        ]
                        if mode == "on":
                            obs_trace.enable_tracing()
                        else:
                            obs_trace.disable_tracing()
                        child.stdin.write(
                            "b %s %s\n" % (mode, " ".join(map(str, offs)))
                        )
                        child.stdin.flush()
                        line = child.stdout.readline()
                        if not line:
                            raise RuntimeError("wire bench child exited early")
                        times = [float(x) for x in line.split()]
                        return float(np.median(times)), times

                    # Warm every offset in both modes: server chunk cache,
                    # connection reuse, import/code paths on both sides.
                    for mode, i0 in (("off", 0), ("on", 4), ("off", 8)):
                        block(mode, i0)

                    base_meds: list = []
                    deltas: list = []
                    off_samples: list = []
                    on_samples: list = []
                    for i in range(n_super):
                        a1, ta1 = block("off", i * 4)
                        b1, tb1 = block("on", i * 4)
                        b2, tb2 = block("on", i * 4 + 2)
                        a2, ta2 = block("off", i * 4 + 2)
                        base_meds += [a1, a2]
                        off_samples += ta1 + ta2
                        on_samples += tb1 + tb2
                        deltas.append(((b1 + b2) - (a1 + a2)) / 2)
                    obs_trace.disable_tracing()
                    stats = obs_trace.tracing_stats()
                    obs_trace.reset_tracing()
                    obs_hist.reset_histograms()
                    child.stdin.write("q\n")
                    child.stdin.flush()
                    child.wait(timeout=10)
                finally:
                    if child.poll() is None:
                        child.kill()

    off_p50, off_p99 = _percentiles(off_samples)
    on_p50, on_p99 = _percentiles(on_samples)
    base_p50 = float(np.median(base_meds)) * 1e6
    delta = float(np.median(deltas)) * 1e6
    overhead = 100.0 * delta / base_p50
    emit(
        "obs_wire_pread_p50_untraced", off_p50,
        "p99=%.1fus 4MiB warm pread, subprocess client" % off_p99,
    )
    # The overhead percentage lives in the derived string, not as a row
    # value: a ratio hovering near zero would trip the trajectory checker's
    # relative threshold on pure noise.
    emit(
        "obs_wire_pread_p50_traced", on_p50,
        "p99=%.1fus server_spans=%d paired_delta=%+.1fus overhead=%+.2f%%"
        " target<=5%%"
        % (on_p99, stats["recorded_total"], delta, overhead),
    )


def main() -> None:
    bench_noop_span()
    bench_traced_pread()
    bench_traced_wire()


if __name__ == "__main__":
    main()
