"""Archive-service benchmark: N concurrent clients x M files.

What the single-reader benchmarks cannot show: aggregate throughput when
many clients hammer overlapping files behind one shared cache budget and one
fair thread pool, and the cold->warm delta from the persistent IndexStore
(warm opens skip the speculative first pass entirely — zero nominal tasks).

Emits:
  service_cold_Nc_Mf      aggregate MB/s, first-pass work accounting
  service_warm_Nc_Mf      same traffic with a warm IndexStore
  service_seq_1c_Mf       sequential single-client baseline (fairness cost)
  service_skew_task_rr    skewed tenants (1 heavy batch vs N interactive)
  service_skew_drr        under legacy task-count RR vs byte-weighted DRR +
                          priority lanes; value = interactive first-byte p99
                          us, derived includes p50 and the dispatched-bytes
                          split (acceptance: p99_drr < p99_task_rr)
  service_scaling_locked_Nt    N threads hammering ONE warm handle through
  service_scaling_lockfree_Nt  the legacy serialized cursor vs stateless
                               pread; value = per-request p99 us, derived
                               has p50 + aggregate MB/s + frontier-lock
                               counters (acceptance: lockfree aggregate
                               throughput ~ worker-count x serialized)
  service_scaling_async_Nc     same traffic as read_many batches through
                               AsyncArchiveServer (bridge + event loop)
  service_scaling_speedup      lockfree aggregate MB/s over locked

`bench_remote` (its own section in run.py) measures the remote range-GET
backend against a latency-injected loopback server: cold vs warm index and
a prefetch-degree sweep — see its docstring.

`bench_gateway` (section "gateway" in run.py, --smoke compatible) measures
the HTTP wire front-end: N concurrent HTTP clients vs the same traffic
in-process (wire overhead), chunked full-stream throughput, and the
admission-control isolation acceptance — a flooding tenant collects 429s
while a second tenant's p99 first-byte latency stays within 2x its
unloaded baseline.
"""

from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np

from repro.service import ArchiveServer, IndexStore

from . import common
from .common import DataGen, emit, gzip_bytes, scale


def _percentiles(lats):
    """(p50, p99) seconds of a latency list — shared by every scenario."""
    arr = np.asarray(lats)  # np.percentile partitions internally: no pre-sort
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def _make_files(gen: DataGen, tmpdir: str, n_files: int, size: int):
    paths, datas = [], []
    makers = [gen.text, gen.base64, gen.silesia_like, gen.fastq_like]
    for i in range(n_files):
        data = makers[i % len(makers)](size)
        path = os.path.join(tmpdir, f"archive-{i:02d}.gz")
        with open(path, "wb") as f:
            f.write(gzip_bytes(data, 6))
        paths.append(path)
        datas.append(data)
    return paths, datas


def _client(server, handles, datas, rng_seed: int, n_requests: int, req_size: int, errors):
    rng = np.random.default_rng(rng_seed)
    served = 0
    try:
        for _ in range(n_requests):
            i = int(rng.integers(0, len(handles)))
            off = int(rng.integers(0, max(1, len(datas[i]) - req_size)))
            got = server.read_range(handles[i], off, req_size)
            if got != datas[i][off : off + len(got)]:
                raise AssertionError("byte mismatch at file %d offset %d" % (i, off))
            served += len(got)
    except BaseException as exc:  # noqa: BLE001 - surface in the main thread
        errors.append(exc)
    return served


def _run_fleet(server, handles, datas, *, n_clients: int, n_requests: int, req_size: int):
    errors: list = []
    threads = [
        threading.Thread(
            target=_client,
            args=(server, handles, datas, 1000 + c, n_requests, req_size, errors),
        )
        for c in range(n_clients)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return dt


def _skewed_tenants(gen: DataGen, tmpdir: str) -> None:
    """One heavy batch tenant streaming a large file vs N interactive
    tenants doing small random reads, under both fairness disciplines.

    The heavy tenant's sequential scan keeps a deep backlog of big prefetch
    tasks queued; each interactive request is one small blocking fetch. The
    interesting number is the interactive tenants' first-byte latency tail:
    task-count RR interleaves them 1:1 with multi-MiB-cost tasks, while
    byte-weighted DRR makes the heavy tenant bank deficit across visits and
    priority lanes let blocking reads jump their own tenant's prefetches.
    """
    n_inter = 3
    n_requests = 12 if common.SMOKE else 64
    heavy_size = scale(16 << 20, floor=4 << 20)
    # Interactive working set >> cache budget so timed requests keep missing
    # cache and re-entering the scheduler (the path under test).
    inter_size = scale(8 << 20, floor=2 << 20)
    req_size = 8 << 10
    chunk_size = 128 << 10

    heavy_path = os.path.join(tmpdir, "skew-heavy.gz")
    with open(heavy_path, "wb") as f:
        f.write(gzip_bytes(gen.silesia_like(heavy_size), 6))
    inter_paths, inter_datas = [], []
    for i in range(n_inter):
        data = gen.text(inter_size)
        path = os.path.join(tmpdir, f"skew-inter-{i}.gz")
        with open(path, "wb") as f:
            f.write(gzip_bytes(data, 6))
        inter_paths.append(path)
        inter_datas.append(data)

    results = {}
    for fairness in ("task_rr", "drr"):
        server = ArchiveServer(
            max_workers=2,  # scarce workers: arbitration order dominates
            # Budget far below the working set: interactive reads keep
            # missing cache and re-entering the scheduler, which is the
            # path being measured.
            cache_budget_bytes=1 << 20,
            chunk_size=chunk_size,
            reader_parallelization=4,
            fairness=fairness,
        )
        h_heavy = server.open(heavy_path, tenant="batch")
        h_inter = [
            server.open(p, tenant=f"inter{i}") for i, p in enumerate(inter_paths)
        ]
        # Warm phase (untimed): drive every first pass to EOF so the timed
        # requests measure steady-state indexed reads, not one-off
        # speculative passes hundreds of ms long.
        for h in [h_heavy] + h_inter:
            server.size(h)
        stop = threading.Event()
        errors: list = []

        def batch_client():
            # Endless sequential scan: every read fans out chunk prefetches.
            span = 1 << 20
            off = 0
            try:
                while not stop.is_set():
                    got = server.read_range(h_heavy, off, span)
                    off = 0 if len(got) < span else off + span
            except BaseException as exc:  # noqa: BLE001
                errors.append(exc)

        lat_lock = threading.Lock()
        latencies: list = []

        def interactive_client(idx: int):
            rng = np.random.default_rng(42 + idx)
            data = inter_datas[idx]
            try:
                for _ in range(n_requests):
                    off = int(rng.integers(0, max(1, len(data) - req_size)))
                    t0 = time.perf_counter()
                    got = server.read_range(h_inter[idx], off, req_size)
                    dt = time.perf_counter() - t0
                    if got != data[off : off + len(got)]:
                        raise AssertionError("skew scenario byte mismatch")
                    with lat_lock:
                        latencies.append(dt)
            except BaseException as exc:  # noqa: BLE001
                errors.append(exc)

        batch = threading.Thread(target=batch_client)
        inters = [
            threading.Thread(target=interactive_client, args=(i,))
            for i in range(n_inter)
        ]
        batch.start()
        for t in inters:
            t.start()
        for t in inters:
            t.join()
        stop.set()
        batch.join()
        snap = server.metrics()
        server.shutdown()
        if errors:
            raise errors[0]

        p50, p99 = _percentiles(latencies)
        results[fairness] = p99
        sched = snap["scheduler"]
        db = sched.get("dispatched_bytes_per_tenant", {})
        inter_bytes = sum(v for k, v in db.items() if k.startswith("inter"))
        emit(
            f"service_skew_{fairness}", p99 * 1e6,
            f"p50={p50*1e3:.2f}ms p99={p99*1e3:.2f}ms reqs={len(latencies)} "
            f"batch_bytes={db.get('batch', 0)} inter_bytes={inter_bytes} "
            f"priority_dispatches={sched.get('priority_dispatches', 0)}",
        )
    better = results.get("drr", 0) <= results.get("task_rr", 0)
    emit(
        "service_skew_p99_improvement",
        (results.get("task_rr", 0) - results.get("drr", 0)) * 1e6,
        f"drr_beats_task_rr={better}",
    )


def _concurrent_scaling(gen: DataGen, tmpdir: str) -> None:
    """N concurrent readers hammering ONE warm (finalized-index) handle:
    the legacy serialized discipline (entry lock around a shared-cursor
    seek+read) vs stateless lock-free preads.

    The cache budget is far below the file's working set so timed requests
    keep re-decoding chunks through the shared executor — exactly the work
    the per-handle lock used to serialize. Serialized mode degenerates to
    one zlib delegation at a time regardless of workers; lock-free mode
    keeps all workers busy, so aggregate throughput should scale toward the
    worker count (the PR's acceptance criterion). An asyncio variant drives
    the same traffic as `AsyncArchiveServer.read_many` batches.
    """
    import asyncio

    from repro.service import AsyncArchiveServer

    n_threads = 4 if common.SMOKE else 8
    n_requests = 6 if common.SMOKE else 64  # per thread
    size = scale(8 << 20, floor=1 << 20)
    req_size = 32 << 10 if common.SMOKE else 64 << 10
    chunk_size = 128 << 10 if common.SMOKE else 256 << 10
    data = gen.base64(size)  # low compression ratio: decode cost dominates
    path = os.path.join(tmpdir, "scaling.gz")
    with open(path, "wb") as f:
        f.write(gzip_bytes(data, 6))

    def make_server() -> tuple:
        server = ArchiveServer(
            max_workers=n_threads,
            # Budget << working set: every timed request re-enters the
            # executor for a zlib-delegated chunk decode (the contended path).
            cache_budget_bytes=max(256 << 10, size // 8),
            chunk_size=chunk_size,
            reader_parallelization=4,
        )
        h = server.open(path)
        server.size(h)  # finalize the index: timed reads are all indexed
        return server, h

    results = {}
    for mode in ("locked", "lockfree"):
        server, h = make_server()
        serialized = mode == "locked"
        lat_lock = threading.Lock()
        latencies: list = []
        errors: list = []

        def worker(seed: int) -> None:
            rng = np.random.default_rng(seed)
            try:
                for _ in range(n_requests):
                    off = int(rng.integers(0, max(1, len(data) - req_size)))
                    t0 = time.perf_counter()
                    got = server.read_range(h, off, req_size, serialized=serialized)
                    dt = time.perf_counter() - t0
                    if got != data[off : off + len(got)]:
                        raise AssertionError("scaling scenario byte mismatch")
                    with lat_lock:
                        latencies.append(dt)
            except BaseException as exc:  # noqa: BLE001 - surface in main thread
                errors.append(exc)

        threads = [
            threading.Thread(target=worker, args=(7 + i,)) for i in range(n_threads)
        ]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        m = server.metrics()
        server.shutdown()
        if errors:
            raise errors[0]
        p50, p99 = _percentiles(latencies)
        mbps = len(latencies) * req_size / wall / 1e6
        results[mode] = mbps
        fr = m["fleet"]["frontier"]
        emit(
            f"service_scaling_{mode}_{n_threads}t", p99 * 1e6,
            f"p50={p50*1e3:.2f}ms p99={p99*1e3:.2f}ms {mbps:.1f}MB/s "
            f"frontier_acquires={fr['lock_acquires']} "
            f"contended={fr['lock_contended']} "
            f"reads={m['service']['reads_started']}",
        )

    # Async front-end: same traffic shape, batched through read_many.
    server, h = make_server()

    async def async_clients() -> list:
        async with AsyncArchiveServer(server, front_end_threads=n_threads) as asrv:
            lats: list = []

            async def client(seed: int) -> None:
                rng = np.random.default_rng(seed)
                # Batches of 4: exercises gather fan-out AND per-await timing.
                for _ in range(max(1, n_requests // 4)):
                    offs = [
                        int(rng.integers(0, max(1, len(data) - req_size)))
                        for _ in range(4)
                    ]
                    t0 = time.perf_counter()
                    got = await asrv.read_many([(h, o, req_size) for o in offs])
                    dt = time.perf_counter() - t0
                    for o, g in zip(offs, got):
                        if g != data[o : o + len(g)]:
                            raise AssertionError("async scaling byte mismatch")
                    lats.append(dt / 4)
            await asyncio.gather(*(client(70 + i) for i in range(n_threads)))
            return lats

    t0 = time.perf_counter()
    lats = asyncio.run(async_clients())
    wall = time.perf_counter() - t0
    server.shutdown()
    p50, p99 = _percentiles(lats)
    n_served = len(lats) * 4
    mbps = n_served * req_size / wall / 1e6
    emit(
        f"service_scaling_async_{n_threads}c", p99 * 1e6,
        f"p50={p50*1e3:.2f}ms p99={p99*1e3:.2f}ms {mbps:.1f}MB/s reqs={n_served}",
    )
    emit(
        "service_scaling_speedup",
        results["lockfree"] / max(results["locked"], 1e-9) * 100,
        f"lockfree={results['lockfree']:.1f}MB/s locked={results['locked']:.1f}MB/s "
        f"(value = percent, >100 means lock-free wins)",
    )


def bench_remote() -> None:
    """Remote range-GET backend over a latency-injected loopback server.

    What the local benchmarks cannot show: how well the chunk prefetcher
    hides *network* round trips (paper §3.2's latency-hiding argument
    transferred from decompression to range-GETs). Sweeps the prefetch
    degree (reader parallelization) cold (speculative first pass over the
    wire) and warm (imported index, O(range) zlib-delegated reads), plus a
    warm random-access probe where only the touched chunks travel.

    Emits:
      remote_cold_p{P}    sequential full read, no index
      remote_warm_p{P}    same traffic with an imported seek index
      remote_warm_seek    32 random 64 KiB reads through the warm index;
                          derived reports bytes fetched vs archive size
    """
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from tests._range_server import RangeHTTPServer

    from repro.core import GzipIndex, ParallelGzipReader
    from repro.core.remote import RemoteFileReader

    gen = DataGen()
    size = scale(8 << 20, floor=512 << 10)
    data = gen.base64(size)  # low ratio: many compressed chunks in flight
    blob = gzip_bytes(data, 6)
    latency = 0.0005 if common.SMOKE else 0.005  # injected per-request RTT
    chunk_size = 128 << 10
    block_size = 128 << 10
    degrees = (1, 4) if common.SMOKE else (1, 2, 4, 8)

    with RangeHTTPServer(blob, latency=latency) as srv:

        def open_reader(p: int, index=None) -> ParallelGzipReader:
            return ParallelGzipReader(
                RemoteFileReader(srv.url, block_size=block_size, cache_blocks=16),
                parallelization=p,
                chunk_size=chunk_size,
                index=index,
            )

        index_blob = None
        for p in degrees:
            t0 = time.perf_counter()
            r = open_reader(p)
            got = r.read()
            dt = time.perf_counter() - t0
            assert got == data, "remote cold read mismatch"
            if index_blob is None:
                index_blob = r.build_full_index().to_bytes()
            rs = r._reader.stats  # noqa: SLF001 - benchmark introspection
            r.close()
            emit(
                f"remote_cold_p{p}", dt * 1e6,
                f"{len(data)/dt/1e6:.1f}MB/s requests={rs.requests} "
                f"fetched={rs.bytes_fetched} retries={rs.retries}",
            )

        for p in degrees:
            idx = GzipIndex.from_bytes(index_blob)
            t0 = time.perf_counter()
            r = open_reader(p, index=idx)
            got = r.read()
            dt = time.perf_counter() - t0
            assert got == data, "remote warm read mismatch"
            rs = r._reader.stats  # noqa: SLF001
            r.close()
            emit(
                f"remote_warm_p{p}", dt * 1e6,
                f"{len(data)/dt/1e6:.1f}MB/s requests={rs.requests} "
                f"fetched={rs.bytes_fetched}",
            )

        # Warm random access: the indexed path's O(range) promise — only the
        # compressed spans of touched chunks cross the wire.
        rng = np.random.default_rng(7)
        n_seeks = 8 if common.SMOKE else 32
        req = 64 << 10
        r = open_reader(4, index=GzipIndex.from_bytes(index_blob))
        t0 = time.perf_counter()
        for _ in range(n_seeks):
            off = int(rng.integers(0, max(1, len(data) - req)))
            r.seek(off)
            assert r.read(req) == data[off : off + req]
        dt = time.perf_counter() - t0
        rs = r._reader.stats  # noqa: SLF001
        r.close()
        emit(
            "remote_warm_seek", dt / n_seeks * 1e6,
            f"fetched={rs.bytes_fetched} of archive={len(blob)} "
            f"({rs.bytes_fetched/len(blob):.2f}x) requests={rs.requests}",
        )


def bench_gateway() -> None:
    """HTTP wire front-end vs in-process, plus admission isolation.

    Emits:
      gateway_inproc_{N}c    N threads x read_range in-process (baseline)
      gateway_http_{N}c      same traffic as HTTP range GETs through the
                             gateway; value = per-request p99 us, derived
                             has p50 + aggregate MB/s
      gateway_stream         chunked full-body stream throughput (1 client)
      gateway_vip_p99_unloaded   interactive tenant alone
      gateway_vip_p99_flooded    same traffic while a flooding tenant slams
                                 the gateway into 429s; derived reports the
                                 flood's 429 count and the acceptance check
                                 p99_flooded <= 2x p99_unloaded
    """
    import http.client

    from repro.service.gateway import GatewayClient, GatewayServer, TenantAdmission
    from repro.service.gateway.admission import TenantLimit

    gen = DataGen()
    n_clients = 2 if common.SMOKE else 8
    n_requests = 6 if common.SMOKE else 48  # per client
    size = scale(8 << 20, floor=1 << 20)
    req_size = 16 << 10
    chunk_size = 128 << 10
    data = gen.base64(size)  # decode-bound: the wire must keep workers fed

    def run_threads(fn, n, *args):
        lats: list = []
        errors: list = []
        lock = threading.Lock()

        def worker(seed):
            try:
                out = fn(seed, *args)
                with lock:
                    lats.extend(out)
            except BaseException as exc:  # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(31 + i,)) for i in range(n)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]
        return lats, wall

    with tempfile.TemporaryDirectory(prefix="bench_gateway_") as tmpdir:
        path = os.path.join(tmpdir, "gw.gz")
        with open(path, "wb") as f:
            f.write(gzip_bytes(data, 6))

        server = ArchiveServer(
            max_workers=4,
            cache_budget_bytes=max(256 << 10, size // 8),  # keep decode hot
            chunk_size=chunk_size,
            reader_parallelization=4,
        )
        # Wire-overhead section measures the data path, not backpressure:
        # admit all n_clients concurrently (default limits would 429 at 8).
        open_admission = TenantAdmission(
            max_in_flight=n_clients, max_queued=2 * n_clients
        )
        with GatewayServer(
            server, stream_span=256 << 10, front_end_threads=8,
            admission=open_admission,
        ) as gw:
            client = GatewayClient(gw.url, source=path)
            handle = client.handle  # size() already drove the first pass (HEAD)
            host, port = gw.url[len("http://"):].rsplit(":", 1)
            port = int(port)

            # -- in-process baseline --
            def inproc_client(seed):
                rng = np.random.default_rng(seed)
                out = []
                for _ in range(n_requests):
                    off = int(rng.integers(0, max(1, len(data) - req_size)))
                    t0 = time.perf_counter()
                    got = server.read_range(handle, off, req_size)
                    out.append(time.perf_counter() - t0)
                    if got != data[off : off + len(got)]:
                        raise AssertionError("in-process byte mismatch")
                return out

            lats, wall = run_threads(inproc_client, n_clients)
            p50, p99 = _percentiles(lats)
            mbps = len(lats) * req_size / wall / 1e6
            emit(
                f"gateway_inproc_{n_clients}c", p99 * 1e6,
                f"p50={p50*1e3:.2f}ms p99={p99*1e3:.2f}ms {mbps:.1f}MB/s",
            )

            # -- the same traffic over the wire --
            def http_client_fn(seed):
                rng = np.random.default_rng(seed)
                conn = http.client.HTTPConnection(host, port, timeout=30)
                out = []
                try:
                    for _ in range(n_requests):
                        off = int(rng.integers(0, max(1, len(data) - req_size)))
                        t0 = time.perf_counter()
                        conn.request(
                            "GET", "/v1/archives/%s/bytes" % handle,
                            headers={"Range": "bytes=%d-%d" % (off, off + req_size - 1)},
                        )
                        resp = conn.getresponse()
                        got = resp.read()
                        out.append(time.perf_counter() - t0)
                        if resp.status != 206 or got != data[off : off + req_size]:
                            raise AssertionError("wire byte mismatch (%d)" % resp.status)
                finally:
                    conn.close()
                return out

            lats, wall = run_threads(http_client_fn, n_clients)
            p50, p99 = _percentiles(lats)
            http_mbps = len(lats) * req_size / wall / 1e6
            emit(
                f"gateway_http_{n_clients}c", p99 * 1e6,
                f"p50={p50*1e3:.2f}ms p99={p99*1e3:.2f}ms {http_mbps:.1f}MB/s "
                f"overhead_vs_inproc={mbps/max(http_mbps,1e-9):.2f}x",
            )

            # -- chunked full stream --
            t0 = time.perf_counter()
            n_streamed = sum(len(c) for c in client.stream(read_size=256 << 10))
            dt = time.perf_counter() - t0
            assert n_streamed == len(data)
            emit(
                "gateway_stream", dt * 1e6,
                f"{len(data)/dt/1e6:.1f}MB/s chunked span={len(data)}",
            )
            client.close()
        server.shutdown()

        # -- admission isolation: flood vs vip ------------------------------
        adm = TenantAdmission(
            tokens={"tf": "flood", "tv": "vip"},
            default_tenant=None,
            limits={"flood": TenantLimit(max_in_flight=1, max_queued=1),
                    "vip": TenantLimit(max_in_flight=4, max_queued=8)},
            quanta={"vip": 4.0},
            retry_after=0.1,
        )
        server = ArchiveServer(
            max_workers=2, cache_budget_bytes=512 << 10, chunk_size=chunk_size,
        )
        with GatewayServer(server, admission=adm, front_end_threads=4) as gw:
            vip = GatewayClient(gw.url, source=path, token="tv")
            flood = GatewayClient(gw.url, source=path, token="tf")
            host, port = gw.url[len("http://"):].rsplit(":", 1)
            port = int(port)
            n_vip = 4 if common.SMOKE else 24

            def vip_probe(seed):
                rng = np.random.default_rng(seed)
                conn = http.client.HTTPConnection(host, port, timeout=30)
                out = []
                try:
                    for _ in range(n_vip):
                        off = int(rng.integers(0, max(1, len(data) - req_size)))
                        t0 = time.perf_counter()
                        conn.request(
                            "GET", "/v1/archives/%s/bytes" % vip.handle,
                            headers={"Authorization": "Bearer tv",
                                     "Range": "bytes=%d-%d" % (off, off + req_size - 1)},
                        )
                        resp = conn.getresponse()
                        got = resp.read()
                        out.append(time.perf_counter() - t0)
                        if resp.status != 206 or len(got) != req_size:
                            raise AssertionError("vip probe failed (%d)" % resp.status)
                finally:
                    conn.close()
                return out

            lats, _ = run_threads(vip_probe, 2)
            vp50, vp99_unloaded = _percentiles(lats)
            emit(
                "gateway_vip_p99_unloaded", vp99_unloaded * 1e6,
                f"p50={vp50*1e3:.2f}ms",
            )

            stop = threading.Event()
            flood_codes: list = []

            def flood_client():
                while not stop.is_set():
                    conn = http.client.HTTPConnection(host, port, timeout=30)
                    try:
                        conn.request(
                            "GET", "/v1/archives/%s/bytes" % flood.handle,
                            headers={"Authorization": "Bearer tf",
                                     "Range": "bytes=0-%d" % (min(len(data), 1 << 20) - 1)},
                        )
                        resp = conn.getresponse()
                        resp.read()
                        flood_codes.append(resp.status)
                    except OSError:
                        pass
                    finally:
                        conn.close()

            flooders = [threading.Thread(target=flood_client) for _ in range(4)]
            for t in flooders:
                t.start()
            try:
                lats, _ = run_threads(vip_probe, 2)
            finally:
                stop.set()
                for t in flooders:
                    t.join()
            fp50, vp99_flooded = _percentiles(lats)
            n_429 = sum(1 for s in flood_codes if s == 429)
            within = vp99_flooded <= 2 * max(vp99_unloaded, 1e-9)
            emit(
                "gateway_vip_p99_flooded", vp99_flooded * 1e6,
                f"p50={fp50*1e3:.2f}ms flood_429s={n_429} "
                f"flood_served={sum(1 for s in flood_codes if s == 206)} "
                f"isolation_ok(p99<=2x_unloaded)={within}",
            )
            vip.close()
            flood.close()
        server.shutdown()


def bench_fleet() -> None:
    """Sharded fleet (section "fleet" in run.py): routing cost + failover.

    Emits:
      fleet_direct_{N}req     pread p99 us against the owning gateway via a
                              plain GatewayClient (no routing tier)
      fleet_routed_{N}req     the same preads through FleetRouter/FleetClient
                              — the placement + failover shell's overhead
      fleet_failover_recovery wall-clock us from owner death to the first
                              successful pread on the failover peer (includes
                              re-resolve, re-open, ETag continuity check)
      fleet_warm_open_exchange  cold open on a peer that never saw the
                              archive, with the index imported from a fleet
                              peer over the wire (O(index) instead of the
                              O(file) speculative first pass)
    """
    from repro.service.fleet import FleetRouter, make_index_fallback
    from repro.service.gateway import GatewayClient, GatewayServer

    gen = DataGen()
    size = scale(4 << 20, floor=1 << 20)
    n_requests = 16 if common.SMOKE else 200
    req_size = 16 << 10
    data = gen.text(size)

    with tempfile.TemporaryDirectory(prefix="bench_fleet_") as tmpdir:
        path = os.path.join(tmpdir, "fleet.gz")
        with open(path, "wb") as f:
            f.write(gzip_bytes(data, 6))

        stores, servers, gws = [], [], []
        for i in range(3):
            store = IndexStore(os.path.join(tmpdir, "idx%d" % i))
            srv = ArchiveServer(
                max_workers=2, cache_budget_bytes=8 << 20,
                chunk_size=128 << 10, index_store=store,
            )
            stores.append(store)
            servers.append(srv)
            gws.append(GatewayServer(srv, stream_span=128 << 10).start())
        urls = [gw.url for gw in gws]
        for i, store in enumerate(stores):
            store.set_remote_fallback(make_index_fallback(urls, exclude=[urls[i]]))
        router = FleetRouter(urls, eject_after=1)

        rng = np.random.default_rng(0xF1E7)
        offsets = [
            int(o)
            for o in rng.integers(0, max(1, len(data) - req_size), n_requests)
        ]

        def pread_lats(reader):
            lats = []
            for off in offsets:
                t0 = time.perf_counter()
                got = reader.pread(off, req_size)
                lats.append(time.perf_counter() - t0)
                if got != data[off : off + req_size]:
                    raise AssertionError("fleet byte mismatch at %d" % off)
            return lats

        try:
            # small client-side block cache: the point is wire round trips,
            # not client caching. Snappy retry policy so the recovery number
            # below measures the failover machinery (detect, re-resolve,
            # re-open, revalidate), not the default dead-peer backoff.
            routed = router.open(
                path, block_size=16 << 10, cache_blocks=2,
                max_retries=1, backoff_base=0.01, timeout=5.0,
            )
            owner = routed.peer
            direct = GatewayClient(
                owner, source=path, block_size=16 << 10, cache_blocks=2
            )
            pread_lats(direct)  # warm the server-side caches once for both
            p50, p99 = _percentiles(pread_lats(direct))
            emit(
                f"fleet_direct_{n_requests}req", p99 * 1e6,
                f"p50={p50*1e6:.0f}us p99={p99*1e6:.0f}us",
            )
            d50, d99 = _percentiles(pread_lats(routed))
            emit(
                f"fleet_routed_{n_requests}req", d99 * 1e6,
                f"p50={d50*1e6:.0f}us p99={d99*1e6:.0f}us "
                f"overhead_p50={(d50-p50)*1e6:+.0f}us",
            )
            direct.close()

            # -- failover recovery: kill the owner, time the next pread --
            next(gw for gw in gws if gw.url == owner).close()
            t0 = time.perf_counter()
            got = routed.pread(offsets[0], req_size)
            recovery = time.perf_counter() - t0
            if got != data[offsets[0] : offsets[0] + req_size]:
                raise AssertionError("post-failover byte mismatch")
            emit(
                "fleet_failover_recovery", recovery * 1e6,
                f"{recovery*1e3:.1f}ms failovers={routed.stats['failovers']} "
                f"now={routed.peer}",
            )
            survivor = routed.peer
            routed.close()  # persists the finalized index on the survivor

            # -- index exchange: cold open on the peer that saw nothing --
            third_url = next(
                u for u in urls if u not in (owner, survivor)
            )
            third = next(gw for gw in gws if gw.url == third_url)
            t0 = time.perf_counter()
            g = GatewayClient(third_url, source=path)
            warm_open = time.perf_counter() - t0
            stat = g.stat()
            m = third.metrics()
            emit(
                "fleet_warm_open_exchange", warm_open * 1e6,
                f"{warm_open*1e3:.1f}ms index_was_warm={stat['index_was_warm']} "
                f"remote_hits={m['index_store']['remote_hits']} "
                f"nominal_tasks={m['fleet']['fetcher']['nominal_tasks']}",
            )
            g.close()
        finally:
            router.close()
            for gw in gws:
                try:
                    gw.close()
                except Exception:  # noqa: BLE001 - owner killed above
                    pass
            for srv in servers:
                srv.shutdown()


def main() -> None:
    gen = DataGen()
    n_files = 2 if common.SMOKE else 4
    n_clients = 4 if common.SMOKE else 8
    n_requests = 8 if common.SMOKE else 64
    file_size = scale(4 << 20, floor=256 << 10)
    req_size = 32 << 10

    with tempfile.TemporaryDirectory(prefix="bench_service_") as tmpdir:
        paths, datas = _make_files(gen, tmpdir, n_files, file_size)
        store_dir = os.path.join(tmpdir, "indexes")
        total_req_bytes = n_clients * n_requests * req_size

        for phase in ("cold", "warm"):
            server = ArchiveServer(
                max_workers=4,
                cache_budget_bytes=8 << 20,
                index_store=IndexStore(store_dir),
                chunk_size=256 << 10,
            )
            handles = [server.open(p, tenant=f"client{i % n_clients}") for i, p in enumerate(paths)]
            dt = _run_fleet(server, handles, datas,
                            n_clients=n_clients, n_requests=n_requests, req_size=req_size)
            m = server.metrics()
            f = m["fleet"]["fetcher"]
            emit(
                f"service_{phase}_{n_clients}c_{n_files}f", dt * 1e6,
                f"{total_req_bytes/dt/1e6:.1f}MB/s nominal={f['nominal_tasks']} "
                f"exact={f['exact_tasks']} indexed={f['indexed_tasks']} "
                f"pool_evictions={sum(t['evictions'] for t in m['cache_pool']['tiers'].values())} "
                f"store_hits={m['index_store']['hits']}",
            )
            for h in handles:
                server.size(h)  # drive the first pass to EOF so the index finalizes
            server.close_all()  # persists finalized indexes -> warm phase
            server.shutdown()

        # single-client sequential baseline over the warm store
        server = ArchiveServer(
            max_workers=4, cache_budget_bytes=8 << 20,
            index_store=IndexStore(store_dir), chunk_size=256 << 10,
        )
        handles = [server.open(p) for p in paths]
        dt = _run_fleet(server, handles, datas,
                        n_clients=1, n_requests=n_clients * n_requests, req_size=req_size)
        emit(
            f"service_seq_1c_{n_files}f", dt * 1e6,
            f"{total_req_bytes/dt/1e6:.1f}MB/s",
        )
        server.shutdown()

        # skewed tenants: byte-weighted DRR + priority lanes vs task-count RR
        _skewed_tenants(gen, tmpdir)

        # concurrent-reader scaling on one warm handle: serialized cursor
        # vs lock-free pread vs async front-end
        _concurrent_scaling(gen, tmpdir)


if __name__ == "__main__":
    main()
