"""Archive-service benchmark: N concurrent clients x M files.

What the single-reader benchmarks cannot show: aggregate throughput when
many clients hammer overlapping files behind one shared cache budget and one
fair thread pool, and the cold->warm delta from the persistent IndexStore
(warm opens skip the speculative first pass entirely — zero nominal tasks).

Emits:
  service_cold_Nc_Mf      aggregate MB/s, first-pass work accounting
  service_warm_Nc_Mf      same traffic with a warm IndexStore
  service_seq_1c_Mf       sequential single-client baseline (fairness cost)
"""

from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np

from repro.service import ArchiveServer, IndexStore

from . import common
from .common import DataGen, emit, gzip_bytes, scale


def _make_files(gen: DataGen, tmpdir: str, n_files: int, size: int):
    paths, datas = [], []
    makers = [gen.text, gen.base64, gen.silesia_like, gen.fastq_like]
    for i in range(n_files):
        data = makers[i % len(makers)](size)
        path = os.path.join(tmpdir, f"archive-{i:02d}.gz")
        with open(path, "wb") as f:
            f.write(gzip_bytes(data, 6))
        paths.append(path)
        datas.append(data)
    return paths, datas


def _client(server, handles, datas, rng_seed: int, n_requests: int, req_size: int, errors):
    rng = np.random.default_rng(rng_seed)
    served = 0
    try:
        for _ in range(n_requests):
            i = int(rng.integers(0, len(handles)))
            off = int(rng.integers(0, max(1, len(datas[i]) - req_size)))
            got = server.read_range(handles[i], off, req_size)
            if got != datas[i][off : off + len(got)]:
                raise AssertionError("byte mismatch at file %d offset %d" % (i, off))
            served += len(got)
    except BaseException as exc:  # noqa: BLE001 - surface in the main thread
        errors.append(exc)
    return served


def _run_fleet(server, handles, datas, *, n_clients: int, n_requests: int, req_size: int):
    errors: list = []
    threads = [
        threading.Thread(
            target=_client,
            args=(server, handles, datas, 1000 + c, n_requests, req_size, errors),
        )
        for c in range(n_clients)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return dt


def main() -> None:
    gen = DataGen()
    n_files = 2 if common.SMOKE else 4
    n_clients = 4 if common.SMOKE else 8
    n_requests = 8 if common.SMOKE else 64
    file_size = scale(4 << 20, floor=256 << 10)
    req_size = 32 << 10

    with tempfile.TemporaryDirectory(prefix="bench_service_") as tmpdir:
        paths, datas = _make_files(gen, tmpdir, n_files, file_size)
        store_dir = os.path.join(tmpdir, "indexes")
        total_req_bytes = n_clients * n_requests * req_size

        for phase in ("cold", "warm"):
            server = ArchiveServer(
                max_workers=4,
                cache_budget_bytes=8 << 20,
                index_store=IndexStore(store_dir),
                chunk_size=256 << 10,
            )
            handles = [server.open(p, tenant=f"client{i % n_clients}") for i, p in enumerate(paths)]
            dt = _run_fleet(server, handles, datas,
                            n_clients=n_clients, n_requests=n_requests, req_size=req_size)
            m = server.metrics()
            f = m["fleet"]["fetcher"]
            emit(
                f"service_{phase}_{n_clients}c_{n_files}f", dt * 1e6,
                f"{total_req_bytes/dt/1e6:.1f}MB/s nominal={f['nominal_tasks']} "
                f"exact={f['exact_tasks']} indexed={f['indexed_tasks']} "
                f"pool_evictions={sum(t['evictions'] for t in m['cache_pool']['tiers'].values())} "
                f"store_hits={m['index_store']['hits']}",
            )
            for h in handles:
                server.size(h)  # drive the first pass to EOF so the index finalizes
            server.close_all()  # persists finalized indexes -> warm phase
            server.shutdown()

        # single-client sequential baseline over the warm store
        server = ArchiveServer(
            max_workers=4, cache_budget_bytes=8 << 20,
            index_store=IndexStore(store_dir), chunk_size=256 << 10,
        )
        handles = [server.open(p) for p in paths]
        dt = _run_fleet(server, handles, datas,
                        n_clients=1, n_requests=n_clients * n_requests, req_size=req_size)
        emit(
            f"service_seq_1c_{n_files}f", dt * 1e6,
            f"{total_req_bytes/dt/1e6:.1f}MB/s",
        )
        server.shutdown()


if __name__ == "__main__":
    main()
