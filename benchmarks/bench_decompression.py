"""Paper Figs 9/10/11 + Fig 12 + Tables 3/4: end-to-end decompression.

Wall-clock scaling curves need >1 core; this container has one, so each
figure reports (a) single-core bandwidth for every configuration, (b) the
architecture's *work accounting*: speculative tasks completed, false
positives absorbed, cache hits, zlib delegations — the quantities that
determine scaling on a real node — and (c) the sequential-fraction estimate
(window propagation + finalize) that bounds speedup by Amdahl's law, which
is the paper's own analysis (§2.2/§4.5).
"""

from __future__ import annotations

import io
import time
import zlib

from repro.core import GzipIndex, ParallelGzipReader
from repro.core.deflate import gzip_decompress_sequential
from repro.core.synth import COMPRESSORS

from .common import DataGen, emit, gzip_bytes, scale, timeit


def _run_reader(comp: bytes, *, parallelization: int, chunk_size: int, index=None):
    t0 = time.perf_counter()
    r = ParallelGzipReader(comp, parallelization=parallelization, chunk_size=chunk_size,
                           index=index)
    n = 0
    while True:
        piece = r.read(1 << 20)
        if not piece:
            break
        n += len(piece)
    dt = time.perf_counter() - t0
    stats = r.stats()
    r.close()
    return n, dt, stats


def bench_scaling(gen: DataGen, data_name: str, data: bytes) -> None:
    """Figs 9-11: first pass vs indexed pass vs gzip/zlib baselines."""
    comp = gzip_bytes(data, 6)
    ratio = len(data) / len(comp)

    # single-threaded baselines
    best, _ = timeit(lambda: zlib.decompress(comp, 31), repeats=3, warmup=1)
    emit(f"fig9_{data_name}_zlib_1t", best * 1e6, f"{len(data)/best/1e6:.1f}MB/s")
    best, _ = timeit(lambda: gzip_decompress_sequential(comp), repeats=1, warmup=0)
    emit(f"fig9_{data_name}_custom_sequential", best * 1e6, f"{len(data)/best/1e6:.2f}MB/s")

    idx_bytes = None
    for P in (1, 2, 4):
        n, dt, stats = _run_reader(comp, parallelization=P, chunk_size=256 << 10)
        assert n == len(data)
        f = stats["fetcher"]
        emit(
            f"fig9_{data_name}_rapidgzip_P{P}", dt * 1e6,
            f"{len(data)/dt/1e6:.2f}MB/s ratio={ratio:.2f} nominal={f['nominal_tasks']} "
            f"exact={f['exact_tasks']} fp={f['false_positive_starts']} "
            f"markers={f['chunks_with_markers']}",
        )

    # indexed pass (paper: "with index" curves)
    r = ParallelGzipReader(comp, parallelization=2, chunk_size=256 << 10)
    buf = io.BytesIO()
    r.export_index(buf)
    r.close()
    for P in (1, 2, 4):
        idx = GzipIndex.from_bytes(buf.getvalue())
        n, dt, stats = _run_reader(comp, parallelization=P, chunk_size=256 << 10, index=idx)
        assert n == len(data)
        emit(
            f"fig9_{data_name}_rapidgzip_index_P{P}", dt * 1e6,
            f"{len(data)/dt/1e6:.2f}MB/s zlibdeleg={stats['fetcher']['zlib_delegations']}",
        )


def bench_chunk_size(gen: DataGen) -> None:
    """Fig 12: bandwidth vs chunk size."""
    data = gen.base64(scale(6 << 20, floor=256 << 10))
    comp = gzip_bytes(data, 6)
    for cs_kib in (16, 64, 256, 1024, 4096):
        n, dt, stats = _run_reader(comp, parallelization=4, chunk_size=cs_kib << 10)
        assert n == len(data)
        f = stats["fetcher"]
        emit(
            f"fig12_chunksize_{cs_kib}KiB", dt * 1e6,
            f"{len(data)/dt/1e6:.2f}MB/s tasks={f['nominal_tasks']+f['exact_tasks']}",
        )


def bench_compressors(gen: DataGen) -> None:
    """Table 3: decompression across compressor variants/levels."""
    data = gen.silesia_like(scale(4 << 20, floor=256 << 10))
    for name, fn in sorted(COMPRESSORS.items()):
        comp = fn(data)
        n, dt, stats = _run_reader(comp, parallelization=4, chunk_size=128 << 10)
        assert n == len(data)
        f = stats["fetcher"]
        emit(
            f"table3_{name}", dt * 1e6,
            f"{len(data)/dt/1e6:.2f}MB/s ratio={len(data)/len(comp):.2f} "
            f"nominal={f['nominal_tasks']} zlibdeleg={f['zlib_delegations']}",
        )


def bench_formats(gen: DataGen) -> None:
    """Table 4 analogue: gzip (ours, ours+index, zlib) vs raw memcpy bound."""
    data = gen.silesia_like(scale(4 << 20, floor=256 << 10))
    comp = gzip_bytes(data, 6)
    best, _ = timeit(lambda: zlib.decompress(comp, 31), repeats=3)
    emit("table4_zlib", best * 1e6, f"{len(data)/best/1e6:.1f}MB/s")
    n, dt, _ = _run_reader(comp, parallelization=4, chunk_size=128 << 10)
    emit("table4_rapidgzip", dt * 1e6, f"{len(data)/dt/1e6:.2f}MB/s")
    r = ParallelGzipReader(comp, parallelization=2, chunk_size=128 << 10)
    buf = io.BytesIO(); r.export_index(buf); r.close()
    n, dt, _ = _run_reader(comp, parallelization=4, chunk_size=128 << 10,
                           index=GzipIndex.from_bytes(buf.getvalue()))
    emit("table4_rapidgzip_index", dt * 1e6, f"{len(data)/dt/1e6:.2f}MB/s")
    buf2 = bytearray(len(data))
    best, _ = timeit(lambda: buf2.__setitem__(slice(None), data), repeats=3)
    emit("table4_memcpy_bound", best * 1e6, f"{len(data)/best/1e6:.1f}MB/s")


def bench_amdahl(gen: DataGen) -> None:
    """§2.2/§4.5: sequential fraction = window propagation on the critical
    path; everything else parallelizes. Reported as a speedup bound."""
    from repro.core import BitReader, DeflateChunkDecoder, parse_gzip_header
    from repro.core.markers import propagate_window, replace_markers

    data = gen.silesia_like(scale(4 << 20, floor=512 << 10))
    comp = gzip_bytes(data, 6)
    br = BitReader(comp)
    parse_gzip_header(br)
    dec = DeflateChunkDecoder(comp)
    res = dec.decode_chunk(br.bit_pos, br.bit_pos + (256 << 13), window=None)

    t0 = time.perf_counter()
    dec.decode_chunk(res.start_bit, res.end_bit, window=None)
    t_decode = time.perf_counter() - t0

    t0 = time.perf_counter()
    propagate_window(res.data, b"\0" * 32768)
    t_prop = time.perf_counter() - t0

    t0 = time.perf_counter()
    replace_markers(res.data, b"\0" * 32768)
    t_replace = time.perf_counter() - t0

    seq_frac = t_prop / (t_decode + t_replace)
    bound = 1.0 / max(seq_frac, 1e-9)
    emit("amdahl_sequential_fraction", t_prop * 1e6,
         f"frac={seq_frac:.4f} max_speedup~{bound:.0f}x decode={t_decode*1e3:.0f}ms "
         f"replace={t_replace*1e3:.1f}ms")


def main() -> None:
    gen = DataGen()
    n = scale(4 << 20, floor=256 << 10)
    bench_scaling(gen, "base64", gen.base64(n))
    bench_scaling(gen, "silesia", gen.silesia_like(n))
    bench_scaling(gen, "fastq", gen.fastq_like(n))
    bench_chunk_size(gen)
    bench_compressors(gen)
    bench_formats(gen)
    bench_amdahl(gen)
