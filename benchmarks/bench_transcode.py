"""Transcoded-twin payoff: cold random access on a seek-hostile archive.

A fixed-Huffman/splitless archive (rapidgzip's sequential-only worst case,
paper section 4.8) is probed with cold positional reads — a fresh reader per
read, so every probe pays the full decode-up-to-offset cost — then served
once through an ``ArchiveServer`` whose background transcoder installs a
BGZF twin, and probed cold again through ``resolve_source``. The before/after
p50/p99 pair is the whole feature: the acceptance bar is a >=5x p99 win.

Rows also record what the install itself cost (wall time, output bytes) and
the interactive read latency observed *while* the batch-lane transcode ran —
the fairness claim is that the twin is built for free from the interactive
tenant's point of view.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import ParallelGzipReader
from repro.core.synth import fixed_only_compress
from repro.service import ArchiveServer, IndexStore
from repro.service.transcode import resolve_source

from .common import DataGen, emit, scale

_N_READS = 24
_REQ_SIZE = 8 << 10


def _percentile_us(lats, q):
    return float(np.percentile(np.asarray(lats) * 1e6, q))


def _cold_preads(source, total, chunk, seed, index=None):
    """Fresh reader per read: every probe is a true cold open."""
    rng = np.random.default_rng(seed)
    # Bias offsets toward the back half — that is where a sequential-only
    # archive hurts most and where the twin's exact index pays off.
    lo = total // 2
    offs = rng.integers(lo, max(lo + 1, total - _REQ_SIZE), size=_N_READS)
    lats = []
    for off in offs:
        t0 = time.perf_counter()
        with ParallelGzipReader(
            source, parallelization=4, chunk_size=chunk, index=index
        ) as r:
            r.pread(int(off), _REQ_SIZE)
        lats.append(time.perf_counter() - t0)
    return lats


def main(tmpdir: str) -> None:
    gen = DataGen(0x7817)
    total = scale(4 << 20, floor=256 << 10)
    chunk = scale(256 << 10, floor=32 << 10)
    data = gen.text(total)
    total = len(data)

    path = os.path.join(tmpdir, "hostile.gz")
    with open(path, "wb") as f:
        f.write(fixed_only_compress(data))
    store_dir = os.path.join(tmpdir, "index-store")

    # --- before: the origin is sequential-only for every cold probe -------
    lats = _cold_preads(path, total, chunk, seed=3)
    before_p99 = _percentile_us(lats, 99)
    emit("transcode.origin.cold_pread_p50", _percentile_us(lats, 50))
    emit("transcode.origin.cold_pread_p99", before_p99)

    # --- serve it once; the batch lane builds the twin in the background -
    t_install = time.perf_counter()
    with ArchiveServer(
        index_store=IndexStore(store_dir), chunk_size=chunk, max_workers=4,
        transcode_options={"min_input_bytes": 1, "span_bytes": chunk},
    ) as srv:
        h = srv.open(path)
        srv.size(h)  # finalize the index: triggers the hostility probe
        ident = srv.stat(h).identity
        # Interactive reads while the transcode runs on the batch lane.
        rng = np.random.default_rng(17)
        inter = []
        for off in rng.integers(0, total - _REQ_SIZE, size=_N_READS):
            t0 = time.perf_counter()
            srv.read_range(h, int(off), _REQ_SIZE)
            inter.append(time.perf_counter() - t0)
        state = srv.transcoder.wait(ident, timeout=300)
        if state != "installed":
            raise RuntimeError("transcode did not install: %r" % state)
        job = srv.metrics()["transcode"]["jobs"][ident]
    emit(
        "transcode.install.wall",
        (time.perf_counter() - t_install) * 1e6,
        "bytes_out=%d spans=%d" % (job["bytes_out"], job["spans_done"]),
    )
    emit("transcode.interactive_during.p99", _percentile_us(inter, 99))

    # --- after: cold probes resolve the twin + exact index ----------------
    res = resolve_source(IndexStore(store_dir), path)
    if res.twin is None:
        raise RuntimeError("twin did not resolve after install")
    lats = _cold_preads(res.source, total, chunk, seed=5, index=res.index)
    after_p99 = _percentile_us(lats, 99)
    emit(
        "transcode.twin.cold_pread_p50",
        _percentile_us(lats, 50),
        "twin=%s" % res.twin,
    )
    emit(
        "transcode.twin.cold_pread_p99",
        after_p99,
        "speedup=%.1fx" % (before_p99 / max(after_p99, 1e-9)),
    )


if __name__ == "__main__":
    import tempfile

    main(tempfile.mkdtemp())
