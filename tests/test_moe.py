"""MoE layer unit tests: routing identity, token conservation, capacity
dropping, aux-loss sanity, and gradient flow."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_mesh
from repro.models.layers import gated_mlp, init_tree
from repro.models.moe import moe_defs, moe_layer


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def _params(key, d_model, n_exp, d_ff, n_shared=0):
    defs = moe_defs(0, d_model, n_exp, d_ff, n_shared)
    return init_tree(defs, key)


def test_single_expert_equals_dense(mesh):
    """E=1, top_k=1: the MoE layer must equal its one expert's MLP exactly
    (router weight is 1.0 after top-k renormalization)."""
    key = jax.random.PRNGKey(0)
    D, F = 32, 64
    params = _params(key, D, 1, F)
    x = jax.random.normal(key, (2, 8, D), jnp.float32).astype(jnp.bfloat16)
    y, aux = moe_layer(params, x, mesh=mesh, top_k=1, capacity_factor=8.0)
    dense = {
        "w_gate": params["w_gate"][0],
        "w_up": params["w_up"][0],
        "w_down": params["w_down"][0],
    }
    ref = gated_mlp(dense, x)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2
    )
    assert float(aux) == pytest.approx(1.0, rel=1e-3)  # E * 1 * 1


def test_topk_weights_sum_and_conservation(mesh):
    """Ample capacity: output is a convex combination of expert outputs —
    zero input must give zero output; scaling input scales output of a
    linear-ized layer (gates silu ~ linear near 0)."""
    key = jax.random.PRNGKey(1)
    D, E, F = 16, 8, 32
    params = _params(key, D, E, F)
    x0 = jnp.zeros((1, 4, D), jnp.bfloat16)
    y0, _ = moe_layer(params, x0, mesh=mesh, top_k=2, capacity_factor=8.0)
    assert float(jnp.abs(y0).max()) == 0.0


def test_capacity_dropping(mesh):
    """capacity_factor so small that most tokens drop: output must be finite
    and mostly zeros (dropped tokens pass through as zero residual)."""
    key = jax.random.PRNGKey(2)
    D, E, F = 16, 4, 32
    params = _params(key, D, E, F)
    x = jax.random.normal(key, (1, 64, D), jnp.float32).astype(jnp.bfloat16)
    y_full, _ = moe_layer(params, x, mesh=mesh, top_k=2, capacity_factor=8.0)
    y_tiny, _ = moe_layer(params, x, mesh=mesh, top_k=2, capacity_factor=0.05)
    assert bool(jnp.all(jnp.isfinite(y_tiny.astype(jnp.float32))))
    zeros_tiny = float(jnp.mean((jnp.abs(y_tiny.astype(jnp.float32)).sum(-1) == 0)))
    zeros_full = float(jnp.mean((jnp.abs(y_full.astype(jnp.float32)).sum(-1) == 0)))
    assert zeros_tiny > zeros_full  # dropping visibly occurred


def test_aux_loss_range(mesh):
    key = jax.random.PRNGKey(3)
    D, E, F = 16, 8, 32
    params = _params(key, D, E, F)
    x = jax.random.normal(key, (2, 32, D), jnp.float32).astype(jnp.bfloat16)
    _, aux = moe_layer(params, x, mesh=mesh, top_k=2, capacity_factor=4.0)
    # aux == E * sum(me * ce) >= 1 (perfectly balanced) and bounded by E
    assert 0.9 <= float(aux) <= 8.0


def test_moe_gradients_flow(mesh):
    key = jax.random.PRNGKey(4)
    D, E, F = 16, 4, 32
    params = _params(key, D, E, F)
    x = jax.random.normal(key, (1, 16, D), jnp.float32).astype(jnp.bfloat16)

    def loss(p):
        y, aux = moe_layer(p, x, mesh=mesh, top_k=2, capacity_factor=8.0)
        return jnp.sum(jnp.square(y.astype(jnp.float32))) + 0.01 * aux

    grads = jax.grad(loss)(params)
    gnorms = {k: float(jnp.abs(g).max()) for k, g in grads.items() if hasattr(g, "max")}
    assert gnorms["w_gate"] > 0 and gnorms["w_down"] > 0
    assert np.isfinite(float(loss(params)))


def test_shared_experts_added(mesh):
    key = jax.random.PRNGKey(5)
    D, E, F = 16, 4, 32
    defs = moe_defs(0, D, E, F, n_shared=2)
    params = init_tree(defs, key)
    assert "shared" in params
    assert params["shared"]["w_gate"].shape == (D, 2 * F)
