"""End-to-end system tests: gzip corpus -> training -> checkpoint -> resume,
plus roofline-extraction unit checks (the dry-run's parsing layer)."""

import glob
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.configs import SHAPES, all_configs, get_config, smoke_config
from repro.data import GzipCorpusDataset
from repro.distributed import default_rules
from repro.launch.mesh import make_host_mesh
from repro.launch.roofline import collective_wire_bytes, model_flops, roofline_terms
from repro.launch.train import make_corpus
from repro.models import build_model
from repro.train import AdamWConfig, init_train_state, make_train_step


def test_end_to_end_train_checkpoint_resume(tmp_path):
    """The full deployment loop: corpus -> pipeline -> train -> preempt ->
    restore (model, optimizer AND data position) -> continue -> loss down."""
    corpus = str(tmp_path / "corpus")
    make_corpus(corpus, n_shards=2, shard_bytes=256 << 10)
    shards = sorted(glob.glob(os.path.join(corpus, "*.gz")))

    cfg = smoke_config(get_config("granite-3-2b"))
    model = build_model(cfg)
    mesh = make_host_mesh()
    rules = default_rules(mesh)
    ds = GzipCorpusDataset(shards, seq_len=64, batch_size=4, parallelization=2,
                           chunk_size=64 << 10)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    step_fn, _ = make_train_step(
        model, mesh, rules, AdamWConfig(peak_lr=3e-3, warmup_steps=3, total_steps=40)
    )

    losses = []
    ckpt = str(tmp_path / "ckpt")
    for step in range(10):
        params, opt, m = step_fn(params, opt, ds.next_batch())
        losses.append(float(m["loss"]))
    save_checkpoint(ckpt, 10, {"params": params, "opt": opt, "data": ds.state_dict()})

    # simulate losing the process: fresh states, restore everything
    params2, opt2 = init_train_state(model, jax.random.PRNGKey(123))
    ds2 = GzipCorpusDataset(shards, seq_len=64, batch_size=4, parallelization=2,
                            chunk_size=64 << 10)
    s, state = restore_checkpoint(
        latest_checkpoint(ckpt),
        {"params": params2, "opt": opt2, "data": ds2.state_dict()},
    )
    assert s == 10
    ds2.load_state_dict(state["data"])
    params2, opt2 = state["params"], state["opt"]
    for step in range(10, 20):
        params2, opt2, m = step_fn(params2, opt2, ds2.next_batch())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))
    ds.close(); ds2.close()


# ---------------------------------------------------------------------------
# roofline extraction units
# ---------------------------------------------------------------------------

def test_collective_wire_parser():
    hlo = """
  %ag = bf16[16,4096,5120]{2,1,0} all-gather(%x), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups=[4,8]<=[32], dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %aa = bf16[16,64]{1,0} all-to-all(%v), replica_groups=[2,16]<=[32]
  %done = f32[1024]{0} all-reduce-done(%ar)
"""
    wire = collective_wire_bytes(hlo, default_group=4)
    ag = 16 * 4096 * 5120 * 2
    assert wire["all-gather"] == pytest.approx(ag * 15 / 16)
    assert wire["all-reduce"] == pytest.approx(2 * 4096 * 3 / 4)
    assert wire["reduce-scatter"] == pytest.approx(64 * 4 * 7)
    assert wire["collective-permute"] == pytest.approx(8 * 128 * 2)
    assert wire["all-to-all"] == pytest.approx(16 * 64 * 2 * 15 / 16)
    assert wire["counts"]["all-reduce"] == 1  # -done line not double counted


def test_roofline_terms_math():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    wire = {"total": 50e9 * 0.5}
    t = roofline_terms(cost, wire)
    assert t["t_compute"] == pytest.approx(1.0)
    assert t["t_memory"] == pytest.approx(2.0)
    assert t["t_collective"] == pytest.approx(0.5)
    assert t["dominant"] == "t_memory"
    assert t["roofline_fraction"] == pytest.approx(0.5)


def test_model_flops_semantics():
    cfg = get_config("deepseek-v2-236b")
    train = model_flops(cfg, SHAPES["train_4k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    n_act = cfg.active_param_count()
    assert train == pytest.approx(6 * n_act * 256 * 4096)
    assert decode == pytest.approx(2 * n_act * 128)
    # MoE: active << total
    assert cfg.active_param_count() < 0.15 * cfg.param_count()


def test_dryrun_results_complete():
    """The checked-in sweep covers all 40 cells x 2 meshes with 0 errors."""
    import json

    path = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "results", "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("dry-run results not present")
    d = json.load(open(path))
    single = {k: v for k, v in d.items() if k.endswith("|single")}
    multi = {k: v for k, v in d.items() if k.endswith("|multi")}
    assert len(single) == 40 and len(multi) == 40
    for cells in (single, multi):
        assert sum(1 for c in cells.values() if c["status"] == "ok") == 32
        assert sum(1 for c in cells.values() if c["status"] == "skipped") == 8
        assert not any(c["status"] == "error" for c in cells.values())
    # every ok cell carries memory + cost + roofline terms
    for c in single.values():
        if c["status"] == "ok":
            assert "memory" in c and "cost" in c and "roofline" in c
            assert c["roofline"]["dominant"] in ("t_compute", "t_memory", "t_collective")
