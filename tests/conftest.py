import gzip as _gzip
import sys
import types

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# Optional hypothesis: property tests require it, but the bare container does
# not ship it (see requirements-test.txt). Install a minimal stub so the test
# modules still *collect*; @given-decorated tests are skipped at runtime.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:

    class _AnyStrategy:
        """Catch-all stand-in for hypothesis strategy objects."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    def _stub_given(*_args, **_kwargs):
        def decorate(fn):
            def skipper():
                pytest.skip("hypothesis is not installed (see requirements-test.txt)")

            # No functools.wraps: pytest follows __wrapped__ for signatures
            # and would then demand fixtures named after the strategies.
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper

        return decorate

    def _stub_settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    _stub = types.ModuleType("hypothesis")
    _stub.given = _stub_given
    _stub.settings = _stub_settings
    _stub.assume = lambda *a, **k: True
    _stub.example = _stub_settings
    _stub.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.__getattr__ = lambda name: _AnyStrategy()
    _stub.strategies = _strategies
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies


def pytest_configure(config):
    # "remote" tests are network-free: they talk only to an in-process
    # loopback HTTP server (tests/_range_server.py), so tier-1 stays
    # offline-safe. The marker exists for selection (-m remote) and to
    # document the hermeticity guarantee, not to gate on connectivity.
    config.addinivalue_line(
        "markers",
        "remote: remote-backend tests against the hermetic loopback range "
        "server (no external network access)",
    )
    # Tier-2 concurrency stress: threaded/async consistency tests with
    # internal join timeouts (select with `-m stress`). They also run in the
    # plain tier-1 invocation — the marker exists for targeted selection and
    # for CI lanes that want only the concurrency suite, not to hide tests.
    config.addinivalue_line(
        "markers",
        "stress: tier-2 threaded/async consistency stress tests (bounded by "
        "in-test timeouts; `-m stress` selects just these)",
    )
    # Gateway tests talk HTTP only to an in-process loopback GatewayServer
    # (src/repro/service/gateway/) — hermetic like the `remote` marker, so
    # tier-1 stays offline-safe; `-m gateway` selects just the wire suite.
    config.addinivalue_line(
        "markers",
        "gateway: HTTP gateway tests against an in-process loopback "
        "GatewayServer (no external network access)",
    )
    # Kernel interpret-mode tests (Pallas kernels + the batched device
    # engine) run the kernel bodies in Python — correct but slow. The marker
    # gives them a selection handle: `-m kernels` for the kernel lane,
    # `-m "not kernels"` for a fast CPU-only pass. They still run in plain
    # tier-1; the tier-2 perf gate is
    # `python -m benchmarks.run --smoke --only kernels,codecs` (ROADMAP).
    config.addinivalue_line(
        "markers",
        "kernels: Pallas interpret-mode kernel/engine tests (slow kernel-"
        "body interpretation; `-m kernels` selects just these)",
    )
    # Zstd tests exercise real seekable frames when a library is importable
    # (stdlib compression.zstd on 3.14+, else the optional zstandard extra —
    # see requirements-test.txt) and must skip cleanly on a bare container.
    config.addinivalue_line(
        "markers",
        "zstd: tests needing a zstd library (compression.zstd or zstandard);"
        " auto-skipped when neither is importable",
    )


def pytest_collection_modifyitems(config, items):
    from repro.core.codec import have_zstd

    if have_zstd():
        return
    skip_zstd = pytest.mark.skip(
        reason="no zstd library (compression.zstd needs Python 3.14+; "
        "`pip install zstandard` for older interpreters)"
    )
    for item in items:
        if "zstd" in item.keywords:
            item.add_marker(skip_zstd)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)


class CodecCase:
    """One codec's test surface: its tag and a matching compressor."""

    def __init__(self, tag, compress):
        self.tag = tag
        self.compress = compress

    def __repr__(self):
        return "CodecCase(%s)" % self.tag


def _codec_cases():
    from repro.core.synth import bgzf_compress, gzip_compress, zstd_seekable_compress

    cases = {
        "deflate": CodecCase("deflate", lambda d: gzip_compress(d, 6)),
        "bgzf": CodecCase("bgzf", lambda d: bgzf_compress(d, 6)),
        "zstd": CodecCase("zstd", lambda d: zstd_seekable_compress(d, 3)),
    }
    return cases


@pytest.fixture(
    params=[
        "deflate",
        "bgzf",
        pytest.param("zstd", marks=pytest.mark.zstd),
    ]
)
def codec_case(request):
    """Parametrizes a test over all three codecs (zstd auto-skips when no
    library is importable). Yields a CodecCase: ``.tag`` for assertions and
    ``.compress(data)`` to build a matching archive."""
    return _codec_cases()[request.param]


def make_text(rng, n: int) -> bytes:
    """Compressible text-like data (dynamic blocks, plenty of backrefs)."""
    words = [b"the", b"quick", b"brown", b"fox", b"jumps", b"over", b"lazy",
             b"dog", b"rapidgzip", b"parallel", b"deflate", b"window"]
    idx = rng.integers(0, len(words), size=max(8, n // 4))
    out = b" ".join(words[i] for i in idx)
    return out[:n]


def make_base64(rng, n: int) -> bytes:
    import base64

    raw = rng.integers(0, 256, (n * 3) // 4 + 3, dtype=np.uint8).tobytes()
    return base64.b64encode(raw)[:n]


def make_random(rng, n: int) -> bytes:
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


def gzip_bytes(data: bytes, level: int = 6) -> bytes:
    return _gzip.compress(data, compresslevel=level)
