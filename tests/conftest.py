import gzip as _gzip

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)


def make_text(rng, n: int) -> bytes:
    """Compressible text-like data (dynamic blocks, plenty of backrefs)."""
    words = [b"the", b"quick", b"brown", b"fox", b"jumps", b"over", b"lazy",
             b"dog", b"rapidgzip", b"parallel", b"deflate", b"window"]
    idx = rng.integers(0, len(words), size=max(8, n // 4))
    out = b" ".join(words[i] for i in idx)
    return out[:n]


def make_base64(rng, n: int) -> bytes:
    import base64

    raw = rng.integers(0, 256, (n * 3) // 4 + 3, dtype=np.uint8).tobytes()
    return base64.b64encode(raw)[:n]


def make_random(rng, n: int) -> bytes:
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


def gzip_bytes(data: bytes, level: int = 6) -> bytes:
    return _gzip.compress(data, compresslevel=level)
