"""Per-architecture smoke tests: reduced same-family configs, one forward +
train step on CPU, asserting output shapes and finiteness (assignment (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_configs, input_specs, shape_applicable, smoke_config
from repro.distributed import default_rules
from repro.launch.mesh import make_mesh
from repro.models import ModelContext, build_model

ARCHS = sorted(all_configs())


@pytest.fixture(scope="module")
def ctx():
    mesh = make_mesh((1, 1), ("data", "model"))
    return ModelContext(mesh, default_rules(mesh))


def _batch(cfg, key, B=2, S=48):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(key, (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, ctx):
    cfg = smoke_config(all_configs()[arch])
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = _batch(cfg, key)
    loss, metrics = model.loss(params, batch, ctx)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # one SGD-ish step must also be finite (gradient path exercised)
    grads = jax.grad(lambda p: model.loss(p, batch, ctx)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_shapes(arch, ctx):
    cfg = smoke_config(all_configs()[arch])
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :S]
    logits, caches = model.prefill(params, pre_batch, ctx)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert caches is not None
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_configs_are_exact(arch):
    """The full (non-smoke) configs carry the assigned hyperparameters."""
    cfg = all_configs()[arch]
    expected = {
        "deepseek-v2-236b": (60, 5120, 128, 128, 102400),
        "deepseek-moe-16b": (28, 2048, 16, 16, 102400),
        "internlm2-20b": (48, 6144, 48, 8, 92544),
        "qwen2.5-32b": (64, 5120, 40, 8, 152064),
        "gemma-2b": (18, 2048, 8, 1, 256000),
        "granite-3-2b": (40, 2048, 32, 8, 49155),
        "hymba-1.5b": (32, 1600, 25, 5, 32001),
        "whisper-tiny": (4, 384, 6, 6, 51865),
        "internvl2-76b": (80, 8192, 64, 8, 128256),
        "xlstm-350m": (24, 1024, 4, 4, 50304),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.vocab_size) == expected


def test_applicability_matrix():
    """40 cells: long_500k runs only for sub-quadratic archs."""
    runs = 0
    skips = []
    for arch, cfg in all_configs().items():
        for shape in SHAPES.values():
            ok, reason = shape_applicable(cfg, shape)
            if ok:
                runs += 1
            else:
                skips.append((arch, shape.name))
    assert runs == 32
    assert all(s == "long_500k" for _, s in skips)
    assert {a for a, _ in skips} == {
        "deepseek-v2-236b", "deepseek-moe-16b", "internlm2-20b", "qwen2.5-32b",
        "gemma-2b", "granite-3-2b", "whisper-tiny", "internvl2-76b",
    }


def test_input_specs_cover_all_cells():
    for arch, cfg in all_configs().items():
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            B = shape.global_batch
            if shape.kind == "train":
                assert specs["tokens"].shape == (B, shape.seq_len + 1)
            elif shape.kind == "prefill":
                assert specs["tokens"].shape == (B, shape.seq_len)
            else:
                assert specs["tokens"].shape == (B, 1)
            if cfg.family == "audio" and shape.kind != "decode":
                assert specs["frames"].shape[0] == B
            if cfg.family == "vlm" and shape.kind != "decode":
                assert specs["patches"].shape == (B, cfg.vision_tokens, cfg.d_model)


def test_param_counts_are_plausible():
    """Full-config parameter counts near their nameplates (within 30%)."""
    expectations = {
        "deepseek-v2-236b": 236e9,
        "deepseek-moe-16b": 16e9,
        "internlm2-20b": 20e9,
        "qwen2.5-32b": 32e9,
        "gemma-2b": 2.5e9,
        "granite-3-2b": 2.5e9,
        "hymba-1.5b": 1.5e9,
        "internvl2-76b": 70e9,
        # the assigned dims (d=1024, 24L, pf=2 mLSTM) give ~0.52B; the
        # nameplate of the paper's 350M run used smaller projections.
        "xlstm-350m": 0.42e9,
    }
    for arch, nameplate in expectations.items():
        cfg = all_configs()[arch]
        n = cfg.param_count()
        assert 0.6 * nameplate < n < 1.45 * nameplate, (arch, n, nameplate)
