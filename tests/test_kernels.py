"""Pallas kernel validation: interpret=True vs pure-jnp oracles, with
shape/dtype sweeps per the repo convention."""

import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import crc32_parallel, marker_replace, precode_candidates
from repro.kernels.crc32 import (
    SEG_COLS,
    SEG_ROWS,
    crc32_segments,
    crc32_segments_batched,
    make_crc_table,
)
from repro.kernels.marker_replace import (
    TILE,
    TILE_COLS,
    TILE_ROWS,
    marker_replace_tiles,
    marker_replace_tiles_multi,
)
from repro.kernels.precode_check import BLOCK, HALO, precode_check_blocks
from repro.kernels.ref import (
    crc32_segments_batched_ref,
    crc32_segments_ref,
    make_replacement_table,
    marker_replace_multi_ref,
    marker_replace_ref,
    precode_check_ref,
)
from repro.core.block_finder import scan_dynamic_candidates
from repro.core.markers import replace_markers

from conftest import make_random, make_text

pytestmark = pytest.mark.kernels


# ---------------------------------------------------------------------------
# marker_replace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_tiles", [1, 2, 5])
def test_marker_replace_kernel_vs_ref(rng, n_tiles):
    window = rng.integers(0, 256, 32768, dtype=np.uint8)
    table = jnp.asarray(make_replacement_table(window))
    syms = rng.integers(0, 256 + 32768, (n_tiles, TILE_ROWS, TILE_COLS), dtype=np.int64)
    tiles = jnp.asarray(syms.astype(np.int32))
    out_kernel = marker_replace_tiles(tiles, table, interpret=True)
    out_ref = marker_replace_ref(tiles, table)
    np.testing.assert_array_equal(np.asarray(out_kernel), np.asarray(out_ref))


@pytest.mark.parametrize("n", [0, 1, 1000, TILE, TILE + 17])
def test_marker_replace_op_shapes(rng, n):
    window = rng.integers(0, 256, 32768, dtype=np.uint8).tobytes()
    syms = rng.integers(0, 256 + 32768, n, dtype=np.uint16)
    out = marker_replace(syms, window)
    host = replace_markers(syms, window)
    np.testing.assert_array_equal(out, host)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3000),
    wlen=st.integers(min_value=0, max_value=32768),
)
def test_marker_replace_property(n, wlen):
    rng = np.random.default_rng(n * 7919 + wlen)
    window = rng.integers(0, 256, wlen, dtype=np.uint8).tobytes()
    # markers may only reference the defined (right-aligned) window region
    lo = 256 + (32768 - wlen)
    lits = rng.integers(0, 256, n, dtype=np.uint16)
    marks = rng.integers(lo, 256 + 32768, n, dtype=np.uint16) if wlen else lits
    pick = rng.integers(0, 2, n, dtype=np.uint16)
    syms = np.where(pick == 1, marks, lits).astype(np.uint16)
    np.testing.assert_array_equal(marker_replace(syms, window), replace_markers(syms, window))


@pytest.mark.parametrize("n_tiles,n_tables", [(1, 1), (4, 2), (6, 4)])
def test_marker_replace_multi_kernel_vs_ref(rng, n_tiles, n_tables):
    """Batched multi-window kernel: per-tile table select matches the oracle
    and the single-table kernel applied table by table."""
    tables_np = np.stack([
        make_replacement_table(rng.integers(0, 256, 32768, dtype=np.uint8))
        for _ in range(n_tables)
    ])
    tables = jnp.asarray(tables_np)
    syms = jnp.asarray(
        rng.integers(0, 256 + 32768, (n_tiles, TILE_ROWS, TILE_COLS), dtype=np.int64)
        .astype(np.int32)
    )
    tids_np = rng.integers(0, n_tables, n_tiles, dtype=np.int64).astype(np.int32)
    tids = jnp.asarray(tids_np)
    out = np.asarray(marker_replace_tiles_multi(syms, tables, tids, interpret=True))
    ref = np.asarray(marker_replace_multi_ref(syms, tables, tids))
    np.testing.assert_array_equal(out, ref)
    for t in range(n_tables):
        sel = tids_np == t
        if not sel.any():
            continue
        single = np.asarray(
            marker_replace_tiles(syms[sel], tables[t], interpret=True)
        )
        np.testing.assert_array_equal(out[sel], single)


# ---------------------------------------------------------------------------
# precode_check
# ---------------------------------------------------------------------------

def test_precode_kernel_vs_ref(rng):
    bits = rng.integers(0, 2, (4, BLOCK), dtype=np.int64).astype(np.int32)
    bits = jnp.asarray(np.concatenate([bits, np.zeros((1, BLOCK), np.int32)]))
    out_kernel = np.asarray(precode_check_blocks(bits, interpret=True))
    flat = np.asarray(bits).reshape(-1)
    for blk in range(4):
        seg = jnp.asarray(flat[blk * BLOCK : blk * BLOCK + BLOCK + HALO])
        ref = np.asarray(precode_check_ref(seg))
        np.testing.assert_array_equal(out_kernel[blk][: BLOCK], np.pad(ref, (0, BLOCK - ref.shape[0])))


@pytest.mark.parametrize("nbytes", [1000, 40_000])
def test_precode_candidates_match_host_finder(rng, nbytes):
    blob = make_random(rng, nbytes)
    end = nbytes * 8 - HALO
    kern = set(precode_candidates(blob, 0, end).tolist())
    host = set(
        c for c in scan_dynamic_candidates(blob, 0, nbytes * 8, full_validation=False) if c < end
    )
    assert kern == host


def test_precode_candidates_find_real_blocks(rng):
    import gzip as _gzip

    data = make_text(rng, 300_000)
    comp = _gzip.compress(data, 6)
    from repro.core import BitReader, DeflateChunkDecoder, parse_gzip_header

    br = BitReader(comp)
    parse_gzip_header(br)
    res = DeflateChunkDecoder(comp).decode_chunk(br.bit_pos, len(comp) * 8, window=b"")
    dynamic = [b.bit_offset for b in res.blocks if b.block_type == 2 and not b.is_final]
    cands = set(precode_candidates(comp).tolist())
    assert all(b in cands for b in dynamic)


# ---------------------------------------------------------------------------
# crc32
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seg_len", [1, 7, 64])
def test_crc32_kernel_vs_ref(rng, seg_len):
    data = rng.integers(0, 256, (SEG_ROWS, SEG_COLS, seg_len), dtype=np.int64).astype(np.int32)
    table = make_crc_table()
    out_kernel = np.asarray(crc32_segments(jnp.asarray(data), table, interpret=True))
    out_ref = np.asarray(crc32_segments_ref(jnp.asarray(data), table))
    np.testing.assert_array_equal(out_kernel, out_ref)
    # spot-check lane (0,0) against zlib
    seg = bytes(int(b) for b in data[0, 0])
    assert (int(out_kernel[0, 0]) & 0xFFFFFFFF) == (zlib.crc32(seg) & 0xFFFFFFFF)


@pytest.mark.parametrize("n", [0, 1, 1023, 4096, 100_001])
def test_crc32_parallel_matches_zlib(rng, n):
    blob = make_random(rng, n)
    assert crc32_parallel(blob) == (zlib.crc32(blob) & 0xFFFFFFFF)


@pytest.mark.parametrize("batch,seg_len", [(1, 1), (2, 7), (4, 16)])
def test_crc32_batched_kernel_vs_ref(rng, batch, seg_len):
    data = rng.integers(
        0, 256, (batch, SEG_ROWS, SEG_COLS, seg_len), dtype=np.int64
    ).astype(np.int32)
    table = make_crc_table()
    out = np.asarray(crc32_segments_batched(jnp.asarray(data), table, interpret=True))
    ref = np.asarray(crc32_segments_batched_ref(jnp.asarray(data), table))
    np.testing.assert_array_equal(out, ref)
    # each batch row must equal the unbatched kernel on the same lanes
    for b in range(batch):
        single = np.asarray(crc32_segments(jnp.asarray(data[b]), table, interpret=True))
        np.testing.assert_array_equal(out[b], single)
    # spot-check one lane against zlib
    seg = bytes(int(x) for x in data[-1, 0, 0])
    assert (int(out[-1, 0, 0]) & 0xFFFFFFFF) == (zlib.crc32(seg) & 0xFFFFFFFF)
