"""CachePool / CacheStats.merge / LRUCache.snapshot (service memory budget)."""

import threading

import numpy as np
import pytest

from repro.core.cache import CacheStats, LRUCache
from repro.service.cache_pool import ACCESS, PREFETCH, CachePool, default_size_of


# ---------------------------------------------------------------------------
# CacheStats.merge / LRUCache.snapshot (satellite: metrics aggregation)
# ---------------------------------------------------------------------------

def test_cache_stats_merge_sums_and_does_not_mutate():
    a = CacheStats(hits=3, misses=1, insertions=2, evictions=0)
    b = CacheStats(hits=10, misses=4, insertions=7, evictions=5)
    c = a.merge(b)
    assert c.as_dict() == {"hits": 13, "misses": 5, "insertions": 9, "evictions": 5}
    assert a.hits == 3 and b.hits == 10  # operands untouched
    # dict operands (reader.stats() reports) merge too
    d = c.merge({"hits": 1, "misses": 1, "insertions": 0, "evictions": 0})
    assert d.hits == 14 and d.misses == 6
    assert CacheStats().merge() .as_dict() == CacheStats().as_dict()


def test_lru_snapshot_is_consistent_under_concurrent_traffic():
    cache = LRUCache(64)
    stop = threading.Event()

    def hammer(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            k = int(rng.integers(0, 128))
            if rng.random() < 0.5:
                cache.insert(k, bytes(16))
            else:
                cache.get(k)

    threads = [threading.Thread(target=hammer, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(50):
            snap = cache.snapshot()
            s = snap["stats"]
            # A torn read would show impossible combinations; the atomic
            # snapshot guarantees len <= capacity and non-negative counters.
            assert 0 <= snap["len"] <= snap["capacity"]
            assert s.insertions >= s.evictions
            assert min(s.hits, s.misses, s.insertions, s.evictions) >= 0
    finally:
        stop.set()
        for t in threads:
            t.join()


# ---------------------------------------------------------------------------
# CachePool: budget, tiers, tenants
# ---------------------------------------------------------------------------

def test_pool_enforces_byte_budget_with_global_lru():
    # max_tenant_fraction=1.0 disables soft isolation: pure global LRU
    pool = CachePool(1000, access_fraction=0.5, max_tenant_fraction=1.0)
    c1 = pool.cache(tier=PREFETCH, tenant="a")
    c2 = pool.cache(tier=PREFETCH, tenant="b")
    # prefetch tier budget = 500 bytes; each entry 100 bytes
    for i in range(4):
        c1.insert(("k", i), bytes(100))
    for i in range(4):
        c2.insert(("k", i), bytes(100))
    held = pool.bytes_held(PREFETCH)
    assert held <= 500
    # oldest entries (c1's) were evicted from the pool AND from their cache
    assert ("k", 0) not in c1 and ("k", 1) not in c1
    assert ("k", 3) in c2
    snap = pool.snapshot()
    assert snap["tiers"][PREFETCH]["evictions"] >= 3
    assert snap["tenants"]["a"]["evictions_suffered"] >= 3


def test_pool_tier_isolation_prefetch_cannot_evict_access():
    pool = CachePool(1000, access_fraction=0.3)  # access budget 300
    acc = pool.cache(tier=ACCESS, tenant="t")
    pre = pool.cache(tier=PREFETCH, tenant="t")
    acc.insert("hot", bytes(200))
    for i in range(50):  # massive prefetch churn
        pre.insert(i, bytes(100))
    assert acc.get("hot") is not None  # pollution isolation, fleet-wide
    assert pool.bytes_held(ACCESS) == 200
    assert pool.bytes_held(PREFETCH) <= 700


def test_pool_lru_order_respects_recent_gets():
    pool = CachePool(400, access_fraction=0.25)  # prefetch budget 300
    c = pool.cache(tier=PREFETCH, tenant="t")
    c.insert("a", bytes(100))
    c.insert("b", bytes(100))
    c.insert("c", bytes(100))
    assert c.get("a") is not None  # touch: "a" becomes MRU
    c.insert("d", bytes(100))  # over budget -> evict LRU = "b"
    assert "b" not in c
    assert c.get("a") is not None and c.get("d") is not None


def test_pool_soft_tenant_isolation_hog_evicts_itself_first():
    pool = CachePool(1000, access_fraction=0.2, max_tenant_fraction=0.5)
    hog = pool.cache(tier=PREFETCH, tenant="hog")
    small = pool.cache(tier=PREFETCH, tenant="small")
    small.insert("s", bytes(100))
    for i in range(20):
        hog.insert(i, bytes(100))
    # The hog is over its 50% share: its own LRU entries go first, the small
    # tenant's single entry survives.
    assert small.get("s") is not None
    stats = pool.tenant_stats()
    assert stats["hog"]["evictions_suffered"] > 0
    assert stats["small"]["evictions_suffered"] == 0


def test_pool_replacement_and_pop_update_accounting():
    pool = CachePool(10_000)
    c = pool.cache(tier=PREFETCH, tenant="t")
    c.insert("k", bytes(1000))
    assert pool.bytes_held(PREFETCH) == 1000
    c.insert("k", bytes(200))  # replace: decharge 1000, charge 200
    assert pool.bytes_held(PREFETCH) == 200
    assert c.pop("k") is not None
    assert pool.bytes_held(PREFETCH) == 0
    c.insert("x", bytes(300))
    c.clear()
    assert pool.bytes_held(PREFETCH) == 0
    assert len(c) == 0


def test_pool_entry_capacity_still_applies():
    """Per-cache entry caps survive pooling (access cache size semantics)."""
    pool = CachePool(1 << 20)
    c = pool.cache(tier=ACCESS, tenant="t", capacity=2)
    c.insert("a", bytes(10))
    c.insert("b", bytes(10))
    c.insert("c", bytes(10))
    assert len(c) == 2 and "a" not in c
    assert pool.bytes_held(ACCESS) == 20  # evicted entry was decharged


def test_cost_aware_eviction_prefers_cheap_to_recompute_victims():
    """At similar recency, a zlib-delegable ('ix') entry goes before an older
    marker-mode ('fp') entry that costs far more to recompute."""
    pool = CachePool(1000, access_fraction=0.5)  # prefetch budget 500
    c = pool.cache(tier=PREFETCH, tenant="t")
    # Oldest entry is expensive (marker-mode: 3x recompute), newer one cheap.
    c.insert_hinted(("fp", 1), bytes(200), recompute_cost=600)
    c.insert_hinted(("ix", 1), bytes(200), recompute_cost=200)
    c.insert_hinted(("ix", 2), bytes(200), recompute_cost=200)  # overflows
    assert ("fp", 1) in c, "expensive marker-mode entry was evicted first"
    assert ("ix", 1) not in c, "cheap zlib-delegable entry should be the victim"
    assert ("ix", 2) in c
    snap = pool.snapshot()
    assert snap["tiers"][PREFETCH]["evicted_cost"] == 200
    assert snap["tiers"][PREFETCH]["evicted_bytes"] == 200
    t = snap["tenants"]["t"]
    assert t["eviction_cost_suffered"] == 200
    assert t["eviction_cost_caused"] == 200


def test_cost_aware_eviction_ages_out_cold_expensive_entries():
    """Cost bias is bounded: an expensive entry passed over for a full
    window of younger victims without being re-accessed is evicted anyway —
    cold marker-mode chunks are not immortal."""
    from repro.service.cache_pool import EVICTION_WINDOW

    pool = CachePool(10_000, access_fraction=0.5)  # prefetch budget 5000
    c = pool.cache(tier=PREFETCH, tenant="t")
    c.insert_hinted(("fp", 0), bytes(400), recompute_cost=1600)  # oldest, pricey
    # 24 cheap entries behind it -> 13 evictions; the fp entry survives the
    # first EVICTION_WINDOW of them on cost, then ages out.
    for i in range(24):
        c.insert_hinted(("ix", i), bytes(400), recompute_cost=400)
    assert ("fp", 0) not in c
    assert ("ix", 23) in c
    assert pool.bytes_held(PREFETCH) <= 5000
    # ...but a *re-accessed* expensive entry stays: the lookup resets aging.
    pool2 = CachePool(10_000, access_fraction=0.5)
    c2 = pool2.cache(tier=PREFETCH, tenant="t")
    c2.insert_hinted(("fp", 0), bytes(400), recompute_cost=1600)
    for i in range(24):
        assert c2.get(("fp", 0)) is not None  # hot entry, touched constantly
        c2.insert_hinted(("ix", i), bytes(400), recompute_cost=400)
    assert ("fp", 0) in c2


def test_unhinted_inserts_degrade_to_plain_lru():
    pool = CachePool(400, access_fraction=0.25)  # prefetch budget 300
    c = pool.cache(tier=PREFETCH, tenant="t")
    c.insert("a", bytes(100))
    c.insert("b", bytes(100))
    c.insert("c", bytes(100))
    c.insert("d", bytes(100))
    assert "a" not in c and "b" in c and "c" in c and "d" in c


def test_weighted_tenant_shares_shrink_and_grow_soft_isolation():
    # Base share is 50%; the demoted tenant's weight 0.4 caps it at 20%.
    pool = CachePool(1000, access_fraction=0.2, max_tenant_fraction=0.5)
    pool.set_tenant_weight("demoted", 0.4)
    demoted = pool.cache(tier=PREFETCH, tenant="demoted")
    small = pool.cache(tier=PREFETCH, tenant="small")
    small.insert("s", bytes(100))
    for i in range(20):
        demoted.insert(i, bytes(100))
    # Over budget with the demoted tenant over its weighted cap: it sheds its
    # own entries, the small tenant's entry survives.
    assert small.get("s") is not None
    stats = pool.tenant_stats()
    assert stats["demoted"]["evictions_suffered"] > 0
    assert stats["small"]["evictions_suffered"] == 0
    snap = pool.snapshot()
    assert snap["tenant_weights"] == {"demoted": 0.4}
    with pytest.raises(ValueError):
        pool.set_tenant_weight("x", 0)


def test_pooled_cache_lookup_respects_record_miss_flag():
    pool = CachePool(10_000)
    c = pool.cache(tier=ACCESS, tenant="t")
    assert c.lookup("nope", record_miss=False) is None
    assert pool.tenant_stats()["t"]["misses"] == 0
    assert c.snapshot()["stats"].misses == 0
    assert c.lookup("nope") is None
    assert pool.tenant_stats()["t"]["misses"] == 1
    c.insert("k", b"v")
    assert c.lookup("k", record_miss=False) == b"v"
    assert pool.tenant_stats()["t"]["hits"] == 1


def test_pool_rejects_bad_config():
    with pytest.raises(ValueError):
        CachePool(0)
    with pytest.raises(ValueError):
        CachePool(100, access_fraction=1.5)
    with pytest.raises(ValueError):
        CachePool(100).cache(tier="bogus")


def test_default_size_of_understands_cached_value_shapes():
    assert default_size_of(np.zeros(100, np.uint8)) == 100
    assert default_size_of(b"12345") == 5

    class FakeDecodeResult:
        data = np.zeros(50, np.uint16)

    assert default_size_of(FakeDecodeResult()) == 100 + 256
    assert default_size_of(object()) == 1024


def test_pool_concurrent_inserts_keep_ledger_consistent():
    pool = CachePool(50_000, access_fraction=0.5)
    caches = [pool.cache(tier=PREFETCH, tenant=f"t{i}") for i in range(4)]

    def worker(c, seed):
        rng = np.random.default_rng(seed)
        for _ in range(300):
            k = int(rng.integers(0, 64))
            if rng.random() < 0.7:
                c.insert(k, bytes(int(rng.integers(1, 500))))
            elif rng.random() < 0.5:
                c.get(k)
            else:
                c.pop(k)

    threads = [threading.Thread(target=worker, args=(c, i)) for i, c in enumerate(caches)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # Ledger agrees with reality: held == sum of live entry sizes; within
    # budget (steady state, no in-flight inserts).
    snap = pool.snapshot()
    assert pool.bytes_held(PREFETCH) <= snap["tiers"][PREFETCH]["budget"]
    total = sum(s["bytes_held"] for s in snap["tenants"].values())
    assert total == pool.bytes_held()
    assert total >= 0


def test_release_deregisters_and_returns_budget():
    pool = CachePool(10_000)
    c1 = pool.cache(tier=PREFETCH, tenant="t")
    c2 = pool.cache(tier=PREFETCH, tenant="t")
    c1.insert("a", bytes(1000))
    c2.insert("b", bytes(500))
    assert pool.snapshot()["n_caches"] == 2
    c1.release()
    assert pool.bytes_held(PREFETCH) == 500
    assert pool.snapshot()["n_caches"] == 1
    assert pool.tenant_stats()["t"]["bytes_held"] == 500
    c1.release()  # idempotent


def test_reader_close_releases_pooled_caches(rng):
    """A closed reader must not pin pool budget or registry entries
    (long-running services open/close readers constantly)."""
    import gzip as _gz

    from repro.core import ParallelGzipReader

    pool = CachePool(8 << 20)
    data = bytes(make_text := b"hello rapidgzip " * 20_000)
    comp = _gz.compress(data, 6)
    for _ in range(3):
        acc, pre = pool.reader_caches("svc")
        r = ParallelGzipReader(comp, parallelization=2, chunk_size=64 << 10,
                               access_cache=acc, prefetch_cache=pre)
        assert r.read() == data
        assert pool.bytes_held() > 0
        r.close()
        assert pool.bytes_held() == 0
        assert pool.snapshot()["n_caches"] == 0


def test_insert_after_release_does_not_recharge_pool():
    """A decompression task finishing after its reader closed (reads no
    longer hold the entry lock, so this race is real) must not re-charge
    the ledger of a deregistered cache — those bytes would never be
    decharged and the budget would shrink forever."""
    pool = CachePool(1 << 20)
    c = pool.cache(tier=PREFETCH, tenant="t")
    c.insert("a", bytes(1000))
    assert pool.bytes_held(PREFETCH) == 1000
    c.release()
    assert pool.bytes_held(PREFETCH) == 0
    c.insert("late", bytes(4000))  # racing task lands after release
    c.insert_hinted("late2", bytes(4000), recompute_cost=8000)
    assert pool.bytes_held(PREFETCH) == 0
    assert pool.snapshot()["n_caches"] == 0
    assert pool.tenant_stats()["t"]["bytes_held"] == 0
