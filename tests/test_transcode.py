"""Transcoded-twin read path: hostility probe, twin store, background
re-encoding, atomic install, and the server's source-resolution seam.

The scenario under test is the paper's §4.8 worst case made durable: a
fixed-Huffman (splitless) archive degrades every cold open to a sequential
scan, so the service pays one sequential pass, re-encodes a BGZF twin in
the background, and every later open resolves to the twin — same identity,
bit-identical bytes, zero speculative work.
"""

import json
import os
import threading

import numpy as np
import pytest

from conftest import gzip_bytes, make_text
from repro.core.index import GzipIndex
from repro.core.reader import ParallelGzipReader
from repro.core.synth import bgzf_compress, fixed_only_compress
from repro.service.index_store import IndexStore, file_identity
from repro.service.scheduler import FairExecutor
from repro.service.server import ArchiveServer
from repro.service.transcode import TranscodeManager, resolve_source

TEXT = make_text(np.random.default_rng(0x7E57), 200_000)


def _hostility(comp: bytes, **kw) -> float:
    kw.setdefault("parallelization", 2)
    kw.setdefault("chunk_size", 32 << 10)
    with ParallelGzipReader(comp, **kw) as r:
        r.build_full_index()
        return r.seek_hostility()


def _hostile_file(tmp_path, name="hostile.gz"):
    p = tmp_path / name
    p.write_bytes(fixed_only_compress(TEXT))
    return str(p)


# ---------------------------------------------------------------------------
# hostility scoring
# ---------------------------------------------------------------------------

def test_fixed_only_archive_probes_maximally_hostile():
    assert _hostility(fixed_only_compress(TEXT)) == 1.0


def test_ordinary_gzip_probes_friendly():
    assert _hostility(gzip_bytes(TEXT, 6)) < 0.7


def test_bgzf_probes_zero():
    assert _hostility(bgzf_compress(TEXT)) == 0.0


def test_imported_index_probes_zero():
    """A warm (imported) index carries no first-pass observations — scoring
    it would condemn archives the importer never even decoded here."""
    comp = fixed_only_compress(TEXT)
    with ParallelGzipReader(comp, chunk_size=32 << 10) as r:
        r.build_full_index()
        blob = r.index.to_bytes()
    with ParallelGzipReader(comp, index=blob) as warm:
        assert warm.seek_hostility() == 0.0


# ---------------------------------------------------------------------------
# IndexStore twin slots: registration, resolution, torn installs
# ---------------------------------------------------------------------------

def _twin_fixture():
    """(origin bytes, twin bytes, twin's finalized index)."""
    origin = fixed_only_compress(TEXT)
    twin = bgzf_compress(TEXT)
    with ParallelGzipReader(twin, codec="bgzf", parallelization=1) as r:
        assert r.index.finalized
        index = r.index
    return origin, twin, index


def test_memory_store_twin_roundtrip():
    origin, twin, index = _twin_fixture()
    store = IndexStore()
    key = file_identity(origin)
    assert store.resolve_twin(key) is None
    assert store.register_twin(key, codec_tag="bgzf", data=twin, index=index)
    rec = store.resolve_twin(key)
    assert rec is not None
    assert rec.codec_tag == "bgzf"
    assert rec.source == twin
    assert rec.meta["bytes_out"] == len(twin)
    assert GzipIndex.from_bytes(rec.index_blob).finalized
    assert store.stats.twin_installs == 1 and store.stats.twin_hits == 1
    store.drop_twin(key)
    assert store.resolve_twin(key) is None


def test_register_twin_refuses_unfinalized_index():
    origin, twin, _ = _twin_fixture()
    store = IndexStore()
    assert (
        store.register_twin(
            file_identity(origin), codec_tag="bgzf", data=twin, index=GzipIndex()
        )
        is None
    )
    assert store.stats.twin_rejected == 1


def test_disk_store_twin_roundtrip_and_torn_installs(tmp_path):
    origin, twin, index = _twin_fixture()
    store = IndexStore(str(tmp_path / "s"))
    key = file_identity(origin)
    tmp = store.twin_tmp_path(key)
    with open(tmp, "wb") as f:
        f.write(twin)
    assert store.register_twin(key, codec_tag="bgzf", data=tmp, index=index)
    assert not os.path.exists(tmp)  # renamed into place, not copied

    rec = store.resolve_twin(key)
    assert rec is not None and rec.codec_tag == "bgzf"
    with open(rec.source, "rb") as f:
        assert f.read() == twin

    data_path = os.path.join(store.root, key + ".twin")
    idx_path = os.path.join(store.root, key + ".twinidx")
    meta_path = os.path.join(store.root, key + ".twinmeta")
    assert os.path.exists(data_path) and os.path.exists(idx_path)

    # meta is the commit point: without it the twin does not exist.
    with open(meta_path, "rb") as f:
        meta_blob = f.read()
    os.unlink(meta_path)
    assert store.resolve_twin(key) is None
    with open(meta_path, "wb") as f:
        f.write(meta_blob)
    assert store.resolve_twin(key) is not None

    # corrupt meta: unparseable JSON never resolves.
    with open(meta_path, "wb") as f:
        f.write(b"{half a record")
    assert store.resolve_twin(key) is None
    with open(meta_path, "wb") as f:
        f.write(meta_blob)

    # torn data (crash mid-write): size mismatch never resolves.
    with open(data_path, "wb") as f:
        f.write(twin[: len(twin) // 2])
    assert store.resolve_twin(key) is None
    with open(data_path, "wb") as f:
        f.write(twin)
    assert store.resolve_twin(key) is not None

    # codec mismatch between meta and index blob never resolves.
    meta = json.loads(meta_blob)
    meta["codec"] = "zstd"
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    assert store.resolve_twin(key) is None
    with open(meta_path, "wb") as f:
        f.write(meta_blob)

    store.drop_twin(key)
    assert store.resolve_twin(key) is None
    assert not os.path.exists(data_path)


def test_store_clear_removes_twins(tmp_path):
    origin, twin, index = _twin_fixture()
    store = IndexStore(str(tmp_path / "s"))
    key = file_identity(origin)
    tmp = store.twin_tmp_path(key)
    with open(tmp, "wb") as f:
        f.write(twin)
    store.register_twin(key, codec_tag="bgzf", data=tmp, index=index)
    store.clear()
    assert store.resolve_twin(key) is None
    assert os.listdir(store.root) == []


# ---------------------------------------------------------------------------
# resolve_source
# ---------------------------------------------------------------------------

def test_resolve_source_without_store_and_with_warm_index():
    comp = gzip_bytes(TEXT, 6)
    bare = resolve_source(None, comp)
    assert bare.source is comp and bare.index is None and bare.twin is None
    assert bare.identity == file_identity(comp)

    store = IndexStore()
    cold = resolve_source(store, comp)
    assert not cold.index_was_warm
    with ParallelGzipReader(comp, chunk_size=32 << 10) as r:
        r.build_full_index()
        store.put(cold.identity, r.index)
    warm = resolve_source(store, comp)
    assert warm.index_was_warm and warm.index is not None
    assert warm.twin is None and warm.source is comp


def test_resolve_source_binds_twin_and_survives_corrupt_twin_index():
    origin, twin, index = _twin_fixture()
    store = IndexStore()
    key = file_identity(origin)
    store.register_twin(key, codec_tag="bgzf", data=twin, index=index)

    res = resolve_source(store, origin)
    assert res.twin == "bgzf" and res.codec == "bgzf"
    assert res.source == twin and res.index.finalized
    assert res.identity == key  # identity stays the ORIGIN's key

    # A twin whose index blob no longer parses must never win resolution:
    # the origin stays servable.
    store._twins[key].index_blob = b"not an index"
    res = resolve_source(store, origin)
    assert res.twin is None and res.source is origin


# ---------------------------------------------------------------------------
# TranscodeManager: background job, fault injection, atomicity
# ---------------------------------------------------------------------------

def _probe_reader(path):
    r = ParallelGzipReader(path, parallelization=1, chunk_size=32 << 10)
    r.build_full_index()
    return r


def test_manager_transcodes_hostile_file_and_skips_friendly(tmp_path):
    path = _hostile_file(tmp_path)
    store = IndexStore(str(tmp_path / "s"))
    ex = FairExecutor(2)
    try:
        mgr = TranscodeManager(store, ex, span_bytes=1 << 16, min_input_bytes=1)
        ident = file_identity(path)
        with _probe_reader(path) as r:
            assert mgr.consider(ident, path, r)
            assert not mgr.consider(ident, path, r)  # dedup: job exists
        assert mgr.wait(ident, timeout=60) == "installed"
        rec = store.resolve_twin(ident)
        assert rec is not None and rec.codec_tag == "bgzf"
        with ParallelGzipReader(rec.source, codec="bgzf") as tw:
            assert tw.pread(0, len(TEXT) + 1) == TEXT
        # origin's own index was persisted under the origin key too
        origin_idx = store.get(ident)
        assert origin_idx is not None
        assert origin_idx.compressed_size == os.path.getsize(path)

        # a friendly archive is considered but never scheduled
        friendly = tmp_path / "friendly.gz"
        friendly.write_bytes(gzip_bytes(TEXT, 6))
        with _probe_reader(str(friendly)) as r:
            assert not mgr.consider(file_identity(str(friendly)), str(friendly), r)
        snap = mgr.snapshot()
        assert snap["counters"]["scheduled"] == 1
        job = snap["jobs"][ident]
        assert job["state"] == "installed"
        assert job["speedup"] >= 2  # several seek points vs one
        assert job["bytes_out"] > 0
    finally:
        ex.shutdown(wait=True)


def test_open_filereader_source_is_skipped_not_raced(tmp_path):
    """An already-open FileReader can't be re-opened by value — the job
    would race the handle's close. It must be skipped, with a counter."""
    path = _hostile_file(tmp_path)
    store = IndexStore()
    ex = FairExecutor(1)
    try:
        mgr = TranscodeManager(store, ex, min_input_bytes=1)
        from repro.core.filereader import SharedFileReader

        src = SharedFileReader(path)
        with ParallelGzipReader(src, parallelization=1, chunk_size=32 << 10) as r:
            r.build_full_index()
            assert not mgr.consider(file_identity(path), src, r)
        assert mgr.snapshot()["counters"]["skipped_unresolvable"] == 1
    finally:
        ex.shutdown(wait=True)


@pytest.mark.parametrize("stage", ["open", "span", "finish", "validate", "install"])
def test_fault_injection_never_installs_a_half_twin(tmp_path, stage):
    """Kill the transcoder at every lifecycle stage: the job fails, no
    half-written twin is ever resolvable, tmp files are cleaned up, and the
    origin keeps serving bit-identical bytes."""
    path = _hostile_file(tmp_path)
    store = IndexStore(str(tmp_path / "s"))
    ex = FairExecutor(2)
    try:
        def hook(s):
            if s == stage:
                raise RuntimeError("injected crash at %s" % s)

        mgr = TranscodeManager(
            store, ex, span_bytes=1 << 16, min_input_bytes=1, fault_hook=hook
        )
        ident = file_identity(path)
        with _probe_reader(path) as r:
            assert mgr.consider(ident, path, r)
        assert mgr.wait(ident, timeout=60) == "failed"
        job = mgr.snapshot()["jobs"][ident]
        assert "injected crash" in job["error"]

        assert store.resolve_twin(ident) is None
        stray = [
            f for f in os.listdir(store.root)
            if f.endswith((".twin", ".twinidx", ".twinmeta", ".tmp"))
        ]
        assert stray == [], stray

        with ArchiveServer(index_store=store, transcode="off",
                           max_workers=2) as srv:
            h = srv.open(path)
            assert srv.read_range(h, 0, len(TEXT)) == TEXT
            assert srv.stat(h).twin is None
    finally:
        ex.shutdown(wait=True)


def test_fault_after_data_rename_is_still_unresolvable(tmp_path):
    """Crash *between* the twin-data rename and the meta write (simulated
    by deleting idx+meta after a full install): data alone never resolves —
    meta is the commit point."""
    path = _hostile_file(tmp_path)
    store = IndexStore(str(tmp_path / "s"))
    ex = FairExecutor(2)
    try:
        mgr = TranscodeManager(store, ex, min_input_bytes=1)
        ident = file_identity(path)
        with _probe_reader(path) as r:
            mgr.consider(ident, path, r)
        assert mgr.wait(ident, timeout=60) == "installed"
        os.unlink(os.path.join(store.root, ident + ".twinidx"))
        os.unlink(os.path.join(store.root, ident + ".twinmeta"))
        assert store.resolve_twin(ident) is None
        res = resolve_source(store, path)
        assert res.twin is None and res.source == path
    finally:
        ex.shutdown(wait=True)


# ---------------------------------------------------------------------------
# ArchiveServer end to end: hostile open -> background twin -> resolved reopen
# ---------------------------------------------------------------------------

def test_server_end_to_end_twin_lifecycle(tmp_path):
    path = _hostile_file(tmp_path)
    store_dir = str(tmp_path / "store")

    # Pass 1: cold open pays the sequential first pass; the transcoder
    # installs a twin in the background.
    with ArchiveServer(
        index_store=IndexStore(store_dir), chunk_size=32 << 10, max_workers=4,
        transcode_options={"min_input_bytes": 1, "span_bytes": 1 << 16},
    ) as srv:
        h = srv.open(path)
        assert srv.read_range(h, 0, len(TEXT)) == TEXT
        st = srv.stat(h)
        assert st.twin is None and st.codec == "deflate"
        assert srv.transcoder.wait(st.identity, timeout=60) == "installed"
        tsnap = srv.metrics()["transcode"]
        assert tsnap["counters"]["installed"] == 1
        assert tsnap["jobs"][st.identity]["speedup"] >= 2
        identity = st.identity

    # Pass 2: cold reopen resolves the twin — same identity, bgzf serving
    # codec, warm exact index (zero speculative tasks), bit-identical bytes.
    with ArchiveServer(
        index_store=IndexStore(store_dir), chunk_size=32 << 10, max_workers=4,
    ) as srv:
        h = srv.open(path)
        assert srv.read_range(h, 0, len(TEXT)) == TEXT
        assert srv.read_range(h, 12_345, 4096) == TEXT[12_345 : 12_345 + 4096]
        st = srv.stat(h)
        assert st.twin == "bgzf" and st.codec == "bgzf"
        assert st.identity == identity  # ETag semantics preserved
        assert st.index_was_warm
        m = srv.metrics()
        assert m["fleet"]["fetcher"]["nominal_tasks"] == 0
        assert m["per_file"][h]["twin"] == "bgzf"

        # the index-exchange endpoint must serve the ORIGIN's blob: a peer
        # asking for this identity holds the origin's bytes.
        key, blob = srv.index_blob(h)
        assert key == identity
        assert GzipIndex.from_bytes(blob).compressed_size == os.path.getsize(path)

        # closing the twin-bound handle must not overwrite the origin's
        # index slot with the twin's layout...
        srv.close(h, persist_index=True)

    with ArchiveServer(
        index_store=IndexStore(store_dir), chunk_size=32 << 10, transcode="off",
    ) as srv:
        # ...so a later origin-keyed lookup still describes the origin.
        idx = srv.index_store.get(identity)
        assert idx is not None
        assert idx.compressed_size == os.path.getsize(path)


def test_server_concurrent_reads_while_transcoding(tmp_path):
    """Interactive reads keep flowing (and stay byte-exact) while the
    batch-lane transcode of the same archive runs."""
    path = _hostile_file(tmp_path)
    with ArchiveServer(
        index_store=IndexStore(str(tmp_path / "s")), chunk_size=32 << 10,
        max_workers=2,
        transcode_options={"min_input_bytes": 1, "span_bytes": 1 << 16},
    ) as srv:
        h = srv.open(path)
        srv.size(h)  # finalize: triggers the hostility probe
        errors = []

        def hammer(seed):
            rng = np.random.default_rng(seed)
            try:
                for _ in range(20):
                    off = int(rng.integers(0, len(TEXT)))
                    n = int(rng.integers(1, 8192))
                    if srv.read_range(h, off, n) != TEXT[off : off + n]:
                        raise AssertionError("bytes diverged at %d+%d" % (off, n))
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not errors, errors[0]
        ident = srv.stat(h).identity
        assert srv.transcoder.wait(ident, timeout=60) == "installed"
