"""Gzip-corpus data pipeline: packing, sharding, checkpoint/resume."""

import gzip as _gzip

import numpy as np
import pytest

from repro.data import BOS, ByteTokenizer, EOS, GzipCorpusDataset

from conftest import make_text


def _shards(rng, n_shards=2, size=120_000):
    shards = []
    for i in range(n_shards):
        data = make_text(rng, size)
        shards.append(_gzip.compress(data, 6))
    return shards


def test_batch_shapes_and_determinism(rng):
    shards = _shards(rng)
    ds = GzipCorpusDataset(shards, seq_len=128, batch_size=4, parallelization=2,
                           chunk_size=32 * 1024, loop=True)
    b1 = ds.next_batch()
    assert b1["tokens"].shape == (4, 129)
    assert b1["tokens"].dtype == np.int32
    ds.close()

    ds2 = GzipCorpusDataset(shards, seq_len=128, batch_size=4, parallelization=2,
                            chunk_size=32 * 1024, loop=True)
    b2 = ds2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    ds2.close()


def test_tokens_reproduce_corpus(rng):
    shards = _shards(rng, n_shards=1, size=50_000)
    truth = _gzip.decompress(shards[0])
    ds = GzipCorpusDataset(shards, seq_len=64, batch_size=2, parallelization=1,
                           chunk_size=32 * 1024, loop=False)
    tok = ByteTokenizer()
    stream = []
    for batch in ds:
        stream.extend(batch["tokens"].reshape(-1).tolist())
    ds.close()
    decoded = tok.decode(stream)
    assert decoded.startswith(truth[:1000])
    # full corpus covered (padding tail allowed)
    assert truth in decoded + truth[-10:] or decoded[: len(truth)] == truth


def test_sharded_pipelines_are_disjoint(rng):
    shards = _shards(rng, n_shards=4, size=30_000)
    a = GzipCorpusDataset(shards, seq_len=64, batch_size=2, shard_id=0, num_shards=2, loop=False)
    b = GzipCorpusDataset(shards, seq_len=64, batch_size=2, shard_id=1, num_shards=2, loop=False)
    ta = a.next_batch()["tokens"]
    tb = b.next_batch()["tokens"]
    assert not np.array_equal(ta, tb)
    a.close(); b.close()


def test_checkpoint_resume_exact(rng):
    shards = _shards(rng, n_shards=1, size=200_000)
    kw = dict(seq_len=96, batch_size=2, parallelization=2, chunk_size=32 * 1024, loop=True)
    ds = GzipCorpusDataset(shards, **kw)
    for _ in range(5):
        ds.next_batch()
    state = ds.state_dict()
    expected = [ds.next_batch()["tokens"] for _ in range(3)]
    ds.close()

    ds2 = GzipCorpusDataset(shards, **kw)
    ds2.load_state_dict(state)
    got = [ds2.next_batch()["tokens"] for _ in range(3)]
    ds2.close()
    for e, g in zip(expected, got):
        np.testing.assert_array_equal(e, g)


def test_index_reuse_accelerates_restart(rng):
    """Exported seek indexes make the restore O(1) (the paper tie-in)."""
    from repro.core import GzipIndex

    shards = _shards(rng, n_shards=1, size=150_000)
    ds = GzipCorpusDataset(shards, seq_len=64, batch_size=2, loop=True)
    for _ in range(3):
        ds.next_batch()
    idx_bytes = ds.export_indexes()
    st = ds.state_dict()
    ds.close()
    assert 0 in idx_bytes

    indexes = {k: GzipIndex.from_bytes(v) for k, v in idx_bytes.items()}
    ds2 = GzipCorpusDataset(shards, seq_len=64, batch_size=2, loop=True, indexes=indexes)
    ds2.load_state_dict(st)
    b = ds2.next_batch()
    assert b is not None
    # indexed shard: the reader must be in pure zlib-delegation mode
    st2 = ds2._reader.stats()["fetcher"]
    assert st2["nominal_tasks"] == 0
    ds2.close()


def test_pipeline_draws_from_shared_service_pool(rng, tmp_path):
    """Pipelines wired into the service layer share one cache budget, one
    executor, and persist shard indexes for warm restarts."""
    from repro.service import CachePool, FairExecutor, IndexStore

    shards = _shards(rng, n_shards=2, size=150_000)
    pool = CachePool(4 << 20)
    executor = FairExecutor(3)
    store = IndexStore(str(tmp_path / "indexes"))

    ds = GzipCorpusDataset(shards, seq_len=64, batch_size=2, parallelization=2,
                           chunk_size=32 * 1024, loop=True,
                           cache_pool=pool, executor=executor, index_store=store,
                           tenant="train")
    ref = GzipCorpusDataset(shards, seq_len=64, batch_size=2, parallelization=2,
                            chunk_size=32 * 1024, loop=True)
    for _ in range(3):
        a, b = ds.next_batch(), ref.next_batch()
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # traffic flowed through the shared resources
    assert executor.snapshot()["done"] > 0
    assert pool.snapshot()["tenants"]["train"]["insertions"] > 0
    # walk far enough to finish shard 0 -> its index persists on rotation
    while ds.state.shard_idx == 0:
        ds.next_batch()
    ds.close()
    ref.close()
    assert len(store.keys()) >= 1

    # warm restart: shard 0 reopens with a stored index (no speculative pass)
    ds2 = GzipCorpusDataset(shards, seq_len=64, batch_size=2, parallelization=2,
                            chunk_size=32 * 1024, loop=True,
                            cache_pool=pool, executor=executor, index_store=store,
                            tenant="train-restart")
    ds2.next_batch()
    st = ds2._reader.stats()["fetcher"]
    assert st["nominal_tasks"] == 0 and st["exact_tasks"] == 0
    ds2.close()
    executor.shutdown(wait=False)
