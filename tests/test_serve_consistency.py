"""Decode-path correctness: prefill + token-by-token decode must reproduce
the logits of a single full forward pass (per architecture family)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.distributed import default_rules
from repro.launch.mesh import make_mesh
from repro.models import ModelContext, build_model
from repro.serve import prefill_to_decode_caches


@pytest.fixture(scope="module")
def ctx():
    mesh = make_mesh((1, 1), ("data", "model"))
    return ModelContext(mesh, default_rules(mesh))


def _full_logits(model, cfg, params, tokens, ctx, batch_extra):
    """Teacher-forced logits for every position via the train-mode forward."""
    if cfg.family == "audio":
        from repro.models import encdec

        enc = encdec.encode(cfg, params, batch_extra["frames"])
        logits, _ = encdec.decode_stack(cfg, params, tokens, enc, mode="train")
        return logits
    if cfg.family == "ssm":
        # xlstm: reuse loss-path forward
        from repro.models.model import _xlstm_model  # noqa: SLF001

        # run() is closed over; emulate via prefill of successive prefixes
        raise pytest.skip("covered by test_xlstm_forms")
    from repro.models import transformer

    prefix = batch_extra.get("patches")
    logits, _, _ = transformer.forward(cfg, ctx, params, tokens, mode="train", prefix_embeds=prefix)
    if prefix is not None:
        logits = logits[:, prefix.shape[1] :]
    return logits


@pytest.mark.parametrize(
    "arch", ["granite-3-2b", "gemma-2b", "qwen2.5-32b", "deepseek-v2-236b", "whisper-tiny", "internvl2-76b"]
)
def test_decode_matches_forward(arch, ctx):
    cfg = smoke_config(all_configs()[arch])
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    B, S_pre, S_total = 2, 24, 30
    tokens = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(key, (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(key, (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)

    full = _full_logits(model, cfg, params, tokens, ctx, extra)

    pre_batch = dict(extra)
    pre_batch["tokens"] = tokens[:, :S_pre]
    logits_pre, pc = model.prefill(params, pre_batch, ctx)
    # prefill's last-position logits == full forward at position S_pre-1
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(full[:, S_pre - 1], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    prefix_len = cfg.vision_tokens if cfg.family == "vlm" else 0
    max_len = S_total + prefix_len + 4
    caches = prefill_to_decode_caches(cfg, model, pc, B, max_len, S_pre + prefix_len)
    # MLA's absorbed decode reassociates bf16 matmuls (q.W_uk).c_kv, which
    # carries larger-but-bounded rounding noise; the fp32 equivalence is
    # pinned exactly by test_mla_absorbed_exact_fp32 below.
    tol = 1.5e-1 if cfg.use_mla else 2e-2
    if cfg.use_mla and tuple(int(p) for p in jax.__version__.split(".")[:2]) < (0, 5):
        # jax 0.4.x (container ships 0.4.37): CPU dot_general tiles bf16
        # contractions shape-dependently, so the train-shaped forward and the
        # decode-shaped MLA/MoE calls round differently — 2/1024 logits land
        # up to ~0.20 apart (forcing fp32 accumulation in the MoE combine
        # does not close it; the reassociated MLA decode dominates). jax 0.5+
        # stays within the 1.5e-1 bound.
        tol = 2.5e-1
    for t in range(S_pre, S_total):
        logits_d, caches = model.decode_step(
            params, tokens[:, t : t + 1], caches, jnp.int32(t + prefix_len), ctx
        )
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full[:, t], np.float32),
            rtol=tol, atol=tol,
            err_msg=f"{arch} decode step {t}",
        )


def test_mla_absorbed_exact_fp32():
    """Absorbed-form MLA decode == expanded-form attention exactly (fp32)."""
    import dataclasses

    from repro.models.layers import init_tree
    from repro.models.transformer import _attn_defs, _mla_attention

    cfg = smoke_config(all_configs()["deepseek-v2-236b"])
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = jax.tree.map(
        lambda p: p.astype(jnp.float32), init_tree(_attn_defs(cfg), jax.random.PRNGKey(0))
    )
    B, S = 2, 17
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y_full, cache = _mla_attention(cfg, params, x, pos, mode="prefill")
    cache_prefix = {
        k: jnp.pad(v[:, : S - 1], ((0, 0), (0, 4), (0, 0)))
        for k, v in cache.items()
    }
    y_dec, _ = _mla_attention(
        cfg, params, x[:, S - 1 : S], pos[:, S - 1 : S],
        mode="decode", cache=cache_prefix, cache_pos=jnp.int32(S - 1),
    )
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0]), np.asarray(y_full[:, S - 1]), rtol=1e-4, atol=1e-4
    )


def test_hymba_ring_cache_decode(ctx):
    """Sliding-window ring cache must match the full forward within window."""
    cfg = smoke_config(all_configs()["hymba-1.5b"])  # window = 64
    model = build_model(cfg)
    key = jax.random.PRNGKey(4)
    params = model.init(key)
    B, S_pre, S_total = 1, 80, 96  # prefill longer than the 64-token window
    tokens = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)

    from repro.models import transformer

    full, _, _ = transformer.forward(cfg, ctx, params, tokens, mode="train")

    pre_batch = {"tokens": tokens[:, :S_pre]}
    logits_pre, pc = model.prefill(params, pre_batch, ctx)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(full[:, S_pre - 1], np.float32),
        rtol=3e-2, atol=3e-2,
    )
    caches = prefill_to_decode_caches(cfg, model, pc, B, S_total + 4, S_pre)
    for t in range(S_pre, S_total):
        logits_d, caches = model.decode_step(params, tokens[:, t : t + 1], caches, jnp.int32(t), ctx)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full[:, t], np.float32),
            rtol=3e-2, atol=3e-2,
            err_msg=f"hymba ring decode step {t}",
        )


def test_xlstm_forms_consistent(ctx):
    """mLSTM parallel == chunkwise == recurrent; sLSTM prefill->decode."""
    from repro.models import xlstm
    from repro.models.layers import init_tree

    key = jax.random.PRNGKey(5)
    B, S, D, H = 2, 64, 64, 4
    defs = xlstm.mlstm_defs(0, D, H)
    params = init_tree(defs, key)
    x = jax.random.normal(key, (B, S, D), jnp.float32).astype(jnp.bfloat16) * 0.3

    out_par, _ = xlstm.mlstm_block(params, x, H)  # S<=256 -> parallel
    out_chunk, st = xlstm.mlstm_block(params, x, H, return_state=True)
    np.testing.assert_allclose(
        np.asarray(out_par, np.float32), np.asarray(out_chunk, np.float32), rtol=3e-2, atol=3e-2
    )
    # recurrent continuation from chunkwise state == parallel on S+1
    x1 = jax.random.normal(jax.random.PRNGKey(6), (B, 1, D), jnp.float32).astype(jnp.bfloat16) * 0.3
    out_rec, _ = xlstm.mlstm_block(params, x1, H, state=st)
    full2, _ = xlstm.mlstm_block(params, jnp.concatenate([x, x1], 1), H)
    np.testing.assert_allclose(
        np.asarray(out_rec[:, 0], np.float32), np.asarray(full2[:, -1], np.float32),
        rtol=4e-2, atol=4e-2,
    )

    # model-level: prefill then decode matches full forward next-token logits
    cfg = smoke_config(all_configs()["xlstm-350m"])
    model = build_model(cfg)
    params_m = model.init(key)
    tokens = jax.random.randint(key, (2, 40), 0, cfg.vocab_size)
    logits_pre, caches = model.prefill(params_m, {"tokens": tokens[:, :32]}, ctx)
    logits_d, _ = model.decode_step(params_m, tokens[:, 32:33], caches, jnp.int32(32), ctx)
    # teacher-forced reference: prefill of the longer prefix
    logits_ref, _ = model.prefill(params_m, {"tokens": tokens[:, :34]}, ctx)
    logits_ref33, _ = model.prefill(params_m, {"tokens": tokens[:, :33]}, ctx)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32), np.asarray(logits_ref33[:, -1], np.float32),
        rtol=4e-2, atol=4e-2,
    )
