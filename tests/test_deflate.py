import gzip as _gzip
import zlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BitReader,
    DeflateChunkDecoder,
    MARKER_BASE,
    WINDOW_SIZE,
    gzip_decompress_sequential,
    inflate_raw,
    parse_gzip_header,
    replace_markers,
)
from repro.core.errors import DeflateError, GzipFooterError
from repro.core.synth import fixed_only_compress, pigz_like_compress, stored_only_compress

from conftest import gzip_bytes, make_base64, make_random, make_text


@pytest.mark.parametrize("level", [1, 6, 9])
@pytest.mark.parametrize("kind", ["text", "base64", "random"])
def test_sequential_roundtrip(rng, level, kind):
    data = {"text": make_text, "base64": make_base64, "random": make_random}[kind](rng, 200_000)
    assert gzip_decompress_sequential(gzip_bytes(data, level)) == data


def test_multi_member(rng):
    data1, data2 = make_text(rng, 50_000), make_base64(rng, 30_000)
    comp = gzip_bytes(data1) + gzip_bytes(data2) + gzip_bytes(b"")
    assert gzip_decompress_sequential(comp) == data1 + data2


def test_stored_blocks(rng):
    data = make_random(rng, 300_000)  # incompressible -> stored blocks
    assert gzip_decompress_sequential(stored_only_compress(data)) == data


def test_fixed_blocks(rng):
    data = make_text(rng, 100_000)
    assert gzip_decompress_sequential(fixed_only_compress(data)) == data


def test_pigz_like_sync_flush(rng):
    data = make_text(rng, 300_000)
    assert gzip_decompress_sequential(pigz_like_compress(data, block_size=64 << 10)) == data


def test_crc_mismatch_detected(rng):
    comp = bytearray(gzip_bytes(make_text(rng, 10_000)))
    comp[-6] ^= 0xFF  # corrupt stored CRC32
    with pytest.raises(GzipFooterError):
        gzip_decompress_sequential(bytes(comp))


def test_raw_deflate(rng):
    data = make_text(rng, 120_000)
    raw = zlib.compress(data, 6)[2:-4]
    assert inflate_raw(raw) == data


def test_reserved_block_type_rejected():
    # final=1, type=11 (reserved): bits 1,1,1 LSB-first -> byte 0b00000111
    with pytest.raises(DeflateError):
        inflate_raw(b"\x07\x00\x00")


def _block_offsets(comp: bytes):
    br = BitReader(comp)
    parse_gzip_header(br)
    dec = DeflateChunkDecoder(comp)
    res = dec.decode_chunk(br.bit_pos, len(comp) * 8, window=b"")
    return res


@pytest.mark.parametrize("kind", ["text", "base64"])
def test_two_stage_equals_single_stage(rng, kind):
    """Core paper property: marker decode + replacement == known-window decode."""
    data = {"text": make_text, "base64": make_base64}[kind](rng, 400_000)
    comp = gzip_bytes(data, 6)
    full = _block_offsets(comp)
    assert len(full.blocks) >= 2, "need multiple blocks for this test"
    dec = DeflateChunkDecoder(comp)
    for blk in full.blocks[1:3]:
        window = data[max(0, blk.out_offset - WINDOW_SIZE) : blk.out_offset]
        single = dec.decode_chunk(blk.bit_offset, len(comp) * 8, window=window)
        marker = dec.decode_chunk(blk.bit_offset, len(comp) * 8, window=None)
        assert marker.marker_mode and not single.marker_mode
        resolved = replace_markers(marker.data, window)
        np.testing.assert_array_equal(resolved, single.data)
        truth = data[blk.out_offset : blk.out_offset + single.size]
        assert single.data.tobytes() == truth


def test_marker_values_name_window_positions(rng):
    data = make_text(rng, 600_000)
    comp = gzip_bytes(data, 6)
    full = _block_offsets(comp)
    assert len(full.blocks) >= 2, "test data must span multiple deflate blocks"
    blk = full.blocks[1]
    dec = DeflateChunkDecoder(comp)
    res = dec.decode_chunk(blk.bit_offset, len(comp) * 8, window=None)
    syms = res.data
    markers = syms[syms >= MARKER_BASE]
    if markers.size:  # every marker points into the 32 KiB window
        w = markers.astype(np.int64) - MARKER_BASE
        assert w.min() >= 0 and w.max() < WINDOW_SIZE
        # resolve and compare against the original stream
        window = data[max(0, blk.out_offset - WINDOW_SIZE) : blk.out_offset]
        out = replace_markers(syms, window)
        assert out.tobytes() == data[blk.out_offset : blk.out_offset + res.size]
        assert res.first_marker >= 0 and res.last_marker >= res.first_marker


def test_stop_condition_matches_next_chunk(rng):
    """Chunk end offsets must be decodable start offsets for the successor."""
    data = make_base64(rng, 600_000)
    comp = gzip_bytes(data, 6)
    br = BitReader(comp)
    parse_gzip_header(br)
    dec = DeflateChunkDecoder(comp)
    stop = br.bit_pos + 400_000 * 8 // 2
    first = dec.decode_chunk(br.bit_pos, stop, window=b"")
    assert first.end_bit >= stop or first.ended_at_eos
    if not first.ended_at_eos:
        second = dec.decode_chunk(first.end_bit, len(comp) * 8, window=None)
        window = first.data[-WINDOW_SIZE:].tobytes()
        resolved = replace_markers(second.data, window)
        combined = first.data.tobytes() + resolved.tobytes()
        assert combined == data[: len(combined)]


@settings(max_examples=20, deadline=None)
@given(blob=st.binary(min_size=0, max_size=5000), level=st.integers(min_value=0, max_value=9))
def test_property_roundtrip_any_bytes(blob, level):
    assert gzip_decompress_sequential(_gzip.compress(blob, compresslevel=level)) == blob
