"""Training substrate: convergence, grad-accum equivalence, ZeRO shardings,
checkpoint/restore (incl. elastic), gradient compression properties."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.configs import all_configs, smoke_config
from repro.distributed import default_rules, zero1_spec
from repro.distributed.compression import compress, decompress, init_error_state, quantize_with_feedback
from repro.distributed.sharding import batch_partition, fit_spec
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train import AdamWConfig, init_train_state, lr_schedule, make_train_step


@pytest.fixture(scope="module")
def setup():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    cfg = smoke_config(all_configs()["granite-3-2b"])
    model = build_model(cfg)
    return mesh, rules, cfg, model


def _batch(vocab, B=4, S=64, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": rng.integers(0, vocab, (B, S + 1), dtype=np.int32)}


def test_loss_decreases(setup):
    mesh, rules, cfg, model = setup
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    step, _ = make_train_step(model, mesh, rules, AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=100))
    batch = _batch(cfg.vocab_size)
    losses = []
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.7 * losses[0]
    assert all(np.isfinite(losses))


def test_grad_accum_equivalence(setup):
    mesh, rules, cfg, model = setup
    params, opt = init_train_state(model, jax.random.PRNGKey(1))
    ocfg = AdamWConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)
    step1, _ = make_train_step(model, mesh, rules, ocfg, grad_accum=1)
    step2, _ = make_train_step(model, mesh, rules, ocfg, grad_accum=2)
    batch = _batch(cfg.vocab_size, B=4)
    p1, o1, m1 = step1(jax.tree.map(jnp.copy, params), jax.tree.map(jnp.copy, opt), batch)
    p2, o2, m2 = step2(jax.tree.map(jnp.copy, params), jax.tree.map(jnp.copy, opt), batch)
    # same data, same update (up to bf16 accumulation noise)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=3e-2, atol=3e-3
        )


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100, end_lr_fraction=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=0.01)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.05)


def test_zero1_spec_math():
    mesh = make_mesh((1, 1), ("data", "model"))
    # data axis size 1: spec must stay valid; divisibility logic exercised
    s = zero1_spec(P(None, "model"), (64, 128), mesh)
    assert s[0] in ("data", None)
    big = make_mesh((1, 1), ("data", "model"))
    # pure function checks on a fake 16x16 mesh via fit_spec composition
    s2 = fit_spec(P("model", None), (40, 128), big)
    # trailing Nones are stripped; size-1 axes always divide
    assert s2 == P("model")


def test_fit_spec_prefix_rules():
    mesh = make_mesh((1, 1), ("data", "model"))
    # non-divisible dims fall back to replication on a real 16-wide axis;
    # with size-1 axes everything divides, so spec is preserved
    assert fit_spec(P("model"), (40,), mesh) == P("model")
    assert batch_partition(mesh, 4)[0] == "data"


def test_checkpoint_roundtrip_and_rotation(tmp_path, setup):
    mesh, rules, cfg, model = setup
    params, opt = init_train_state(model, jax.random.PRNGKey(2))
    state = {"params": params, "opt": opt, "data": {"shard_idx": 3, "byte_offset": 12345,
                                                    "buffered_tokens": 0, "pending_buffer": 0}}
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4):
        save_checkpoint(d, s, state, keep_n=2)
    assert latest_checkpoint(d).endswith("step_00000004")
    assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2

    template = {"params": jax.tree.map(jnp.zeros_like, params),
                "opt": jax.tree.map(jnp.zeros_like, opt),
                "data": {"shard_idx": 0, "byte_offset": 0, "buffered_tokens": 0, "pending_buffer": 0}}
    step, restored = restore_checkpoint(latest_checkpoint(d), template)
    assert step == 4
    for a, b in zip(jax.tree.leaves(restored["params"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert restored["data"]["byte_offset"] == 12345


def test_checkpoint_elastic_resharding(tmp_path, setup):
    """Restore with explicit shardings (the elastic path)."""
    mesh, rules, cfg, model = setup
    params, opt = init_train_state(model, jax.random.PRNGKey(3))
    d = str(tmp_path / "ckpt2")
    save_checkpoint(d, 7, {"params": params})
    from repro.train import param_shardings

    shardings = {"params": param_shardings(model, mesh, rules)}
    step, restored = restore_checkpoint(latest_checkpoint(d), {"params": params}, shardings=shardings)
    assert step == 7
    leaf = jax.tree.leaves(restored["params"])[0]
    assert leaf.sharding is not None


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=-1e3, max_value=1e3, allow_nan=False), min_size=1, max_size=64))
def test_compress_bounded_error(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    q, scale = compress(x)
    err = np.abs(np.asarray(decompress(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_converges():
    """With error feedback, the running sum of dequantized grads tracks the
    running sum of true grads (unbiasedness in the long run)."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=128).astype(np.float32)) * 0.01 for _ in range(50)]
    err = init_error_state({"w": g_true[0]})
    acc_q = np.zeros(128, np.float32)
    acc_t = np.zeros(128, np.float32)
    for g in g_true:
        out, err = quantize_with_feedback({"w": g}, err)
        acc_q += np.asarray(out["w"])
        acc_t += np.asarray(g)
    resid = np.abs(acc_q - acc_t).max()
    # residual bounded by one quantization step, NOT growing with t
    assert resid < 0.01


def test_compressed_grads_training_still_converges(setup):
    mesh, rules, cfg, model = setup
    params, opt = init_train_state(model, jax.random.PRNGKey(4), compress_grads=True)
    step, _ = make_train_step(
        model, mesh, rules, AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=100),
        compress_grads=True,
    )
    batch = _batch(cfg.vocab_size)
    losses = []
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.75 * losses[0]
