"""Codec interface: detection, the BGZF zero-speculation path, codec-aware
store identity, and the zstd seekable backend.

Detection must be evidence-based (BGZF by its BC FEXTRA subfield, zstd by
frame magic), degrade to deflate on ambiguity (valid gzip can never error
out of auto-detection), and flow consistently into `IndexStore.file_identity`
so twins of the same logical content under different codecs never collide.
Zstd decode tests carry the ``zstd`` marker (auto-skip on a bare container);
everything structural — probing, seek-table parsing, identity — runs
without a zstd library.
"""

import gzip as _gzip
import struct

import pytest

from repro.core import ParallelGzipReader
from repro.core.codec import (
    BgzfCodec,
    DeflateCodec,
    ZstdCodec,
    detect_codec,
    detect_codec_tag,
    have_zstd,
    parse_zstd_seek_table,
    resolve_codec,
)
from repro.core.errors import FormatError
from repro.core.filereader import BytesFileReader
from repro.core.index import GzipIndex
from repro.core.synth import bgzf_compress, gzip_compress
from repro.service.index_store import IndexStore, file_identity

from conftest import make_base64, make_text


# ---------------------------------------------------------------------------
# detection
# ---------------------------------------------------------------------------


def test_detect_plain_gzip_is_deflate(rng):
    comp = gzip_compress(make_text(rng, 10_000), 6)
    assert detect_codec(comp).tag == "deflate"


def test_detect_bgzf_by_bc_subfield(rng):
    comp = bgzf_compress(make_text(rng, 10_000), 6)
    assert detect_codec(comp).tag == "bgzf"


def test_gzip_with_unrelated_fextra_is_not_bgzf(rng):
    """BGZF detection requires the BC subfield, not just FEXTRA presence: a
    gzip member with an unrelated extra field must stay deflate."""
    import zlib

    data = make_text(rng, 20_000)
    xtra = b"XY" + struct.pack("<H", 4) + b"\xde\xad\xbe\xef"
    header = (
        b"\x1f\x8b\x08\x04\x00\x00\x00\x00\x00\xff"
        + struct.pack("<H", len(xtra))
        + xtra
    )
    co = zlib.compressobj(6, zlib.DEFLATED, -15)
    raw = co.compress(data) + co.flush()
    footer = struct.pack("<II", zlib.crc32(data) & 0xFFFFFFFF, len(data) & 0xFFFFFFFF)
    comp = header + raw + footer
    assert _gzip.decompress(comp) == data  # sanity: valid gzip
    assert detect_codec(comp).tag == "deflate"
    with ParallelGzipReader(comp, parallelization=2) as r:
        assert r.codec.tag == "deflate"
        assert r.read() == data


def test_detect_zstd_by_frame_magic():
    assert detect_codec(b"\x28\xb5\x2f\xfd" + b"\x00" * 16).tag == "zstd"
    # skippable-frame-first files (seekable archives may start with one)
    assert detect_codec(struct.pack("<II", 0x184D2A5E, 4) + b"\x00" * 8).tag == "zstd"


def test_detect_garbage_falls_back_to_deflate():
    assert detect_codec(b"").tag == "deflate"
    assert detect_codec(b"\x00\x01\x02not an archive").tag == "deflate"
    assert detect_codec(b"\x1f").tag == "deflate"  # truncated magic


def test_truncated_bgzf_header_degrades_not_errors(rng):
    """A BGZF head truncated mid-header must not raise out of detection —
    the BGZF probe fails closed and deflate (whose probe needs only the
    2-byte magic) takes over."""
    comp = bgzf_compress(make_text(rng, 10_000), 6)
    for cut in (2, 3, 10, 15):
        tag = detect_codec(comp[:cut]).tag
        assert tag == "deflate", cut


def test_bgzf_leading_member_with_gzip_tail_falls_back(rng):
    """First member BGZF, rest plain gzip: the exact-index walk fails midway
    and the reader must fall back to the speculative pass (never error on
    valid gzip), still producing exact bytes — with an unpolluted index."""
    a, b = make_text(rng, 120_000), make_base64(rng, 80_000)
    comp = bgzf_compress(a, 6) + gzip_compress(b, 6)
    truth = a + b
    assert _gzip.decompress(comp) == truth  # sanity: valid multi-member gzip
    with ParallelGzipReader(comp, parallelization=2, chunk_size=48 << 10) as r:
        assert r.read() == truth
        assert r.index.finalized
        assert r.index.decompressed_size == len(truth)


def test_resolve_codec_rejects_unknown_tag():
    with pytest.raises(ValueError):
        resolve_codec("lz77-from-the-future")


# ---------------------------------------------------------------------------
# BGZF: the zero-speculation acceptance (paper §3.4.4)
# ---------------------------------------------------------------------------


def test_bgzf_cold_open_zero_speculation(rng):
    """A cold BGZF open performs ZERO speculative decoding: no nominal
    tasks, no frontier lock acquisitions, index finalized before the first
    read — while serving bit-identical bytes."""
    data = make_text(rng, 600_000)
    comp = bgzf_compress(data, 6)
    with ParallelGzipReader(comp, parallelization=3, chunk_size=64 << 10) as r:
        assert r.codec.tag == "bgzf"
        assert r.index.finalized  # before any read
        assert r.index.codec_tag == "bgzf"
        for off in (0, 123_457, 599_000, 300_000):
            assert r.pread(off, 2000) == data[off : off + 2000]
        assert r.read() == data
        st = r.stats()
        assert st["fetcher"]["nominal_tasks"] == 0
        assert st["fetcher"]["exact_tasks"] == 0
        assert st["fetcher"]["candidates_tried"] == 0
        assert st["frontier"]["lock_acquires"] == 0
        assert st["fetcher"]["zlib_delegations"] > 0


# ---------------------------------------------------------------------------
# codec-aware identity (store + fleet rendezvous keys)
# ---------------------------------------------------------------------------


def test_identity_distinguishes_codec_twins(rng):
    """Twins of the same logical content under different codecs must get
    different store keys — in every source shape (bytes, path, FileReader)."""
    data = make_text(rng, 64 << 10)
    gz = gzip_compress(data, 6)
    bg = bgzf_compress(data, 6)
    assert file_identity(gz) != file_identity(bg)

    # Same bytes, different pinned codec tag: still different keys.
    assert file_identity(gz, codec="deflate") != file_identity(gz, codec="zstd")

    # FileReader branch agrees with the bytes branch about codec mixing.
    assert file_identity(BytesFileReader(gz)) != file_identity(BytesFileReader(bg))


def test_identity_same_key_for_same_blob_any_shape(rng, tmp_path):
    """Auto-probe is deterministic: repeated identity calls agree (this is
    what keeps fleet rendezvous routing consistent across router/server)."""
    data = make_text(rng, 32 << 10)
    bg = bgzf_compress(data, 6)
    assert file_identity(bg) == file_identity(bg)
    assert detect_codec_tag(bg) == "bgzf"
    p = tmp_path / "x.bgzf.gz"
    p.write_bytes(bg)
    assert detect_codec_tag(str(p)) == "bgzf"
    assert file_identity(str(p)) == file_identity(str(p))


def test_store_keys_codec_twins_separately(rng, tmp_path):
    """End-to-end regression: persist a gzip twin's index, then open the
    BGZF twin against the same store — it must MISS (different key), never
    import the deflate index for the BGZF bytes."""
    data = make_text(rng, 200_000)
    gz, bg = gzip_compress(data, 6), bgzf_compress(data, 6)
    store = IndexStore(str(tmp_path / "idx"))

    with ParallelGzipReader(gz, parallelization=2, chunk_size=64 << 10) as r:
        r.read()
        assert store.put(gz, r.index) is not None
    assert store.get(gz) is not None  # warm for the gzip twin
    assert store.get(bg) is None  # cold for the BGZF twin
    assert store.key_for(gz) != store.key_for(bg)


def test_detect_codec_tag_malformed_source_degrades():
    """Identity keys must be computable for malformed sources too (the open
    that follows reports the real error) — probe failures mean deflate."""
    assert detect_codec_tag(b"") == "deflate"
    assert detect_codec_tag("/nonexistent/path/really") == "deflate"


# ---------------------------------------------------------------------------
# zstd: structure without a library, decode with one
# ---------------------------------------------------------------------------


def _fake_seekable(frames):
    """Seekable container with arbitrary (fake) frame bytes — the seek-table
    parser never decodes frames, so it is testable without a zstd library."""
    body = b"".join(f for f, _ in frames)
    entries = b"".join(struct.pack("<II", len(f), d) for f, d in frames)
    table = entries + struct.pack("<IBI", len(frames), 0, 0x8F92EAB1)
    return body + struct.pack("<II", 0x184D2A5E, len(table)) + table


def test_zstd_seek_table_parses_without_library():
    frames = [(b"\x28\xb5\x2f\xfdAAAA", 100), (b"\x28\xb5\x2f\xfdBBBBBB", 250)]
    blob = _fake_seekable(frames)
    got = parse_zstd_seek_table(BytesFileReader(blob))
    assert got == [(0, 8, 100), (8, 10, 250)]


def test_zstd_seek_table_rejects_inconsistent_footer():
    frames = [(b"\x28\xb5\x2f\xfdAAAA", 100)]
    blob = bytearray(_fake_seekable(frames))
    blob[-1] ^= 0x5A  # corrupt the seekable magic
    with pytest.raises(FormatError):
        parse_zstd_seek_table(BytesFileReader(bytes(blob)))
    with pytest.raises(FormatError):
        parse_zstd_seek_table(BytesFileReader(b"\x28\xb5\x2f\xfd" + b"\x00" * 20))


def test_zstd_open_without_library_fails_loudly():
    """On a bare container a zstd source must produce a clear FormatError at
    open time (mentioning how to get a backend), not a junk decode."""
    if have_zstd():
        pytest.skip("a zstd library is importable; the loud-failure path is moot")
    frames = [(b"\x28\xb5\x2f\xfdAAAA", 100)]
    blob = _fake_seekable(frames)
    with pytest.raises(FormatError, match="zstandard"):
        ParallelGzipReader(blob, parallelization=1)


@pytest.mark.zstd
def test_zstd_roundtrip_real_frames(rng):
    """Real seekable frames (library present): cold open builds the index
    from the seek table — zero speculation, exact bytes, random access."""
    from repro.core.synth import zstd_seekable_compress

    data = make_text(rng, 500_000)
    comp = zstd_seekable_compress(data, 3, frame_size=64 << 10)
    with ParallelGzipReader(comp, parallelization=3) as r:
        assert r.codec.tag == "zstd"
        assert r.index.finalized
        assert r.read() == data
        for off in (0, 123_457, 499_000):
            assert r.pread(off, 1500) == data[off : off + 1500]
        st = r.stats()
        assert st["fetcher"]["nominal_tasks"] == 0
        assert st["frontier"]["lock_acquires"] == 0
        assert st["fetcher"]["zlib_delegations"] > 0  # native-path counter


@pytest.mark.zstd
def test_zstd_index_roundtrip_and_store(rng, tmp_path):
    from repro.core.synth import zstd_seekable_compress

    data = make_base64(rng, 300_000)
    comp = zstd_seekable_compress(data, 3, frame_size=64 << 10)
    store = IndexStore(str(tmp_path / "idx"))
    with ParallelGzipReader(comp, parallelization=2) as r:
        r.read()
        assert store.put(comp, r.index) is not None
    warm = store.get(comp)
    assert warm is not None and warm.codec_tag == "zstd"
    with ParallelGzipReader(comp, parallelization=2, index=warm.to_bytes()) as r2:
        assert r2.codec.tag == "zstd"
        assert r2.pread(150_000, 5000) == data[150_000:155_000]


# ---------------------------------------------------------------------------
# fetcher-level invariance: nothing above the fetcher is codec-aware
# ---------------------------------------------------------------------------


def test_server_surfaces_codec_tag(rng, tmp_path):
    """ArchiveServer auto-detects per handle and reports the resolved tag in
    stat()/metrics() with no per-codec branches of its own."""
    from repro.service.server import ArchiveServer

    data = make_text(rng, 150_000)
    with ArchiveServer(max_workers=2, chunk_size=64 << 10) as srv:
        h_gz = srv.open(gzip_compress(data, 6))
        h_bg = srv.open(bgzf_compress(data, 6))
        assert srv.read_range(h_gz, 1000, 2000) == data[1000:3000]
        assert srv.read_range(h_bg, 1000, 2000) == data[1000:3000]
        assert srv.stat(h_gz).codec == "deflate"
        assert srv.stat(h_bg).codec == "bgzf"
        per_file = srv.metrics()["per_file"]
        assert {v["codec"] for v in per_file.values()} == {"deflate", "bgzf"}


def test_codec_window_size_contract():
    assert DeflateCodec().window_size == 32768
    assert BgzfCodec().window_size == 32768  # members are deflate inside
    assert ZstdCodec().window_size == 0  # frames are independent
    assert not ZstdCodec().supports_speculation
    assert BgzfCodec().supports_speculation  # fallback path needs it
