"""Pipeline parallelism over a mesh axis — runs in a subprocess with 4 fake
host devices (XLA device count is locked at first init, so the main pytest
process must keep its single CPU device)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import pipeline_apply
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4, 1), ("pod", "data"))
    n_stages, n_micro, B, D = 4, 8, 2, 16

    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (n_stages, D, D), jnp.float32) * 0.3

    def stage_fn(params, x):
        return jnp.tanh(x @ params["w"])

    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, B, D), jnp.float32)

    out = pipeline_apply(stage_fn, {"w": w}, x, mesh=mesh, axis="pod")

    # sequential reference
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ w[s])

    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    print("PIPELINE_OK")
    """
)


def test_pipeline_apply_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=300,
    )
    assert "PIPELINE_OK" in proc.stdout, proc.stderr[-3000:]


MULTIPOD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import all_configs, smoke_config
    from repro.distributed import default_rules
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.train import AdamWConfig, init_train_state, make_train_step

    # miniature multi-pod mesh: (pod=2, data=2, model=2)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = default_rules(mesh)
    cfg = smoke_config(all_configs()["deepseek-moe-16b"])
    model = build_model(cfg)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    step, _ = make_train_step(model, mesh, rules, AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (4, 65), dtype=np.int32)}
    losses = []
    for _ in range(6):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))
    print("MULTIPOD_MOE_OK", [round(l, 3) for l in losses])
    """
)


def test_multipod_moe_training_executes():
    """Actually EXECUTE (not just compile) MoE EP training on a (2,2,2) mesh:
    all-to-alls, ZeRO-1 moments and TP collectives all run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", MULTIPOD_SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=540,
    )
    assert "MULTIPOD_MOE_OK" in proc.stdout, proc.stderr[-3000:]
