"""AsyncArchiveServer: non-blocking bridge over the concurrent ArchiveServer.

The async consistency test carries the tier-2 ``stress`` marker; every
``asyncio`` entry point runs under ``asyncio.wait_for`` so a bridge
regression (e.g. a reintroduced per-handle lock starving the front-end
pool) fails the test instead of hanging the suite.
"""

import asyncio
import threading
import time

import numpy as np
import pytest

from repro.service import ArchiveServer, AsyncArchiveServer

from conftest import gzip_bytes, make_base64, make_text

RUN_TIMEOUT = 60  # seconds per asyncio scenario


def _run(coro):
    return asyncio.run(asyncio.wait_for(coro, RUN_TIMEOUT))


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0xA57)
    data = make_text(rng, 300_000) + make_base64(rng, 300_000)
    return data, gzip_bytes(data, 6)


def test_async_open_read_stat_close(corpus):
    data, comp = corpus

    async def scenario():
        async with AsyncArchiveServer(
            cache_budget_bytes=2 << 20, max_workers=2, chunk_size=64 << 10
        ) as srv:
            h = await srv.open(comp, tenant="t0")
            st = await srv.stat(h)
            assert not st.opened  # lazy, like the sync server
            got = await srv.read_range(h, 1000, 5000)
            assert got == data[1000:6000]
            assert await srv.size(h) == len(data)
            st = await srv.stat(h)
            assert st.opened and st.reads == 1 and st.bytes_served == 5000
            m = srv.metrics()
            assert m["service"]["reads_started"] == 1
            assert m["service"]["reads_in_flight"] == 0
            await srv.close(h)
            with pytest.raises(KeyError):
                await srv.read_range(h, 0, 1)

    _run(scenario())


def test_async_read_many_order_and_content(corpus):
    data, comp = corpus

    async def scenario():
        async with AsyncArchiveServer(
            cache_budget_bytes=2 << 20, max_workers=4, chunk_size=64 << 10,
            front_end_threads=4,
        ) as srv:
            h = await srv.open(comp)
            reqs = [(h, off, 4096) for off in (0, 250_000, 13, 599_000, 300_001)]
            got = await srv.read_many(reqs)
            assert got == [data[o : o + n] for _, o, n in reqs]

    _run(scenario())


def test_async_wraps_existing_server_without_owning_it(corpus):
    data, comp = corpus
    server = ArchiveServer(cache_budget_bytes=1 << 20, max_workers=2)
    try:

        async def scenario():
            async with AsyncArchiveServer(server, front_end_threads=2) as srv:
                h = await srv.open(comp)
                assert await srv.read_range(h, 0, 100) == data[:100]

        _run(scenario())
        # wrapper shutdown must NOT have shut the caller's server down
        h2 = server.open(comp)
        assert server.read_range(h2, 5, 50) == data[5:55]
    finally:
        server.shutdown()


def test_async_event_loop_stays_responsive_during_first_pass(corpus):
    """A cold size() (whole speculative first pass) runs on the bridge; the
    event loop must keep scheduling other coroutines meanwhile."""
    data, comp = corpus

    async def scenario():
        async with AsyncArchiveServer(
            cache_budget_bytes=2 << 20, max_workers=2, chunk_size=32 << 10,
            front_end_threads=2,
        ) as srv:
            h = await srv.open(comp)
            ticks = 0

            async def ticker():
                nonlocal ticks
                while True:
                    await asyncio.sleep(0.001)
                    ticks += 1

            t = asyncio.ensure_future(ticker())
            size = await srv.size(h)  # drives the whole first pass
            t.cancel()
            assert size == len(data)
            # The loop turned over while the bridge thread did the work. A
            # blocking bridge would leave ticks at ~0.
            assert ticks >= 5, f"event loop starved: {ticks} ticks"

    _run(scenario())


@pytest.mark.stress
def test_async_threaded_consistency_warm_and_cold(corpus):
    """Concurrent coroutine clients (over the bridge) + a sync thread
    hammering the same handle: bit-identical results, cold and warm."""
    data, comp = corpus

    for warm in (False, True):
        server = ArchiveServer(
            cache_budget_bytes=4 << 20, max_workers=4, chunk_size=64 << 10
        )
        sync_errors: list = []

        async def scenario():
            async with AsyncArchiveServer(server, front_end_threads=8) as srv:
                h = await srv.open(comp)
                if warm:
                    await srv.size(h)  # finalize the index first

                def sync_client():
                    rng = np.random.default_rng(3)
                    try:
                        for _ in range(15):
                            off = int(rng.integers(0, len(data)))
                            got = server.read_range(h, off, 10_000)
                            if got != data[off : off + 10_000]:
                                raise AssertionError("sync client mismatch")
                    except BaseException as exc:  # noqa: BLE001
                        sync_errors.append(exc)

                async def client(seed):
                    rng = np.random.default_rng(seed)
                    for _ in range(10):
                        off = int(rng.integers(0, len(data)))
                        got = await srv.read_range(h, off, 10_000)
                        assert got == data[off : off + 10_000]

                thread = threading.Thread(target=sync_client)
                thread.start()
                try:
                    await asyncio.gather(*(client(50 + i) for i in range(8)))
                finally:
                    thread.join(RUN_TIMEOUT)
                assert not thread.is_alive(), "sync client deadlocked"
                if warm:
                    # warm handle: nobody ever touched the frontier lock
                    fr = srv.metrics()["fleet"]["frontier"]
                    assert fr["lock_contended"] == 0

        _run(scenario())
        server.shutdown()
        assert not sync_errors, sync_errors[0]


def test_cancelled_await_never_leaks_a_bridge_thread(corpus):
    """Regression (gateway cancellation): cancelling an await whose bridged
    call is still *queued* must cancel the underlying future — the call
    never starts on a bridge thread, and the books prove it
    (submitted == started + cancelled)."""
    data, comp = corpus
    server = ArchiveServer(cache_budget_bytes=1 << 20, max_workers=2)
    h = server.open(comp)
    server.read_range(h, 0, 1)  # open the reader eagerly

    real_pread = server._entries[h].reader.pread
    release = threading.Event()

    def slow_pread(offset, size):
        release.wait(RUN_TIMEOUT)
        return real_pread(offset, size)

    server._entries[h].reader.pread = slow_pread
    try:

        async def scenario():
            # One bridge thread: the first read occupies it, the rest queue.
            async with AsyncArchiveServer(server, front_end_threads=1) as srv:
                first = asyncio.ensure_future(srv.read_range(h, 0, 10))
                await asyncio.sleep(0.05)  # first is now *running* on the bridge
                queued = [
                    asyncio.ensure_future(srv.read_range(h, i, 10))
                    for i in range(1, 5)
                ]
                await asyncio.sleep(0.05)  # all four submitted, none started
                for task in queued:
                    task.cancel()
                await asyncio.gather(*queued, return_exceptions=True)
                stats = srv.bridge_stats()
                assert stats["cancelled"] == 4, stats
                # started counts only the occupying call (+1 for the later
                # verification read): cancelled calls never ran.
                release.set()
                assert await first == data[:10]
                server._entries[h].reader.pread = real_pread
                assert await srv.read_range(h, 5, 10) == data[5:15]
                stats = srv.bridge_stats()
                assert stats["submitted"] == stats["started"] + stats["cancelled"]
                assert stats["started"] == 2, stats

        _run(scenario())
    finally:
        release.set()
        server.shutdown()


def test_read_many_failure_cancels_queued_siblings(corpus):
    """One bad range fails the batch AND reaps its still-queued siblings —
    they must not keep occupying (or later claim) bridge threads."""
    data, comp = corpus
    server = ArchiveServer(cache_budget_bytes=1 << 20, max_workers=2)
    h = server.open(comp)
    server.read_range(h, 0, 1)

    real_pread = server._entries[h].reader.pread
    release = threading.Event()

    def gated_pread(offset, size):
        if offset == 0:
            raise RuntimeError("injected range failure")
        release.wait(RUN_TIMEOUT)
        return real_pread(offset, size)

    server._entries[h].reader.pread = gated_pread
    try:

        async def scenario():
            async with AsyncArchiveServer(server, front_end_threads=2) as srv:
                reqs = [(h, off, 10) for off in (100, 0)] + [
                    (h, off, 10) for off in range(200, 1000, 100)
                ]
                with pytest.raises(RuntimeError, match="injected"):
                    await srv.read_many(reqs)
                release.set()
                await asyncio.sleep(0.1)  # let any stragglers finish
                stats = srv.bridge_stats()
                # the failing range + at most front_end_threads slow ones ran;
                # everything else was reaped while still queued.
                assert stats["cancelled"] >= len(reqs) - 3, stats
                assert stats["submitted"] == stats["started"] + stats["cancelled"]

        _run(scenario())
    finally:
        release.set()
        server._entries[h].reader.pread = real_pread
        server.shutdown()


def test_async_read_many_concurrency_actually_overlaps(corpus):
    """read_many must fan out: with a slow blocking read underneath, total
    time for K requests must be well under K x single-read time."""
    _, comp = corpus
    server = ArchiveServer(cache_budget_bytes=1 << 20, max_workers=2)
    h = server.open(comp)
    server.read_range(h, 0, 1)  # open the reader eagerly

    real_pread = server._entries[h].reader.pread

    def slow_pread(offset, size):
        time.sleep(0.05)
        return real_pread(offset, size)

    server._entries[h].reader.pread = slow_pread
    try:

        async def scenario():
            async with AsyncArchiveServer(server, front_end_threads=8) as srv:
                t0 = time.perf_counter()
                await srv.read_many([(h, 0, 10)] * 8)
                return time.perf_counter() - t0

        dt = _run(scenario())
        # serialized would be >= 8 * 0.05 = 0.4s; allow generous slack
        assert dt < 0.3, f"read_many did not overlap: {dt:.3f}s"
    finally:
        server.shutdown()
