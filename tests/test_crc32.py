import zlib

from hypothesis import given, settings, strategies as st

from repro.core.crc32 import RunningCRC, combine_parts, crc32_combine


@settings(max_examples=60, deadline=None)
@given(a=st.binary(max_size=2000), b=st.binary(max_size=2000))
def test_combine_matches_concatenation(a, b):
    crc_a = zlib.crc32(a) & 0xFFFFFFFF
    crc_b = zlib.crc32(b) & 0xFFFFFFFF
    assert crc32_combine(crc_a, crc_b, len(b)) == (zlib.crc32(a + b) & 0xFFFFFFFF)


@settings(max_examples=20, deadline=None)
@given(parts=st.lists(st.binary(min_size=0, max_size=500), min_size=1, max_size=8))
def test_running_crc_fold(parts):
    acc = RunningCRC()
    for p in parts:
        acc.add(zlib.crc32(p) & 0xFFFFFFFF, len(p))
    assert acc.crc == (zlib.crc32(b"".join(parts)) & 0xFFFFFFFF)
    assert acc.length == sum(len(p) for p in parts)


def test_combine_parts_helper():
    blobs = [b"hello ", b"parallel ", b"world"]
    parts = [(zlib.crc32(b) & 0xFFFFFFFF, len(b)) for b in blobs]
    assert combine_parts(parts) == (zlib.crc32(b"".join(blobs)) & 0xFFFFFFFF)


def test_empty_and_identity():
    assert crc32_combine(0, 0, 0) == 0
    assert crc32_combine(0xDEADBEEF, 0, 0) == 0xDEADBEEF
