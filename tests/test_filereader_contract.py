"""FileReader contract suite, run against every backend (paper §3, Fig 5).

The decompression machinery only ever sees the pread abstraction, so all
backends must agree on the contract: short reads never truncate mid-file,
EOF-straddling reads return the short tail, negative offset/size raise
ValueError, preads are thread-safe, close is idempotent. A network backend
turns any divergence from latent into load-bearing — hence one parametrized
suite instead of per-backend spot checks.
"""

import io
import threading

import pytest

from _range_server import RangeHTTPServer
from repro.core.filereader import (
    BytesFileReader,
    FileReader,
    PythonFileReader,
    SharedFileReader,
    open_file_reader,
)
from repro.core.remote import RemoteFileReader

DATA = bytes(range(256)) * 300  # 76800 bytes: straddles 64 KiB and blocks


class ShortReadFile(io.RawIOBase):
    """File-like whose read(n) legally returns at most 7 bytes at a time."""

    def __init__(self, data: bytes):
        super().__init__()
        self._data = data
        self._pos = 0

    def readable(self) -> bool:
        return True

    def seekable(self) -> bool:
        return True

    def seek(self, offset, whence=io.SEEK_SET):
        if whence == io.SEEK_SET:
            self._pos = offset
        elif whence == io.SEEK_CUR:
            self._pos += offset
        else:
            self._pos = len(self._data) + offset
        return self._pos

    def tell(self):
        return self._pos

    def read(self, n=-1):
        if n is None or n < 0:
            n = len(self._data) - self._pos
        n = min(n, 7)  # the short-read adversary
        out = self._data[self._pos : self._pos + n]
        self._pos += len(out)
        return out


BACKENDS = [
    "bytes",
    "shared",
    "python",
    "python_short",
    pytest.param("remote", marks=pytest.mark.remote),
    pytest.param("gateway", marks=pytest.mark.gateway),
    pytest.param("fleet", marks=pytest.mark.gateway),
]


@pytest.fixture(scope="module")
def gateway_over_data(tmp_path_factory):
    """One loopback gateway serving DATA (gzip-compressed server-side) for
    the whole module: the GatewayClient backend decompresses over the wire,
    so the same contract suite that covers bytes/mmap/python/remote also
    pins the wire protocol."""
    import gzip

    from repro.service.gateway import GatewayServer

    path = tmp_path_factory.mktemp("gwdata") / "contract.gz"
    path.write_bytes(gzip.compress(DATA, 6))
    with GatewayServer(
        cache_budget_bytes=4 << 20, max_workers=2, chunk_size=16 << 10
    ) as gw:
        yield gw, str(path)


@pytest.fixture(scope="module")
def fleet_over_data(tmp_path_factory):
    """Three loopback gateways behind a FleetRouter, all serving DATA: the
    FleetClient backend adds placement + failover on top of the gateway
    wire path, and must still honor the exact same pread contract."""
    import gzip

    from repro.service.gateway import GatewayServer
    from repro.service.fleet import FleetRouter

    path = tmp_path_factory.mktemp("fleetdata") / "contract.gz"
    path.write_bytes(gzip.compress(DATA, 6))
    gws = [
        GatewayServer(
            cache_budget_bytes=4 << 20, max_workers=2, chunk_size=16 << 10
        ).start()
        for _ in range(3)
    ]
    router = FleetRouter([gw.url for gw in gws])
    try:
        yield router, str(path)
    finally:
        router.close()
        for gw in gws:
            gw.close()


@pytest.fixture(params=BACKENDS)
def backend(request, tmp_path):
    """(reader, cleanup-managed) FileReader over DATA for each backend."""
    kind = request.param
    if kind == "gateway":
        from repro.service.gateway import GatewayClient

        gw, path = request.getfixturevalue("gateway_over_data")
        reader = GatewayClient(gw.url, source=path, block_size=4096, cache_blocks=8)
        yield reader
        reader.close()
    elif kind == "fleet":
        router, path = request.getfixturevalue("fleet_over_data")
        reader = router.open(path, block_size=4096, cache_blocks=8)
        yield reader
        reader.close()
    elif kind == "bytes":
        reader = BytesFileReader(DATA)
        yield reader
        reader.close()
    elif kind == "shared":
        path = tmp_path / "contract.bin"
        path.write_bytes(DATA)
        reader = SharedFileReader(path)
        yield reader
        reader.close()
    elif kind == "python":
        reader = PythonFileReader(io.BytesIO(DATA))
        yield reader
        reader.close()
    elif kind == "python_short":
        reader = PythonFileReader(ShortReadFile(DATA), close_fileobj=True)
        yield reader
        reader.close()
    else:
        with RangeHTTPServer(DATA) as srv:
            reader = RemoteFileReader(
                srv.url, block_size=4096, cache_blocks=8, sleep=lambda _s: None
            )
            yield reader
            reader.close()


def test_size(backend):
    assert backend.size() == len(DATA)


def test_pread_full(backend):
    assert backend.pread(0, len(DATA)) == DATA


@pytest.mark.parametrize(
    "offset,size",
    [
        (0, 1),
        (1, 4095),
        (4095, 2),  # block straddle for the remote backend
        (65535, 1024),  # 64 KiB straddle
        (12345, 33333),
    ],
)
def test_pread_middle(backend, offset, size):
    assert backend.pread(offset, size) == DATA[offset : offset + size]


def test_pread_eof_straddle(backend):
    # A read straddling EOF returns the short tail, never raises.
    assert backend.pread(len(DATA) - 10, 100) == DATA[-10:]


def test_pread_at_and_past_eof(backend):
    assert backend.pread(len(DATA), 10) == b""
    assert backend.pread(len(DATA) + 1000, 10) == b""


def test_pread_zero_size(backend):
    assert backend.pread(100, 0) == b""


def test_negative_offset_raises(backend):
    # A negative offset must not fall through to Python slicing (which
    # would silently serve bytes from the end of the buffer).
    with pytest.raises(ValueError):
        backend.pread(-1, 10)


def test_negative_size_raises(backend):
    with pytest.raises(ValueError):
        backend.pread(0, -1)


def test_concurrent_preads(backend):
    offsets = [0, 5, 4090, 12345, 40000, 65000, len(DATA) - 100]
    errors = []

    def worker(seed: int):
        try:
            for i in range(20):
                off = offsets[(seed + i) % len(offsets)]
                got = backend.pread(off, 500)
                assert got == DATA[off : off + 500], "mismatch at %d" % off
        except BaseException as exc:  # noqa: BLE001 - surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[0]


def test_close_idempotent(backend):
    data = backend.pread(0, 10)
    assert data == DATA[:10]
    backend.close()
    backend.close()  # second close must be a no-op, not an error


def test_view_contract(backend):
    view = backend.view()
    assert view is None or bytes(view) == DATA


def test_context_manager(tmp_path):
    with BytesFileReader(DATA) as r:
        assert isinstance(r, FileReader)
        assert r.pread(0, 4) == DATA[:4]


# -- backend-specific contract details --------------------------------------


def test_python_short_read_loop_no_truncation():
    # Regression: a single f.read(n) returning short used to truncate the
    # chunk silently and poison trial decompression downstream.
    reader = PythonFileReader(ShortReadFile(DATA))
    assert reader.pread(0, 1000) == DATA[:1000]
    assert reader.pread(70000, 10000) == DATA[70000:]


def test_python_close_propagation_opt_in():
    f1 = io.BytesIO(DATA)
    PythonFileReader(f1).close()
    assert not f1.closed  # default: wrapped object stays open

    f2 = io.BytesIO(DATA)
    PythonFileReader(f2, close_fileobj=True).close()
    assert f2.closed


def test_open_file_reader_dispatch(tmp_path):
    path = tmp_path / "d.bin"
    path.write_bytes(DATA)
    assert isinstance(open_file_reader(DATA), BytesFileReader)
    assert isinstance(open_file_reader(str(path)), SharedFileReader)
    assert isinstance(open_file_reader(io.BytesIO(DATA)), PythonFileReader)


@pytest.mark.remote
def test_open_file_reader_url_dispatch():
    with RangeHTTPServer(DATA) as srv:
        reader = open_file_reader(srv.url)
        assert isinstance(reader, RemoteFileReader)
        assert reader.pread(10, 20) == DATA[10:30]
        reader.close()
