"""ParallelGzipReader.pread: stateless concurrent positional reads, EOF
boundaries through the indexed path, and reader lifecycle (constructor
failure teardown, close-always-closes).

The threaded consistency tests carry the tier-2 ``stress`` marker
(`-m stress` selects just these); every join uses an explicit timeout so a
regression deadlocks into a test failure, not a hung CI job.
"""

import io
import threading

import numpy as np
import pytest

from repro.core import GzipIndex, ParallelGzipReader
from repro.core.errors import GzipHeaderError
from repro.core.filereader import BytesFileReader
from repro.core.index import SeekPoint

from conftest import gzip_bytes, make_base64, make_text

JOIN_TIMEOUT = 60  # seconds: generous for CI, finite so deadlocks fail


# ---------------------------------------------------------------------------
# pread semantics
# ---------------------------------------------------------------------------


def test_pread_matches_slices_and_leaves_cursor_alone(rng):
    data = make_text(rng, 400_000)
    comp = gzip_bytes(data, 6)
    with ParallelGzipReader(comp, parallelization=2, chunk_size=64 * 1024) as r:
        r.seek(123)
        for off, n in [(0, 1000), (399_000, 5000), (17, 0), (250_000, 64 * 1024)]:
            assert r.pread(off, n) == data[off : off + n]
        assert r.tell() == 123  # pread never moves the cursor
        assert r.read(10) == data[123:133]


def test_pread_validates_arguments(rng):
    comp = gzip_bytes(make_text(rng, 10_000))
    with ParallelGzipReader(comp, parallelization=1) as r:
        with pytest.raises(ValueError):
            r.pread(-1, 10)
        with pytest.raises(ValueError):
            r.pread(0, -10)


def test_pread_exact_eof_boundaries_indexed(rng):
    """Exact-EOF positional reads served through a finalized index."""
    data = make_base64(rng, 300_000)
    comp = gzip_bytes(data, 6)
    r = ParallelGzipReader(comp, parallelization=2, chunk_size=64 * 1024)
    r.build_full_index()
    buf = io.BytesIO()
    r.export_index(buf)
    r.close()

    with ParallelGzipReader(
        comp, parallelization=2, chunk_size=64 * 1024,
        index=GzipIndex.from_bytes(buf.getvalue()),
    ) as r2:
        n = len(data)
        assert r2.pread(n, 100) == b""  # at EOF
        assert r2.pread(n + 12345, 100) == b""  # past EOF
        assert r2.pread(n - 1, 100) == data[-1:]  # straddling: short
        assert r2.pread(n - 100, 100) == data[-100:]  # ends exactly at EOF
        # cursor API agrees at the same boundaries
        r2.seek(n)
        assert r2.read(10) == b""
        r2.seek(n - 7)
        assert r2.read() == data[-7:]
        # all indexed: the frontier lock was never taken
        assert r2.stats()["frontier"]["lock_acquires"] == 0


def test_pread_semantics_all_codecs(rng, codec_case):
    """pread semantics (slices, cursor independence, EOF boundaries) hold
    for every codec through the same machinery."""
    data = make_base64(rng, 300_000)
    comp = codec_case.compress(data)
    with ParallelGzipReader(comp, parallelization=2, chunk_size=64 * 1024) as r:
        r.seek(123)
        for off, n in [(0, 1000), (299_000, 5000), (17, 0), (150_000, 64 * 1024)]:
            assert r.pread(off, n) == data[off : off + n]
        assert r.tell() == 123
        n = len(data)
        assert r.pread(n, 100) == b""
        assert r.pread(n - 1, 100) == data[-1:]


def test_read_short_chunk_breaks_instead_of_looping(rng):
    """The indexed-path ``avail <= 0`` guard: when a (stale) finalized index
    overstates coverage and the cached last chunk is short, reads come back
    short instead of raising or spinning."""
    data = make_text(rng, 50_000)
    comp = gzip_bytes(data, 6)
    # Build a real index, then re-finalize a copy claiming 1000 extra bytes.
    r = ParallelGzipReader(comp, parallelization=1, chunk_size=16 * 1024)
    r.build_full_index()
    stale = GzipIndex()
    for p in r.index.points():
        stale.add_point(p)
    stale.finalize(len(data) + 1000, len(comp))
    r.close()

    with ParallelGzipReader(
        comp, parallelization=1, chunk_size=16 * 1024, index=stale
    ) as r2:
        # Seed the last chunk's true (short relative to the stale claim)
        # bytes through the public frontier-handoff API so the read is
        # served from cache rather than tripping a decode-size check.
        last = len(stale) - 1
        start = stale.point_at(last).decompressed_byte
        r2._fetcher.put_indexed(last, np.frombuffer(data[start:], dtype=np.uint8))
        r2.seek(len(data) - 5)
        assert r2.read(5000) == data[-5:]  # short, not an exception
        assert r2.pread(len(data), 100) == b""  # exactly at true EOF
        assert r2.pread(len(data) + 500, 10) == b""  # inside the stale claim


# ---------------------------------------------------------------------------
# lifecycle: constructor failure + close
# ---------------------------------------------------------------------------


class _TrackingReader(BytesFileReader):
    """BytesFileReader that records whether close() was called."""

    def __init__(self, data):
        super().__init__(data)
        self.closed = False

    def close(self):
        self.closed = True
        super().close()


class _ReleaseTrackingCache:
    """Duck-typed injectable cache that records release() (the PooledCache
    deregistration hook)."""

    def __init__(self):
        self.released = 0
        self._data = {}

    def get(self, key):
        return self._data.get(key)

    def insert(self, key, value):
        self._data[key] = value

    def __contains__(self, key):
        return key in self._data

    def release(self):
        self.released += 1


def test_constructor_failure_closes_reader_and_releases_caches():
    """Header parsing raising mid-constructor must close the FileReader and
    release injected (pooled) caches — repeated client retries must not
    accumulate FDs, connections, or pool registrations."""
    for _ in range(3):  # retries: teardown must be repeatable
        src = _TrackingReader(b"this is definitely not gzip data")
        access, prefetch = _ReleaseTrackingCache(), _ReleaseTrackingCache()
        with pytest.raises(GzipHeaderError):
            ParallelGzipReader(
                src, parallelization=2, access_cache=access, prefetch_cache=prefetch
            )
        assert src.closed, "FileReader leaked on constructor failure"
        assert access.released == 1 and prefetch.released == 1


def test_constructor_failure_before_fetcher_still_cleans_up(tmp_path):
    """An index-import failure (before the fetcher exists) must still close
    the FileReader and release the injected caches."""
    bad_index = tmp_path / "bad.idx"
    bad_index.write_bytes(b"not an index blob")
    src = _TrackingReader(gzip_bytes(b"x" * 1000))
    access, prefetch = _ReleaseTrackingCache(), _ReleaseTrackingCache()
    with pytest.raises(Exception):
        ParallelGzipReader(
            src, index=str(bad_index), access_cache=access, prefetch_cache=prefetch
        )
    assert src.closed
    assert access.released == 1 and prefetch.released == 1


def test_close_closes_reader_even_when_fetcher_shutdown_raises(rng):
    data = make_text(rng, 20_000)
    src = _TrackingReader(gzip_bytes(data))
    r = ParallelGzipReader(src, parallelization=1)
    assert r.read() == data

    def boom():
        raise RuntimeError("shutdown failed")

    r._fetcher.shutdown = boom
    with pytest.raises(RuntimeError):
        r.close()
    assert src.closed, "FileReader must close even when fetcher.shutdown raises"


# ---------------------------------------------------------------------------
# threaded consistency (tier-2 stress)
# ---------------------------------------------------------------------------


def _hammer_pread(reader, data, n_threads, n_reads, req_size, seed0=100):
    errors: list = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(n_reads):
                off = int(rng.integers(0, len(data)))
                got = reader.pread(off, req_size)
                want = data[off : off + req_size]
                if got != want:
                    raise AssertionError(
                        "pread mismatch off=%d got=%d want=%d"
                        % (off, len(got), len(want))
                    )
        except BaseException as exc:  # noqa: BLE001 - surface in main thread
            errors.append(exc)

    threads = [
        threading.Thread(target=worker, args=(seed0 + t,)) for t in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(JOIN_TIMEOUT)
    hung = [t for t in threads if t.is_alive()]
    assert not hung, "pread workers deadlocked (join timeout)"
    assert not errors, errors[0]


@pytest.mark.stress
@pytest.mark.parametrize("phase", ["cold", "warm"])
def test_threaded_pread_bit_identical(rng, phase):
    """Many threads, random ranges, bit-identical to sequential
    decompression — cold (racing the first pass through the frontier lock)
    and warm (finalized index, fully lock-free)."""
    data = make_text(rng, 600_000) + make_base64(rng, 600_000)
    comp = gzip_bytes(data, 6)
    index = None
    if phase == "warm":
        r = ParallelGzipReader(comp, parallelization=2, chunk_size=64 * 1024)
        index = r.build_full_index().to_bytes()
        r.close()
    r = ParallelGzipReader(
        comp, parallelization=4, chunk_size=64 * 1024,
        access_cache_size=4, index=index,
    )
    try:
        _hammer_pread(r, data, n_threads=8, n_reads=25, req_size=30_000)
        if phase == "warm":
            assert r.stats()["frontier"]["lock_acquires"] == 0
        else:
            assert r.stats()["frontier"]["lock_acquires"] > 0
        # the whole stream is still byte-exact after the storm
        assert r.pread(0, len(data)) == data
    finally:
        r.close()


@pytest.mark.stress
def test_threaded_pread_mixed_with_cursor_reads(rng):
    """A legacy cursor reader (seek+read from one thread) and concurrent
    pread callers share one instance without corrupting each other."""
    data = make_base64(rng, 500_000)
    comp = gzip_bytes(data, 6)
    r = ParallelGzipReader(comp, parallelization=3, chunk_size=64 * 1024,
                           access_cache_size=4)
    errors: list = []
    done = threading.Event()

    def cursor_reader():
        try:
            rng2 = np.random.default_rng(1)
            for _ in range(20):
                off = int(rng2.integers(0, len(data)))
                r.seek(off)
                got = r.read(10_000)
                if got != data[off : off + 10_000]:
                    raise AssertionError("cursor read mismatch")
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)
        finally:
            done.set()

    t = threading.Thread(target=cursor_reader)
    t.start()
    try:
        _hammer_pread(r, data, n_threads=4, n_reads=20, req_size=20_000, seed0=40)
    finally:
        t.join(JOIN_TIMEOUT)
    assert done.is_set() and not t.is_alive()
    assert not errors, errors[0]
    r.close()
