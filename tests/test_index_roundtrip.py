"""GzipIndex export/import across *separate* reader instances.

The roundtrip was previously only exercised implicitly (export -> from_bytes
in reader tests). These tests pin the serialization contract itself: every
SeekPoint field — including the FLAG_ZLIB_UNSAFE and
FLAG_HAS_INTERIOR_MEMBER_END flags the indexed fetcher dispatches on — must
survive a file roundtrip, and a fresh reader built from the imported file
must behave identically to the builder.
"""

import io
import os

import pytest

from repro.core import GzipIndex, ParallelGzipReader
from repro.core.index import (
    FLAG_HAS_INTERIOR_MEMBER_END,
    FLAG_STORED_BLOCK,
    FLAG_STREAM_START,
    FLAG_ZLIB_UNSAFE,
    SeekPoint,
)
from repro.core.synth import multistream_gzip, stored_only_compress

from conftest import gzip_bytes, make_base64, make_text


def test_synthetic_index_roundtrip_preserves_every_field(tmp_path):
    """All flag combinations + windows + finalization metadata."""
    idx = GzipIndex()
    flag_sets = [
        0,
        FLAG_STREAM_START,
        FLAG_HAS_INTERIOR_MEMBER_END,
        FLAG_STORED_BLOCK,
        FLAG_ZLIB_UNSAFE,
        FLAG_ZLIB_UNSAFE | FLAG_HAS_INTERIOR_MEMBER_END,
        FLAG_STREAM_START | FLAG_STORED_BLOCK | FLAG_ZLIB_UNSAFE,
    ]
    for i, flags in enumerate(flag_sets):
        window = bytes(range(256)) * 128 if i % 2 else b""
        idx.add_point(SeekPoint(i * 1000 + 3, i * 50_000, window, flags))
    idx.finalize(len(flag_sets) * 50_000, 123_456)

    path = os.path.join(tmp_path, "round.rpgzidx")
    idx.export_file(path)
    back = GzipIndex.import_file(path)

    assert back.finalized
    assert back.decompressed_size == idx.decompressed_size
    assert back.compressed_size == idx.compressed_size
    assert len(back) == len(idx)
    for a, b in zip(idx.points(), back.points()):
        assert a.compressed_bit == b.compressed_bit
        assert a.decompressed_byte == b.decompressed_byte
        assert a.flags == b.flags
        assert (a.window or b"") == (b.window or b"")


def test_multi_member_flags_survive_roundtrip_across_readers(rng, tmp_path):
    """Real multi-member gzip: FLAG_HAS_INTERIOR_MEMBER_END must survive the
    file roundtrip, because the second reader's fetcher uses it to refuse
    zlib delegation across member boundaries."""
    data = make_text(rng, 600_000)
    comp = multistream_gzip(data, 6, stream_size=100_000)

    r1 = ParallelGzipReader(comp, parallelization=2, chunk_size=256 << 10)
    assert r1.read() == data
    member_flags = [
        p.flags & FLAG_HAS_INTERIOR_MEMBER_END for p in r1.index.points()
    ]
    assert any(member_flags), "multi-member data must set interior-member-end flags"
    path = os.path.join(tmp_path, "multi.rpgzidx")
    r1.export_index(path)
    r1.close()

    imported = GzipIndex.import_file(path)
    assert [
        p.flags & FLAG_HAS_INTERIOR_MEMBER_END for p in imported.points()
    ] == member_flags

    r2 = ParallelGzipReader(comp, parallelization=2, chunk_size=256 << 10, index=path)
    # Fresh instance, imported index: no first pass, identical bytes.
    for off in (0, 99_990, 150_000, 599_000):
        r2.seek(off)
        assert r2.read(2000) == data[off : off + 2000]
    st = r2.stats()
    assert st["fetcher"]["nominal_tasks"] == 0
    assert st["fetcher"]["exact_tasks"] == 0
    r2.close()


def test_stored_block_zlib_unsafe_flags_survive_roundtrip(rng, tmp_path):
    """Stored-only deflate with interior split points exercises the
    FLAG_ZLIB_UNSAFE / FLAG_STORED_BLOCK path; a reader over the imported
    index must still produce exact bytes (unsafe chunks use the custom
    decoder, not zlib)."""
    data = make_base64(rng, 400_000)
    comp = stored_only_compress(data)

    # Big chunks + small spacing: several stored blocks per chunk, so the
    # interior split points land on stored-block boundaries.
    r1 = ParallelGzipReader(comp, parallelization=2, chunk_size=256 << 10,
                            index_spacing=60_000)
    assert r1.read() == data
    flags1 = [p.flags for p in r1.index.points()]
    assert any(f & FLAG_STORED_BLOCK for f in flags1)
    buf = io.BytesIO()
    r1.export_index(buf)
    r1.close()

    imported = GzipIndex.from_bytes(buf.getvalue())
    assert [p.flags for p in imported.points()] == flags1

    r2 = ParallelGzipReader(comp, parallelization=3, chunk_size=64 << 10,
                            index=imported)
    assert r2.read() == data
    r2.seek(123_456)
    assert r2.read(10_000) == data[123_456:133_456]
    r2.close()


def test_unfinalized_index_roundtrip(tmp_path):
    idx = GzipIndex()
    idx.add_point(SeekPoint(100, 0, b"", FLAG_STREAM_START))
    buf = io.BytesIO()
    idx.export_file(buf)
    back = GzipIndex.from_bytes(buf.getvalue())
    assert not back.finalized
    assert back.decompressed_size is None
    assert len(back) == 1


def test_import_rejects_bad_magic():
    from repro.core.errors import IndexError_

    with pytest.raises(IndexError_):
        GzipIndex.from_bytes(b"NOTANIDX" + b"\0" * 32)


def test_index_store_concurrent_same_key_puts_never_tear(rng, tmp_path):
    """Racing put() calls for the same identity (two handles on one archive
    closed concurrently) must each write their own tmp file — a shared tmp
    path could interleave writes and os.replace a torn blob into the store."""
    import threading

    from conftest import gzip_bytes, make_text
    from repro.service import IndexStore

    data = make_text(rng, 300_000)
    comp = gzip_bytes(data, 6)
    with ParallelGzipReader(comp, parallelization=2, chunk_size=64 << 10) as r:
        r.read()
        index = r.index

    store = IndexStore(str(tmp_path / "idx"))
    barrier = threading.Barrier(6)
    errors = []

    def put():
        try:
            barrier.wait(5)
            for _ in range(10):
                assert store.put(comp, index) is not None
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=put) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[0]
    got = store.get(comp)  # a torn blob would fail to parse here
    assert got is not None and len(got) == len(index)
    # no stray tmp files left behind
    leftovers = [f for f in os.listdir(tmp_path / "idx") if f.endswith(".tmp")]
    assert leftovers == []
