"""GzipIndex export/import across *separate* reader instances.

The roundtrip was previously only exercised implicitly (export -> from_bytes
in reader tests). These tests pin the serialization contract itself: every
SeekPoint field — including the FLAG_ZLIB_UNSAFE and
FLAG_HAS_INTERIOR_MEMBER_END flags the indexed fetcher dispatches on — must
survive a file roundtrip, and a fresh reader built from the imported file
must behave identically to the builder.
"""

import io
import os

import pytest

from repro.core import GzipIndex, ParallelGzipReader
from repro.core.index import (
    FLAG_HAS_INTERIOR_MEMBER_END,
    FLAG_STORED_BLOCK,
    FLAG_STREAM_START,
    FLAG_ZLIB_UNSAFE,
    SeekPoint,
)
from repro.core.synth import multistream_gzip, stored_only_compress

from conftest import gzip_bytes, make_base64, make_text


def test_synthetic_index_roundtrip_preserves_every_field(tmp_path):
    """All flag combinations + windows + finalization metadata."""
    idx = GzipIndex()
    flag_sets = [
        0,
        FLAG_STREAM_START,
        FLAG_HAS_INTERIOR_MEMBER_END,
        FLAG_STORED_BLOCK,
        FLAG_ZLIB_UNSAFE,
        FLAG_ZLIB_UNSAFE | FLAG_HAS_INTERIOR_MEMBER_END,
        FLAG_STREAM_START | FLAG_STORED_BLOCK | FLAG_ZLIB_UNSAFE,
    ]
    for i, flags in enumerate(flag_sets):
        window = bytes(range(256)) * 128 if i % 2 else b""
        idx.add_point(SeekPoint(i * 1000 + 3, i * 50_000, window, flags))
    idx.finalize(len(flag_sets) * 50_000, 123_456)

    path = os.path.join(tmp_path, "round.rpgzidx")
    idx.export_file(path)
    back = GzipIndex.import_file(path)

    assert back.finalized
    assert back.decompressed_size == idx.decompressed_size
    assert back.compressed_size == idx.compressed_size
    assert len(back) == len(idx)
    for a, b in zip(idx.points(), back.points()):
        assert a.compressed_bit == b.compressed_bit
        assert a.decompressed_byte == b.decompressed_byte
        assert a.flags == b.flags
        assert (a.window or b"") == (b.window or b"")


def test_multi_member_flags_survive_roundtrip_across_readers(rng, tmp_path):
    """Real multi-member gzip: FLAG_HAS_INTERIOR_MEMBER_END must survive the
    file roundtrip, because the second reader's fetcher uses it to refuse
    zlib delegation across member boundaries."""
    data = make_text(rng, 600_000)
    comp = multistream_gzip(data, 6, stream_size=100_000)

    r1 = ParallelGzipReader(comp, parallelization=2, chunk_size=256 << 10)
    assert r1.read() == data
    member_flags = [
        p.flags & FLAG_HAS_INTERIOR_MEMBER_END for p in r1.index.points()
    ]
    assert any(member_flags), "multi-member data must set interior-member-end flags"
    path = os.path.join(tmp_path, "multi.rpgzidx")
    r1.export_index(path)
    r1.close()

    imported = GzipIndex.import_file(path)
    assert [
        p.flags & FLAG_HAS_INTERIOR_MEMBER_END for p in imported.points()
    ] == member_flags

    r2 = ParallelGzipReader(comp, parallelization=2, chunk_size=256 << 10, index=path)
    # Fresh instance, imported index: no first pass, identical bytes.
    for off in (0, 99_990, 150_000, 599_000):
        r2.seek(off)
        assert r2.read(2000) == data[off : off + 2000]
    st = r2.stats()
    assert st["fetcher"]["nominal_tasks"] == 0
    assert st["fetcher"]["exact_tasks"] == 0
    r2.close()


def test_stored_block_zlib_unsafe_flags_survive_roundtrip(rng, tmp_path):
    """Stored-only deflate with interior split points exercises the
    FLAG_ZLIB_UNSAFE / FLAG_STORED_BLOCK path; a reader over the imported
    index must still produce exact bytes (unsafe chunks use the custom
    decoder, not zlib)."""
    data = make_base64(rng, 400_000)
    comp = stored_only_compress(data)

    # Big chunks + small spacing: several stored blocks per chunk, so the
    # interior split points land on stored-block boundaries.
    r1 = ParallelGzipReader(comp, parallelization=2, chunk_size=256 << 10,
                            index_spacing=60_000)
    assert r1.read() == data
    flags1 = [p.flags for p in r1.index.points()]
    assert any(f & FLAG_STORED_BLOCK for f in flags1)
    buf = io.BytesIO()
    r1.export_index(buf)
    r1.close()

    imported = GzipIndex.from_bytes(buf.getvalue())
    assert [p.flags for p in imported.points()] == flags1

    r2 = ParallelGzipReader(comp, parallelization=3, chunk_size=64 << 10,
                            index=imported)
    assert r2.read() == data
    r2.seek(123_456)
    assert r2.read(10_000) == data[123_456:133_456]
    r2.close()


def test_unfinalized_index_roundtrip(tmp_path):
    idx = GzipIndex()
    idx.add_point(SeekPoint(100, 0, b"", FLAG_STREAM_START))
    buf = io.BytesIO()
    idx.export_file(buf)
    back = GzipIndex.from_bytes(buf.getvalue())
    assert not back.finalized
    assert back.decompressed_size is None
    assert len(back) == 1


def test_import_rejects_bad_magic():
    from repro.core.errors import IndexError_

    with pytest.raises(IndexError_):
        GzipIndex.from_bytes(b"NOTANIDX" + b"\0" * 32)


# ---------------------------------------------------------------------------
# versioned header: codec tags + legacy (pre-tag) import
# ---------------------------------------------------------------------------


def _legacy_v1_blob(points, finalized, dec_size, comp_size) -> bytes:
    """Hand-built RPGZIDX1 blob, exactly as pre-tag sessions wrote it: magic,
    JSON meta WITHOUT a "codec" key, then <QQII>+zlib(window) per point."""
    import json
    import struct
    import zlib

    meta = {
        "finalized": finalized,
        "decompressed_size": dec_size,
        "compressed_size": comp_size,
        "n_points": len(points),
    }
    blob = json.dumps(meta).encode()
    out = [b"RPGZIDX1", struct.pack("<I", len(blob)), blob]
    for cb, db, flags, window in points:
        wz = zlib.compress(window or b"", 6)
        out.append(struct.pack("<QQII", cb, db, flags, len(wz)))
        out.append(wz)
    return b"".join(out)


def test_codec_tag_roundtrips_versioned_header():
    for tag in ("deflate", "bgzf", "zstd"):
        idx = GzipIndex(codec_tag=tag)
        idx.add_point(SeekPoint(8, 0, b"", FLAG_STREAM_START))
        idx.finalize(1000, 500)
        blob = idx.to_bytes()
        assert blob.startswith(b"RPGZIDX2")
        back = GzipIndex.from_bytes(blob)
        assert back.codec_tag == tag
        assert back.finalized and back.decompressed_size == 1000


def test_legacy_pre_tag_blob_imports_as_deflate():
    """Blobs written before the codec tag existed (RPGZIDX1, no "codec" meta
    key) must import as deflate with every point intact — a warm store from
    an old session keeps working."""
    points = [
        (8, 0, FLAG_STREAM_START, b""),
        (100_003, 50_000, FLAG_ZLIB_UNSAFE, bytes(range(256)) * 4),
    ]
    blob = _legacy_v1_blob(points, True, 120_000, 60_000)
    back = GzipIndex.from_bytes(blob)
    assert back.codec_tag == "deflate"
    assert back.finalized and back.decompressed_size == 120_000
    got = back.points()
    assert len(got) == 2
    assert (got[1].compressed_bit, got[1].decompressed_byte, got[1].flags) == (
        100_003, 50_000, FLAG_ZLIB_UNSAFE,
    )
    assert got[1].window == bytes(range(256)) * 4


def test_index_codec_mismatch_is_refused(rng):
    """A zstd-tagged index can never be served by a deflate reader: the
    chunk semantics differ, so the open must fail loudly, not decode junk."""
    from repro.core.errors import RapidgzipError

    data = make_text(rng, 50_000)
    comp = gzip_bytes(data, 6)
    with ParallelGzipReader(comp, parallelization=1) as r:
        r.read()
        blob = GzipIndex.from_bytes(r.index.to_bytes())
    blob.codec_tag = "zstd"
    with pytest.raises(RapidgzipError):
        ParallelGzipReader(comp, parallelization=1, codec="deflate",
                           index=blob.to_bytes())


def test_versioned_header_property_roundtrip():
    """Property test over synthetic indexes: to_bytes/from_bytes preserves
    the codec tag, finalization metadata, and every point field."""
    from hypothesis import given, settings
    from hypothesis import strategies as st

    point = st.tuples(
        st.integers(0, 2**40), st.integers(0, 2**40),
        st.integers(0, 15), st.binary(max_size=512),
    )

    @settings(max_examples=25, deadline=None)
    @given(
        tag=st.sampled_from(["deflate", "bgzf", "zstd"]),
        raw_points=st.lists(point, max_size=8),
        finalized=st.booleans(),
    )
    def check(tag, raw_points, finalized):
        idx = GzipIndex(codec_tag=tag)
        for cb, db, flags, window in sorted(raw_points, key=lambda p: (p[1], p[0])):
            idx.add_point(SeekPoint(cb, db, window, flags))
        if finalized:
            idx.finalize(2**41, 2**40)
        back = GzipIndex.from_bytes(idx.to_bytes())
        assert back.codec_tag == tag
        assert back.finalized == idx.finalized
        assert len(back) == len(idx)
        for a, b in zip(idx.points(), back.points()):
            assert (a.compressed_bit, a.decompressed_byte, a.flags) == (
                b.compressed_bit, b.decompressed_byte, b.flags,
            )
            assert (a.window or b"") == (b.window or b"")

    check()


def test_index_store_concurrent_same_key_puts_never_tear(rng, tmp_path):
    """Racing put() calls for the same identity (two handles on one archive
    closed concurrently) must each write their own tmp file — a shared tmp
    path could interleave writes and os.replace a torn blob into the store."""
    import threading

    from conftest import gzip_bytes, make_text
    from repro.service import IndexStore

    data = make_text(rng, 300_000)
    comp = gzip_bytes(data, 6)
    with ParallelGzipReader(comp, parallelization=2, chunk_size=64 << 10) as r:
        r.read()
        index = r.index

    store = IndexStore(str(tmp_path / "idx"))
    barrier = threading.Barrier(6)
    errors = []

    def put():
        try:
            barrier.wait(5)
            for _ in range(10):
                assert store.put(comp, index) is not None
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=put) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[0]
    got = store.get(comp)  # a torn blob would fail to parse here
    assert got is not None and len(got) == len(index)
    # no stray tmp files left behind
    leftovers = [f for f in os.listdir(tmp_path / "idx") if f.endswith(".tmp")]
    assert leftovers == []
