"""ArchiveServer: multi-file, multi-client random access (service layer).

Acceptance demo from the issue: >= 8 concurrent client threads over >= 3
distinct gzip files must return byte-exact ranges under a shared cache
budget smaller than the sum of per-reader defaults, and a warm IndexStore
reopen must perform zero speculative (nominal) chunk tasks, verified via
the aggregated fleet stats.
"""

import gzip as _gzip
import threading
import zlib

import numpy as np
import pytest

from repro.service import (
    ArchiveServer,
    FairExecutor,
    IndexStore,
    file_identity,
)

from conftest import gzip_bytes, make_base64, make_random, make_text

N_FILES = 3
N_CLIENTS = 8


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0xA11CE)
    datas = [
        make_text(rng, 500_000),
        make_base64(rng, 400_000),
        make_random(rng, 200_000) + make_text(rng, 200_000),
    ]
    comps = [gzip_bytes(d, 6) for d in datas]
    # sanity: zlib ground truth
    for d, c in zip(datas, comps):
        assert zlib.decompress(c, 31) == d
    return datas, comps


def _hammer(server, handles, datas, seed, n_requests, errors, req_size=20_000):
    rng = np.random.default_rng(seed)
    try:
        for _ in range(n_requests):
            i = int(rng.integers(0, len(handles)))
            off = int(rng.integers(0, len(datas[i])))
            got = server.read_range(handles[i], off, req_size)
            want = datas[i][off : off + req_size]
            if got != want:
                raise AssertionError(
                    "mismatch file=%d off=%d got=%d want=%d" % (i, off, len(got), len(want))
                )
    except BaseException as exc:  # noqa: BLE001
        errors.append(exc)


def test_concurrent_clients_byte_exact_under_shared_budget(corpus, tmp_path):
    datas, comps = corpus
    # Per-reader defaults would be ~2*parallelization chunks each, i.e.
    # N_FILES * 8 * chunk_bytes >> this budget: 2 MiB for the whole fleet.
    store = IndexStore(str(tmp_path / "indexes"))
    server = ArchiveServer(
        max_workers=4,
        cache_budget_bytes=2 << 20,
        index_store=store,
        chunk_size=128 << 10,
        reader_parallelization=4,
    )
    with server:
        handles = [
            server.open(c, tenant="client%d" % (i % 4)) for i, c in enumerate(comps)
        ]
        errors: list = []
        threads = [
            threading.Thread(
                target=_hammer, args=(server, handles, datas, 100 + t, 12, errors)
            )
            for t in range(N_CLIENTS)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors[0]

        m = server.metrics()
        assert m["fleet"]["readers"] == N_FILES
        # The budget was respected while serving all that traffic.
        pool = m["cache_pool"]
        assert pool["tiers"]["access"]["held"] <= pool["tiers"]["access"]["budget"]
        assert pool["tiers"]["prefetch"]["held"] <= pool["tiers"]["prefetch"]["budget"]
        # Work actually flowed through the shared scheduler.
        assert m["scheduler"]["done"] > 0
        assert m["scheduler"]["queued"] == 0
        assert m["fleet"]["fetcher"]["bytes_decompressed"] > 0

        # Finalize + persist every index for the warm test below.
        for h in handles:
            server.size(h)
        server.close_all()
    assert len(store.keys()) == N_FILES


def test_warm_index_store_reopen_zero_nominal_tasks(corpus, tmp_path):
    datas, comps = corpus
    store_dir = str(tmp_path / "indexes")

    # Cold pass: build + persist indexes.
    with ArchiveServer(
        max_workers=4, cache_budget_bytes=2 << 20,
        index_store=IndexStore(store_dir), chunk_size=128 << 10,
    ) as server:
        for c in comps:
            h = server.open(c)
            server.size(h)
            server.close(h)
        cold = server.metrics()

    # Warm pass: fresh server, fresh readers — same traffic, zero
    # speculative work (the issue's acceptance criterion).
    with ArchiveServer(
        max_workers=4, cache_budget_bytes=2 << 20,
        index_store=IndexStore(store_dir), chunk_size=128 << 10,
    ) as server:
        handles = [server.open(c) for c in comps]
        errors: list = []
        threads = [
            threading.Thread(
                target=_hammer, args=(server, handles, datas, 500 + t, 8, errors)
            )
            for t in range(N_CLIENTS)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors[0]

        m = server.metrics()
        f = m["fleet"]["fetcher"]
        assert f["nominal_tasks"] == 0, "warm reopen must skip the speculative pass"
        assert f["exact_tasks"] == 0
        assert f["indexed_tasks"] > 0
        assert m["index_store"]["hits"] == N_FILES
        for h in handles:
            assert server.stat(h).index_was_warm


def test_stat_and_lazy_open(corpus):
    _, comps = corpus
    with ArchiveServer(cache_budget_bytes=1 << 20, max_workers=2) as server:
        h = server.open(comps[0])
        st = server.stat(h)
        assert not st.opened and st.reads == 0  # nothing read yet: lazy
        data = server.read_range(h, 0, 100)
        assert len(data) == 100
        st = server.stat(h)
        assert st.opened and st.reads == 1 and st.bytes_served == 100
        server.close(h)
        with pytest.raises(KeyError):
            server.read_range(h, 0, 1)


def test_read_range_validates_arguments(corpus):
    _, comps = corpus
    with ArchiveServer(cache_budget_bytes=1 << 20, max_workers=2) as server:
        h = server.open(comps[0])
        with pytest.raises(ValueError):
            server.read_range(h, -1, 10)
        with pytest.raises(ValueError):
            server.read_range(h, 0, -10)
        assert server.read_range(h, 10**12, 100) == b""  # past EOF: empty


def test_fair_executor_round_robin_and_teardown():
    ex = FairExecutor(2)
    order: list = []
    lock = threading.Lock()

    def task(tag):
        with lock:
            order.append(tag)

    # Queue a burst for a hog tenant, then one task for a small tenant; the
    # round-robin dispatcher must not serve all 20 hog tasks first.
    futs = [ex.submit("hog", task, ("hog", i)) for i in range(20)]
    futs.append(ex.submit("small", task, ("small", 0)))
    for f in futs:
        f.result()
    small_pos = order.index(("small", 0))
    assert small_pos < 10, f"small tenant starved: position {small_pos}"

    snap = ex.snapshot()
    assert snap["done"] == 21
    assert snap["dispatch_per_tenant"]["small"] == 1
    ex.shutdown(wait=True)
    with pytest.raises(RuntimeError):
        ex.submit("hog", task, ("hog", 99))


def test_tenant_view_shutdown_cancels_only_own_queue():
    ex = FairExecutor(1)
    release = threading.Event()
    started = threading.Event()

    def blocker():
        started.set()
        release.wait(5)

    # Two views of the same tenant: closing one reader must not cancel the
    # tenant's other readers' queued work.
    va, va2 = ex.view("a"), ex.view("a")
    ex.submit("a", blocker)
    started.wait(5)
    fa = va.submit(lambda: "a2")
    fa2 = va2.submit(lambda: "a3")
    fb = ex.submit("b", lambda: "b1")
    va.shutdown(wait=False, cancel_futures=True)
    release.set()
    assert fb.result(5) == "b1"
    assert fa.cancelled()
    assert fa2.result(5) == "a3"
    ex.shutdown(wait=True)


def test_file_identity_distinguishes_sources(tmp_path):
    k1 = file_identity(b"x" * 100_000)
    k2 = file_identity(b"x" * 100_000)
    k3 = file_identity(b"y" * 100_000)
    assert k1 == k2 != k3
    p = tmp_path / "a.gz"
    p.write_bytes(_gzip.compress(b"hello"))
    kp = file_identity(str(p))
    assert kp == file_identity(str(p))
    assert kp != k1


def test_corrupt_source_does_not_leak_pool_registrations():
    with ArchiveServer(cache_budget_bytes=1 << 20, max_workers=2) as server:
        h = server.open(b"this is not gzip data at all")
        for _ in range(5):  # client retries must not grow the pool registry
            with pytest.raises(Exception):
                server.read_range(h, 0, 100)
        snap = server.cache_pool.snapshot()
        assert snap["n_caches"] == 0
        assert server.cache_pool.bytes_held() == 0


def test_stat_is_lock_free_under_held_entry_lock(corpus):
    """stat() must serve telemetry while the entry (lifecycle) lock is held
    — e.g. during a long serialized read or a slow lazy open."""
    _, comps = corpus
    with ArchiveServer(cache_budget_bytes=1 << 20, max_workers=2) as server:
        h = server.open(comps[0])
        server.read_range(h, 0, 100)  # open the reader
        entry = server._entries[h]
        acquired = threading.Event()
        release = threading.Event()

        def holder():
            with entry.lock:
                acquired.set()
                release.wait(10)

        t = threading.Thread(target=holder)
        t.start()
        assert acquired.wait(5)
        try:
            done = threading.Event()
            result: list = []

            def do_stat():
                result.append(server.stat(h))
                done.set()

            s = threading.Thread(target=do_stat)
            s.start()
            # must complete promptly despite the held entry lock
            assert done.wait(2), "stat() blocked behind the entry lock"
            assert result[0].opened and result[0].reads == 1
        finally:
            release.set()
            t.join(5)


def test_read_range_serialized_mode_still_correct(corpus):
    """The legacy one-cursor discipline stays available (A/B baseline) and
    is counted separately in the service gauges."""
    datas, comps = corpus
    with ArchiveServer(cache_budget_bytes=2 << 20, max_workers=2,
                       chunk_size=128 << 10) as server:
        h = server.open(comps[0])
        for off in (0, 100_000, 499_000, 17):
            got = server.read_range(h, off, 5000, serialized=True)
            assert got == datas[0][off : off + 5000]
        got = server.read_range(h, 250_000, 5000)  # lock-free path
        assert got == datas[0][250_000:255_000]
        svc = server.metrics()["service"]
        assert svc["reads_started"] == 5
        assert svc["reads_serialized"] == 4
        assert svc["reads_in_flight"] == 0


def test_read_many_and_concurrent_reads_one_handle(corpus):
    """N threads on ONE handle: byte-exact, and the metrics carry the new
    frontier/lock sections."""
    datas, comps = corpus
    with ArchiveServer(cache_budget_bytes=2 << 20, max_workers=4,
                       chunk_size=128 << 10) as server:
        h = server.open(comps[0])
        reqs = [(h, off, 3000) for off in (0, 77, 300_000, 499_500)]
        assert server.read_many(reqs) == [
            datas[0][o : o + n] for _, o, n in reqs
        ]
        errors: list = []
        threads = [
            threading.Thread(
                target=_hammer, args=(server, [h], [datas[0]], 900 + t, 10, errors)
            )
            for t in range(N_CLIENTS)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not any(t.is_alive() for t in threads), "read_range deadlocked"
        assert not errors, errors[0]
        m = server.metrics()
        assert m["fleet"]["frontier"]["lock_acquires"] > 0  # cold first pass
        assert m["service"]["reads_in_flight"] == 0
        # warm it, then hammer again: indexed reads take no frontier lock
        server.size(h)
        before = server.metrics()["fleet"]["frontier"]["lock_acquires"]
        errors2: list = []
        threads = [
            threading.Thread(
                target=_hammer, args=(server, [h], [datas[0]], 950 + t, 10, errors2)
            )
            for t in range(4)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not errors2, errors2[0]
        after = server.metrics()["fleet"]["frontier"]
        # the warm (finalized-index) hammer is fully lock-free
        assert after["lock_acquires"] == before
        assert server.stat(h).index_finalized


def test_close_drains_in_flight_reads_before_closing_fd(corpus, tmp_path):
    """close() racing a lock-free read must wait for it: the read either
    completes on a live file descriptor or is refused upfront with
    KeyError — never EBADF (or, after fd reuse, another file's bytes)."""
    import time as _t

    datas, _ = corpus
    path = tmp_path / "race.gz"
    path.write_bytes(_gzip.compress(datas[0], 6))
    with ArchiveServer(cache_budget_bytes=2 << 20, max_workers=2,
                       chunk_size=128 << 10) as server:
        h = server.open(str(path))
        server.read_range(h, 0, 1)  # open the reader eagerly
        entry = server._entries[h]
        real_pread = entry.reader.pread

        started = threading.Event()

        def slow_pread(offset, size):
            started.set()
            _t.sleep(0.15)  # close() arrives inside this window
            return real_pread(offset, size)

        entry.reader.pread = slow_pread
        results: list = []
        errors: list = []

        def reading():
            try:
                results.append(server.read_range(h, 1000, 5000))
            except BaseException as exc:  # noqa: BLE001
                errors.append(exc)

        t = threading.Thread(target=reading)
        t.start()
        assert started.wait(5)
        t_close0 = _t.perf_counter()
        server.close(h)  # must block until the in-flight read drains
        close_dt = _t.perf_counter() - t_close0
        t.join(10)
        assert not t.is_alive()
        assert not errors, errors[0]
        assert results[0] == datas[0][1000:6000]
        assert close_dt > 0.05, "close() did not wait for the in-flight read"
        # and post-close reads are refused cleanly
        with pytest.raises(KeyError):
            server.read_range(h, 0, 10)


def test_close_then_read_raises_cleanly(corpus):
    _, comps = corpus
    with ArchiveServer(cache_budget_bytes=1 << 20, max_workers=2) as server:
        h = server.open(comps[0])
        server.read_range(h, 0, 10)
        server.close(h)
        with pytest.raises(KeyError):
            server.read_range(h, 0, 10)
        # closed reader released its caches back to the pool
        assert server.cache_pool.snapshot()["n_caches"] == 0
