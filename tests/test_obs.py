"""Observability subsystem: spans, histograms, Prometheus exposition.

Covers the tracer units (context propagation across threads and the wire,
ring-buffer capacity, Chrome dump format), the log-bucket histogram math,
snapshot sanitization, exposition-format validity, the metrics satellites
(ts/uptime/snapshot_seq, JSON round-trips of every layer's snapshot), the
documented counter invariants across sync/async/gateway serving paths, and
the acceptance trace: a fleet pread that fails over mid-operation yields
ONE stitched trace whose spans cross two gateways via the traceparent
header.

Everything is hermetic (loopback only); gateway/fleet tests carry the
``gateway`` marker like the rest of the wire suite.
"""

import asyncio
import json
import math
import re
import threading
import time

import numpy as np
import pytest

from conftest import gzip_bytes, make_text
from repro import obs
from repro.obs import hist as obs_hist
from repro.obs import trace as obs_trace
from repro.obs.hist import BUCKET_BOUNDS_US, LogHistogram, bucket_index, merge_snapshots
from repro.obs.prom import render_prometheus
from repro.obs.sanitize import sanitize_snapshot
from repro.service import ArchiveServer, AsyncArchiveServer
from repro.service.metrics import format_summary

RUN_TIMEOUT = 60


def _run(coro):
    return asyncio.run(asyncio.wait_for(coro, RUN_TIMEOUT))


@pytest.fixture(autouse=True)
def _clean_tracing():
    """Every test starts and ends with tracing disabled and empty state —
    the tracer is process-global, so leakage would couple tests."""
    obs_trace.disable_tracing()
    obs_trace.reset_tracing()
    obs_hist.reset_histograms()
    yield
    obs_trace.disable_tracing()
    obs_trace.reset_tracing()
    obs_hist.reset_histograms()


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0x0B5)
    data = make_text(rng, 300_000)
    return data, gzip_bytes(data, 6)


# ---------------------------------------------------------------------------
# tracer units
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    s1 = obs_trace.span("a", {"k": 1})
    s2 = obs_trace.span("b")
    assert s1 is s2  # one shared object: zero allocation while disabled
    with s1 as sp:
        sp.set_attr("x", 1)  # must not raise
        assert obs_trace.capture() is None
        assert obs_trace.current_traceparent() is None
    assert obs_trace.recorded_spans() == []


def test_span_nesting_assigns_one_trace():
    obs_trace.enable_tracing()
    with obs_trace.span("outer") as outer:
        with obs_trace.span("mid") as mid:
            with obs_trace.span("inner") as inner:
                pass
    spans = {s["name"]: s for s in obs_trace.recorded_spans()}
    assert set(spans) == {"outer", "mid", "inner"}
    assert spans["outer"]["parent_id"] is None
    assert spans["mid"]["parent_id"] == outer.span_id
    assert spans["inner"]["parent_id"] == mid.span_id
    assert len({s["trace_id"] for s in spans.values()}) == 1
    assert inner.trace_id == outer.trace_id
    # durations nest: outer covers mid covers inner
    assert spans["outer"]["dur_s"] >= spans["mid"]["dur_s"] >= spans["inner"]["dur_s"]


def test_span_records_error_attr():
    obs_trace.enable_tracing()
    with pytest.raises(ValueError):
        with obs_trace.span("boom"):
            raise ValueError("x")
    (rec,) = obs_trace.recorded_spans()
    assert rec["attrs"]["error"] == "ValueError"


def test_traceparent_roundtrip():
    obs_trace.enable_tracing()
    with obs_trace.span("root") as sp:
        tp = obs_trace.current_traceparent()
        assert re.fullmatch(r"00-[0-9a-f]{32}-[0-9a-f]{16}-01", tp)
        assert obs_trace.parse_traceparent(tp) == (sp.trace_id, sp.span_id)
    assert obs_trace.current_traceparent() is None


@pytest.mark.parametrize("bad", [
    None, "", "garbage", "00-short-short-01",
    "00-" + "0" * 32 + "-" + "1" * 16 + "-01",   # all-zero trace id
    "00-" + "1" * 32 + "-" + "0" * 16 + "-01",   # all-zero span id
    "00-" + "g" * 32 + "-" + "1" * 16 + "-01",   # non-hex
])
def test_parse_traceparent_rejects_malformed(bad):
    assert obs_trace.parse_traceparent(bad) is None


def test_capture_attach_carries_context_across_threads():
    obs_trace.enable_tracing()
    carried = {}

    def worker(ctx):
        with obs_trace.attach(ctx), obs_trace.span("child"):
            carried["tp"] = obs_trace.current_traceparent()

    with obs_trace.span("parent") as parent:
        t = threading.Thread(target=worker, args=(obs_trace.capture(),))
        t.start()
        t.join(timeout=10)
    spans = {s["name"]: s for s in obs_trace.recorded_spans()}
    assert spans["child"]["trace_id"] == parent.trace_id
    assert spans["child"]["parent_id"] == parent.span_id
    assert spans["child"]["thread"] != spans["parent"]["thread"]


def test_ring_buffer_capacity_and_drop_accounting():
    obs_trace.enable_tracing(capacity=8)
    for i in range(20):
        with obs_trace.span("s%d" % i):
            pass
    stats = obs_trace.tracing_stats()
    assert stats["recorded"] == 8 and stats["recorded_total"] == 20
    assert stats["dropped"] == 12
    names = [s["name"] for s in obs_trace.recorded_spans()]
    assert names == ["s%d" % i for i in range(12, 20)]  # oldest evicted


def test_dump_trace_chrome_format(tmp_path):
    obs_trace.enable_tracing()
    with obs_trace.span("work", {"size": 7}):
        time.sleep(0.001)
    path = tmp_path / "trace.json"
    trace = obs_trace.dump_trace(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == trace
    events = trace["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    slices = [e for e in events if e["ph"] == "X"]
    assert meta and meta[0]["name"] == "thread_name"
    (ev,) = slices
    assert ev["name"] == "work" and ev["dur"] >= 1000  # microseconds
    assert ev["args"]["size"] == 7
    assert re.fullmatch(r"[0-9a-f]{32}", ev["args"]["trace_id"])


def test_timed_observes_histogram_even_while_disabled():
    with obs_trace.timed("boundary"):
        pass
    snap = obs_hist.histogram_snapshots()
    assert snap["boundary"]["count"] == 1
    assert obs_trace.recorded_spans() == []  # no span while disabled
    obs_trace.enable_tracing()
    with obs_trace.timed("boundary"):
        pass
    assert [s["name"] for s in obs_trace.recorded_spans()] == ["boundary"]
    assert obs_hist.histogram_snapshots()["boundary"]["count"] == 2


# ---------------------------------------------------------------------------
# histogram math
# ---------------------------------------------------------------------------

def test_bucket_index_bounds():
    # bucket i covers (2^(i-1), 2^i] microseconds
    assert bucket_index(0.0) == 0
    assert bucket_index(1e-6) == 0
    assert bucket_index(1.5e-6) == 1
    assert bucket_index(2e-6) == 1
    assert bucket_index(2.0001e-6) == 2
    assert bucket_index(1.0) == 20          # 2^20 µs ≈ 1.05 s
    assert bucket_index(1e9) == len(BUCKET_BOUNDS_US)  # +Inf overflow


def test_histogram_snapshot_percentiles_are_conservative():
    h = LogHistogram()
    values = [3e-6] * 50 + [100e-6] * 40 + [5e-3] * 10
    for v in values:
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 100
    assert snap["sum_s"] == pytest.approx(sum(values))
    # reported pXX is the covering bucket's upper bound: >= true value
    assert snap["p50_s"] >= 3e-6 and snap["p50_s"] <= 8e-6
    assert snap["p90_s"] >= 100e-6 and snap["p90_s"] <= 256e-6
    assert snap["p99_s"] >= 5e-3
    # cumulative buckets: monotone, last equals count
    cums = [c for _, c in snap["buckets"]]
    assert cums == sorted(cums)
    assert cums[-1] == 100
    bounds = [b for b, _ in snap["buckets"]]
    assert bounds == sorted(bounds)


def test_histogram_merge_snapshot_equals_single_stream():
    a, b, both = LogHistogram(), LogHistogram(), LogHistogram()
    rng = np.random.default_rng(7)
    for v in rng.uniform(1e-6, 1e-2, 200):
        a.observe(v)
        both.observe(v)
    for v in rng.uniform(1e-5, 1.0, 100):
        b.observe(v)
        both.observe(v)
    merged = merge_snapshots(a.snapshot(), b.snapshot())
    want = both.snapshot()
    assert merged["count"] == want["count"]
    assert merged["sum_s"] == pytest.approx(want["sum_s"])
    assert merged["buckets"] == want["buckets"]
    assert (merged["p50_s"], merged["p99_s"]) == (want["p50_s"], want["p99_s"])


# ---------------------------------------------------------------------------
# sanitize
# ---------------------------------------------------------------------------

def test_sanitize_snapshot_makes_everything_json_safe():
    raw = {
        ("chunk", 3): {"set": {1, 2}, "nan": float("nan"), "inf": float("inf")},
        "np": np.int64(7),
        "npf": np.float32(1.5),
        "bytes": b"\xff\x00ab",
        "tuple": (1, 2.0, "x"),
        "ok": {"n": 3, "flag": True, "none": None},
    }
    clean = sanitize_snapshot(raw)
    text = json.dumps(clean)  # must not raise
    back = json.loads(text)
    assert back["('chunk', 3)"]["nan"] is None
    assert back["('chunk', 3)"]["inf"] is None
    assert sorted(back["('chunk', 3)"]["set"]) == [1, 2]
    assert back["np"] == 7 and back["npf"] == 1.5
    assert back["tuple"] == [1, 2.0, "x"]
    assert back["ok"] == {"n": 3, "flag": True, "none": None}


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

# One exposition line: name{labels} value  (value: int/float/exponent form)
_PROM_LINE = re.compile(
    r"^[a-zA-Z_][a-zA-Z0-9_]*"
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})?"
    r" -?[0-9.e+-]+$"
)


def _assert_valid_exposition(text):
    assert text.endswith("\n")
    for line in text.rstrip("\n").split("\n"):
        if line.startswith("#"):
            assert re.match(r"^# (TYPE|HELP) [a-zA-Z_][a-zA-Z0-9_]*", line), line
        else:
            assert _PROM_LINE.match(line), "bad exposition line: %r" % line


def test_render_prometheus_names_labels_and_histograms():
    h = LogHistogram()
    for v in (1e-5, 2e-4, 3e-3):
        h.observe(v)
    snap = {
        "ts": 123.5,
        "scheduler": {"submitted": 10, "done": 10, "fairness": "drr"},
        "per_file": {"f0": {"reads": 4, "codec": "gzip"}},
        "admission": {"t1": {"admitted": 2, "in_flight": 0}},
        "obs": {
            "tracing": {"enabled": False, "recorded": 0},
            "histograms": {"server.read_range": h.snapshot()},
            "slow_requests": [{"trace_id": "x", "spans": []}],
        },
    }
    text = render_prometheus(snap)
    _assert_valid_exposition(text)
    assert "repro_ts 123.5" in text
    # sibling string field rides along as a label on the numeric samples
    assert 'repro_scheduler_submitted{fairness="drr"} 10' in text
    # string field became a label, not a sample; per_file key became handle=
    assert 'repro_file_reads{codec="gzip",handle="f0"} 4' in text
    assert "fairness" not in [l.split("{")[0] for l in text.splitlines()]
    assert 'repro_admission_admitted{tenant="t1"} 2' in text
    # histogram family: TYPE histogram, cumulative buckets, +Inf, sum/count
    assert "# TYPE repro_latency_seconds histogram" in text
    bucket_lines = [
        l for l in text.splitlines()
        if l.startswith("repro_latency_seconds_bucket") and "server.read_range" in l
    ]
    assert bucket_lines[-1].startswith(
        'repro_latency_seconds_bucket{le="+Inf",span="server.read_range"} 3'
    )
    cums = [int(l.rsplit(" ", 1)[1]) for l in bucket_lines]
    assert cums == sorted(cums) and cums[-1] == 3
    assert 'repro_latency_seconds_count{span="server.read_range"} 3' in text
    assert 'repro_latency_seconds_sum{span="server.read_range"}' in text
    # the slow-request span trees are not samples
    assert "slow_requests" not in text


def test_render_prometheus_drops_non_finite_and_renders_bools():
    text = render_prometheus({"a": float("nan"), "b": True, "c": float("inf")})
    _assert_valid_exposition(text)
    assert "repro_a" not in text and "repro_c" not in text
    assert "repro_b 1" in text


# ---------------------------------------------------------------------------
# metrics satellites: ts/uptime/seq, summary line, slow-request log
# ---------------------------------------------------------------------------

def test_server_metrics_ts_uptime_and_monotone_seq(corpus):
    data, comp = corpus
    with ArchiveServer(cache_budget_bytes=2 << 20, max_workers=2) as server:
        h = server.open(comp)
        server.read_range(h, 100, 1000)
        m1 = server.metrics()
        m2 = server.metrics()
        assert m2["snapshot_seq"] == m1["snapshot_seq"] + 1
        assert abs(m1["ts"] - time.time()) < 60
        assert 0.0 <= m1["uptime_s"] <= m2["uptime_s"]
        # the obs section is always present, with the read boundary timed
        assert m1["obs"]["histograms"]["server.read_range"]["count"] >= 1
        assert m1["obs"]["tracing"]["enabled"] is False
        summary = format_summary(m2)
        assert summary.startswith("snapshot #%d at ts=" % m2["snapshot_seq"])
        assert "obs: tracing off" in summary
        assert "read_range p50=" in summary


def test_slow_request_log_attaches_span_tree(corpus):
    data, comp = corpus
    obs_trace.enable_tracing()
    with ArchiveServer(
        cache_budget_bytes=2 << 20, max_workers=2, slow_request_s=0.0
    ) as server:
        h = server.open(comp)
        server.read_range(h, 0, 2000)
        m = server.metrics()
        slow = m["obs"]["slow_requests"]
        assert len(slow) >= 1
        entry = slow[-1]
        assert entry["handle"] == h and entry["size"] == 2000
        assert entry["duration_s"] >= 0.0
        assert re.fullmatch(r"[0-9a-f]{32}", entry["trace_id"])
        names = {s["name"] for s in entry["spans"]}
        assert "server.read_range" in names
        assert "reader.pread" in names  # the tree crosses into the core
        json.dumps(m, default=str)  # the whole snapshot stays serializable


def test_slow_request_log_disabled_with_none(corpus):
    data, comp = corpus
    with ArchiveServer(
        cache_budget_bytes=2 << 20, max_workers=2, slow_request_s=None
    ) as server:
        h = server.open(comp)
        server.read_range(h, 0, 2000)
        assert server.metrics()["obs"]["slow_requests"] == []


# ---------------------------------------------------------------------------
# JSON serializability: every layer's snapshot round-trips
# ---------------------------------------------------------------------------

def _assert_json_roundtrip(snapshot, where):
    clean = sanitize_snapshot(snapshot)
    text = json.dumps(clean)
    assert json.loads(text) == clean, where


def test_every_layer_snapshot_is_json_serializable(corpus, tmp_path):
    data, comp = corpus
    with ArchiveServer(cache_budget_bytes=2 << 20, max_workers=2) as server:
        h = server.open(comp)
        server.read_range(h, 5000, 3000)
        m = server.metrics()
        # metrics() must be directly dumpable — sanitize must be a no-op
        # guard for exotic stats, not a crutch the normal path depends on.
        assert json.loads(json.dumps(m)) == json.loads(json.dumps(sanitize_snapshot(m)))
        _assert_json_roundtrip(m, "ArchiveServer.metrics")
        _assert_json_roundtrip(server.stat(h).as_dict(), "HandleStat.as_dict")
    _assert_json_roundtrip(obs_trace.tracing_stats(), "tracing_stats")
    _assert_json_roundtrip(obs_hist.histogram_snapshots(), "histograms")


@pytest.mark.gateway
def test_gateway_and_fleet_snapshots_are_json_serializable(corpus, tmp_path):
    from repro.service.fleet import FleetRouter
    from repro.service.gateway import GatewayServer

    data, comp = corpus
    path = tmp_path / "a.gz"
    path.write_bytes(comp)
    with GatewayServer(cache_budget_bytes=2 << 20, max_workers=2) as gw:
        router = FleetRouter([gw.url])
        try:
            c = router.open(str(path))
            c.pread(0, 1000)
            _assert_json_roundtrip(gw.metrics(), "GatewayServer.metrics")
            _assert_json_roundtrip(router.snapshot(), "FleetRouter.snapshot")
            _assert_json_roundtrip(c.stat(), "FleetClient.stat")
            c.close()
        finally:
            router.close()


# ---------------------------------------------------------------------------
# counter invariants across serving paths
# ---------------------------------------------------------------------------

#: (section, counter) pairs that must never decrease between snapshots.
_MONOTONE = [
    ("scheduler", "submitted"), ("scheduler", "done"), ("scheduler", "cancelled"),
    ("service", "reads_started"),
    ("fleet.fetcher", "bytes_decompressed"), ("fleet.fetcher", "nominal_tasks"),
]


def _dig(snap, dotted):
    node = snap
    for part in dotted.split("."):
        node = node[part]
    return node


def _check_books(snap, *, bridge=False):
    sched = snap["scheduler"]
    assert sched["submitted"] == sched["done"] + sched["cancelled"] + sched["queued"], sched
    if bridge:
        b = snap["bridge"]
        assert b["submitted"] == b["started"] + b["cancelled"], b
    eng = snap.get("engine")
    if eng is not None:
        for kind in ("replace", "crc"):
            assert eng["fallbacks"].get(kind, 0) <= eng["requests"].get(kind, 0)
    for dim in ("ts", "uptime_s", "snapshot_seq"):
        assert dim in snap


def _check_monotone(before, after):
    for section, counter in _MONOTONE:
        try:
            b, a = _dig(before, section)[counter], _dig(after, section)[counter]
        except KeyError:
            continue
        assert a >= b, "%s.%s went backwards: %s -> %s" % (section, counter, b, a)


def _concurrent_reads(read_fn, n_threads=4, n_reads=8):
    errors = []

    def work(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(n_reads):
                read_fn(int(rng.integers(0, 250_000)), 4096)
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=RUN_TIMEOUT)
    assert not errors, errors


def test_invariants_sync_path(corpus):
    data, comp = corpus
    with ArchiveServer(cache_budget_bytes=4 << 20, max_workers=3) as server:
        h = server.open(comp)
        server.read_range(h, 0, 1000)
        before = server.metrics()
        _check_books(before)
        _concurrent_reads(lambda off, n: server.read_range(h, off, n))
        after = server.metrics()
        _check_books(after)
        _check_monotone(before, after)
        assert after["obs"]["histograms"]["server.read_range"]["count"] >= 33


def test_invariants_async_path(corpus):
    data, comp = corpus

    async def scenario():
        async with AsyncArchiveServer(
            cache_budget_bytes=4 << 20, max_workers=3, front_end_threads=3
        ) as srv:
            h = await srv.open(comp)
            await srv.read_range(h, 0, 1000)
            before = srv.metrics()
            _check_books(before, bridge=True)
            await asyncio.gather(*(
                srv.read_range(h, off, 4096)
                for off in range(0, 240_000, 20_000)
            ))
            after = srv.metrics()
            _check_books(after, bridge=True)
            _check_monotone(before, after)
            # every bridged call carries the queue-wait boundary
            bqw = obs_hist.histogram_snapshots()["bridge.queue_wait"]
            assert bqw["count"] >= after["bridge"]["started"]

    _run(scenario())


@pytest.mark.gateway
def test_invariants_gateway_path(corpus, tmp_path):
    from repro.service.gateway import GatewayClient, GatewayServer

    data, comp = corpus
    path = tmp_path / "inv.gz"
    path.write_bytes(comp)
    with GatewayServer(cache_budget_bytes=4 << 20, max_workers=3) as gw:
        c = GatewayClient(gw.url, source=str(path), block_size=16 << 10, cache_blocks=1)
        try:
            c.pread(0, 1000)
            before = gw.metrics()
            _check_books(before, bridge=True)
            _concurrent_reads(lambda off, n: c.pread(off, n), n_threads=3, n_reads=5)
            after = gw.metrics()
            _check_books(after, bridge=True)
            _check_monotone(before, after)
            assert after["gateway"]["reads"] > before["gateway"]["reads"]
            # every request passed the admission-wait boundary timer
            gh = obs_hist.histogram_snapshots()
            assert gh["gateway.admission_wait"]["count"] >= after["gateway"]["reads"]
            assert gh["gateway.request"]["count"] >= after["gateway"]["requests"] - 2
        finally:
            c.close()


# ---------------------------------------------------------------------------
# the wire: /v1/metrics?format=prometheus and /metrics alias
# ---------------------------------------------------------------------------

@pytest.mark.gateway
def test_gateway_prometheus_exposition(corpus, tmp_path):
    import http.client
    import urllib.parse

    from repro.service.gateway import GatewayClient, GatewayServer

    data, comp = corpus
    path = tmp_path / "prom.gz"
    path.write_bytes(comp)
    with GatewayServer(cache_budget_bytes=2 << 20, max_workers=2) as gw:
        c = GatewayClient(gw.url, source=str(path))
        try:
            assert c.pread(100, 5000) == data[100:5100]
        finally:
            c.close()

        def fetch(path_q):
            netloc = urllib.parse.urlsplit(gw.url).netloc
            conn = http.client.HTTPConnection(netloc, timeout=10)
            try:
                conn.request("GET", path_q)
                resp = conn.getresponse()
                return resp.status, resp.getheader("Content-Type"), resp.read()
            finally:
                conn.close()

        status, ctype, body = fetch("/v1/metrics?format=prometheus")
        assert status == 200 and ctype.startswith("text/plain")
        text = body.decode()
        _assert_valid_exposition(text)
        assert "# TYPE repro_latency_seconds histogram" in text
        assert 'repro_latency_seconds_bucket{le="+Inf",span="server.read_range"}' in text
        assert re.search(r"repro_latency_seconds_count\{[^}]*\} [1-9]", text)
        assert "repro_gateway_requests " in text
        assert "repro_uptime_s " in text
        # Bare /metrics is the conventional scrape path: exposition text by
        # default — a Prometheus scrape config never sends ?format=.
        status, ctype, body = fetch("/metrics")
        assert status == 200 and ctype.startswith("text/plain")
        _assert_valid_exposition(body.decode())
        status, ctype, body = fetch("/metrics?format=json")
        assert status == 200 and ctype.startswith("application/json")
        # /v1/metrics default stays JSON, and it is the sanitized snapshot
        status, ctype, body = fetch("/v1/metrics")
        assert status == 200 and ctype.startswith("application/json")
        snap = json.loads(body)
        assert snap["snapshot_seq"] >= 1
        # unknown formats are a client error, not a silent JSON fallback
        status, _, _ = fetch("/v1/metrics?format=xml")
        assert status == 400


# ---------------------------------------------------------------------------
# acceptance: one stitched trace across a mid-operation fleet failover
# ---------------------------------------------------------------------------

@pytest.mark.gateway
def test_fleet_failover_yields_one_stitched_trace(corpus, tmp_path):
    from repro.service.fleet import FleetRouter
    from repro.service.gateway import GatewayServer

    data, comp = corpus
    path = tmp_path / "stitch.gz"
    path.write_bytes(comp)
    gws = [
        GatewayServer(cache_budget_bytes=4 << 20, max_workers=2).start()
        for _ in range(3)
    ]
    router = FleetRouter([gw.url for gw in gws], eject_after=1)
    try:
        obs_trace.enable_tracing()
        # tiny client cache so the post-kill pread must hit the wire
        c = router.open(str(path), block_size=16 << 10, cache_blocks=1)
        owner = c.peer
        # One logical client operation: a cold read served by the owner,
        # then — after the owner dies mid-session — a read that fails over.
        # Everything under this root span must stitch into ONE trace.
        with obs_trace.span("client.session") as root:
            assert c.pread(0, 1000) == data[:1000]
            next(gw for gw in gws if gw.url == owner).close()  # owner dies
            assert c.pread(150_000, 1000) == data[150_000:151_000]
        assert c.stats["failovers"] == 1
        assert c.peer != owner
        c.close()

        spans = obs_trace.recorded_spans()
        tree = [s for s in spans if s["trace_id"] == root.trace_id]
        names = {s["name"] for s in tree}
        # client side: the retry shell, the failover, and the wire hops
        assert {"fleet.pread", "fleet.failover", "remote.range_get"} <= names
        # server side, joined via the traceparent header: front door,
        # admission, bridge hop, executor queue→run, and the frontier wait
        # underneath. (`reader.pread` is deliberately absent: nested preads
        # below the recording floor are elided on the warm path.)
        assert {"gateway.request", "gateway.admission_wait",
                "bridge.call", "executor.run", "reader.frontier_wait",
                "server.read_range"} <= names
        # the trace crossed the wire into TWO distinct gateways: the owner
        # served the first pread, the survivor the failed-over one — their
        # event loops are different threads, same trace id
        gw_reqs = [s for s in tree if s["name"] == "gateway.request"]
        assert len(gw_reqs) >= 2
        assert len({s["thread"] for s in gw_reqs}) >= 2
        # parenting is intact across the hop: every gateway.request's parent
        # is a client-side span of this trace
        ids = {s["span_id"] for s in tree}
        for g in gw_reqs:
            assert g["parent_id"] in ids
        # and the whole thing exports as one Chrome trace
        trace = obs_trace.dump_trace(spans=tree)
        assert len(trace["traceEvents"]) >= len(tree)
    finally:
        router.close()
        for gw in gws:
            try:
                gw.close()
            except Exception:  # noqa: BLE001 - one was killed on purpose
                pass
