"""Sharded archive fleet: placement, failover, membership, index exchange.

Everything speaks HTTP only to in-process loopback gateways (marker
``gateway`` — hermetic, tier-1 stays offline). The failover acceptance test
kills the owning gateway while a chunked stream is mid-flight and asserts
the resumed concatenation is bit-identical; the index-exchange test asserts
a *cold* open on a peer that never saw the archive does zero speculative
work because it imported the index over the wire.
"""

import gzip
import hashlib
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np
import pytest

from conftest import gzip_bytes, make_text
from repro.core import GzipIndex, ParallelGzipReader
from repro.core.errors import RemoteFileChangedError
from repro.service import ArchiveServer, IndexStore
from repro.service.index_store import file_identity
from repro.service.gateway import GatewayClient, GatewayError, GatewayServer
from repro.service.fleet import (
    FleetMembership,
    FleetRouter,
    FleetUnavailable,
    fetch_index_from_peers,
    make_index_fallback,
    rendezvous_rank,
    rendezvous_score,
)

pytestmark = pytest.mark.gateway


# ---------------------------------------------------------------------------
# rendezvous hashing: determinism + minimal disruption
# ---------------------------------------------------------------------------

def test_rendezvous_score_is_sha256_derived_and_stable():
    # The score must be process-stable (never hash(), which is salted):
    # recompute the documented construction independently.
    key, peer = "a" * 64, "http://127.0.0.1:1234"
    h = hashlib.sha256(peer.encode() + b"\0" + key.encode()).digest()
    assert rendezvous_score(key, peer) == int.from_bytes(h[:8], "big")
    assert rendezvous_score(key, peer) == rendezvous_score(key, peer)


def test_rendezvous_rank_minimal_disruption():
    peers = ["http://10.0.0.%d:80" % i for i in range(1, 6)]
    keys = [hashlib.sha256(str(i).encode()).hexdigest() for i in range(200)]
    before = {k: rendezvous_rank(k, peers) for k in keys}
    # every permutation of the input yields the same order
    assert all(rendezvous_rank(k, list(reversed(peers))) == before[k] for k in keys)
    dead = peers[2]
    after = {k: rendezvous_rank(k, [p for p in peers if p != dead]) for k in keys}
    for k in keys:
        # removing one peer deletes it from the preference order and changes
        # nothing else — keys it did not own keep their owner
        assert after[k] == [p for p in before[k] if p != dead]
    moved = sum(1 for k in keys if before[k][0] == dead)
    assert 0 < moved < len(keys)  # ~1/5 of keys owned by the dead peer


def test_router_key_for_hex_passthrough_and_identity(tmp_path):
    router = FleetRouter(["http://127.0.0.1:1"])
    key = "f" * 64
    assert router.key_for(key) == key
    p = tmp_path / "x.gz"
    p.write_bytes(gzip.compress(b"hello"))
    assert router.key_for(str(p)) == file_identity(str(p))
    router.close()


def test_router_requires_exactly_one_of_peers_or_membership():
    with pytest.raises(ValueError):
        FleetRouter()
    with pytest.raises(ValueError):
        FleetRouter(["http://a"], membership=FleetMembership(["http://a"]))


# ---------------------------------------------------------------------------
# membership: ejection, re-admission, stuck streams (injected probe)
# ---------------------------------------------------------------------------

def test_membership_validation():
    with pytest.raises(ValueError):
        FleetMembership([])
    with pytest.raises(ValueError):
        FleetMembership(["http://a", "http://a/"])  # same after rstrip
    with pytest.raises(ValueError):
        FleetMembership(["http://a"], eject_after=0)


def test_membership_eject_and_readmit_with_injected_probe():
    up = {"http://a": True, "http://b": True}

    def probe(url):
        if not up[url]:
            raise OSError("down")
        return {"gateway": {"streams_in_progress": {}}}

    m = FleetMembership(["http://a", "http://b"], eject_after=2, probe=probe)
    assert sorted(m.alive()) == ["http://a", "http://b"]
    up["http://b"] = False
    m.probe_once()
    assert "http://b" in m.alive()  # one failure < eject_after: still in
    m.probe_once()
    assert m.alive() == ["http://a"]
    snap = m.snapshot()["peers"]["http://b"]
    assert not snap["alive"] and snap["ejections"] == 1
    # one good probe re-admits; the consecutive-failure counter resets
    up["http://b"] = True
    m.probe_once()
    snap = m.snapshot()["peers"]["http://b"]
    assert snap["alive"] and snap["readmissions"] == 1
    assert snap["consecutive_failures"] == 0
    assert snap["probes"] == 3


def test_membership_data_path_failures_count_toward_ejection():
    m = FleetMembership(["http://a", "http://b"], eject_after=2)
    m.report_failure("http://a", OSError("reset"))
    assert "http://a" in m.alive()
    m.report_failure("http://a")
    assert m.alive() == ["http://b"]
    m.report_failure("http://nobody")  # unknown peers are ignored, not added
    assert m.peers() == ["http://a", "http://b"]


def test_membership_stuck_stream_detection():
    sent = {"7": 1000}

    def probe(url):
        return {"gateway": {"streams_in_progress": {
            k: {"handle": "f1", "tenant": "t", "sent": v, "total": 9999}
            for k, v in sent.items()
        }}}

    m = FleetMembership(["http://a"], probe=probe)
    m.probe_once()
    assert m.snapshot()["peers"]["http://a"]["stuck_streams"] == 0  # first sight
    m.probe_once()  # byte count unchanged between probes -> stuck
    assert m.snapshot()["peers"]["http://a"]["stuck_streams"] == 1
    sent["7"] = 2000  # progress resumed -> merely slow, not stuck
    m.probe_once()
    assert m.snapshot()["peers"]["http://a"]["stuck_streams"] == 0


# ---------------------------------------------------------------------------
# IndexStore remote fallback: validation + single flight
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def finalized_blob(tmp_path_factory):
    """(key-agnostic) serialized finalized GzipIndex over a small corpus."""
    rng = np.random.default_rng(0x1D3)
    data = make_text(rng, 150_000)
    comp = gzip_bytes(data, 6)
    with ParallelGzipReader(comp, parallelization=2, chunk_size=32 << 10) as r:
        assert r.read() == data
        assert r.index.finalized
        return r.index.to_bytes()


def test_index_store_fallback_installs_valid_blob(finalized_blob):
    calls = []

    def fallback(key):
        calls.append(key)
        return finalized_blob

    store = IndexStore(remote_fallback=fallback)
    key = "a" * 64
    idx = store.get(key)
    assert idx is not None and idx.finalized
    assert calls == [key]
    assert store.stats.remote_hits == 1 and store.stats.hits == 1
    # installed locally: the next get hits without another fetch
    assert store.get(key) is not None
    assert calls == [key]
    assert store.stats.hits == 2 and store.stats.remote_hits == 1


@pytest.mark.parametrize("raw", [None, b"", b"garbage", b"NOTANIDX" + b"\0" * 64])
def test_index_store_fallback_rejects_invalid_blobs(raw):
    store = IndexStore(remote_fallback=lambda key: raw)
    assert store.get("b" * 64) is None
    assert store.stats.misses == 1
    assert store.stats.remote_misses == 1 and store.stats.remote_hits == 0


def test_index_store_fallback_swallows_fetch_errors():
    def fallback(key):
        raise OSError("peer down")

    store = IndexStore(remote_fallback=fallback)
    assert store.get("c" * 64) is None  # degrades to a cold miss, no raise
    assert store.stats.remote_misses == 1


def test_index_store_fallback_single_flight(finalized_blob):
    release = threading.Event()
    calls = []

    def fallback(key):
        calls.append(key)
        release.wait(timeout=10)
        return finalized_blob

    store = IndexStore(remote_fallback=fallback)
    results = []
    threads = [
        threading.Thread(target=lambda: results.append(store.get("d" * 64)))
        for _ in range(4)
    ]
    for t in threads:
        t.start()
    time.sleep(0.2)  # let every thread reach the fetch
    release.set()
    for t in threads:
        t.join(timeout=10)
    assert len(calls) == 1  # one network fetch, three waiters
    assert len(results) == 4 and all(r is not None for r in results)
    assert store.stats.remote_hits == 1


# ---------------------------------------------------------------------------
# wire fixtures: a 3-peer loopback fleet
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small(tmp_path_factory):
    rng = np.random.default_rng(0x51A11)
    data = make_text(rng, 250_000)
    path = tmp_path_factory.mktemp("fleetsmall") / "small.gz"
    path.write_bytes(gzip_bytes(data, 6))
    return str(path), data


@pytest.fixture(scope="module")
def big(tmp_path_factory):
    # ~9.8 MB decompressed: large enough that a chunked stream cannot be
    # fully absorbed by loopback socket buffers before the owner is killed
    # (else the client drains the stream from buffers and never fails over).
    rng = np.random.default_rng(0xF1EE7)
    words = [rng.bytes(3) * 2 for _ in range(64)]
    data = b" ".join(words[int(i)] for i in rng.integers(0, 64, 1_400_000))
    path = tmp_path_factory.mktemp("fleetbig") / "big.gz"
    path.write_bytes(gzip_bytes(data, 5))
    return str(path), data


@pytest.fixture
def fleet(tmp_path):
    """Factory: n loopback gateways (own ArchiveServer + IndexStore each,
    cross-wired index fallbacks) behind a FleetRouter with eject_after=1."""
    made = []

    def make(n=3, *, wire_exchange=True, **router_kwargs):
        stores, servers, gws = [], [], []
        for i in range(n):
            store = IndexStore(tmp_path / ("idx%d" % i))
            srv = ArchiveServer(
                cache_budget_bytes=8 << 20, max_workers=2,
                chunk_size=128 << 10, index_store=store,
            )
            gw = GatewayServer(srv, stream_span=64 << 10).start()
            stores.append(store)
            servers.append(srv)
            gws.append(gw)
        urls = [gw.url for gw in gws]
        if wire_exchange:
            for i, store in enumerate(stores):
                store.set_remote_fallback(
                    make_index_fallback(urls, exclude=[urls[i]])
                )
        router_kwargs.setdefault("eject_after", 1)
        router = FleetRouter(urls, **router_kwargs)
        made.append((router, gws, servers))
        return router, gws, stores

    yield make
    for router, gws, servers in made:
        router.close()
        for gw in gws:
            try:
                gw.close()
            except Exception:  # noqa: BLE001 - killed mid-test on purpose
                pass
        for srv in servers:
            srv.shutdown()


def _gw_for(gws, url):
    return next(gw for gw in gws if gw.url == url)


# ---------------------------------------------------------------------------
# placement on the wire
# ---------------------------------------------------------------------------

def test_fleet_routes_to_owner(fleet, small):
    path, data = small
    router, gws, _ = fleet()
    c = router.open(path)
    try:
        assert c.peer == router.owner(c.key)
        assert c.size() == len(data)
        assert c.pread(1234, 4096) == data[1234 : 1234 + 4096]
        # exactly the owner served it — the other peers saw no open
        for gw in gws:
            opened = gw.metrics()["gateway"].get("opened", 0)
            assert opened == (1 if gw.url == c.peer else 0)
    finally:
        c.close()
    assert router.snapshot()["counters"]["opens"] == 1


def test_fleet_unavailable_when_all_peers_dead(fleet, small):
    path, _ = small
    router, gws, _ = fleet(n=2)
    for url in router.membership.peers():
        router.membership.report_failure(url)  # eject_after=1: both out
    with pytest.raises(FleetUnavailable):
        router.open(path)
    with pytest.raises(FleetUnavailable):
        router.owner("e" * 64)


# ---------------------------------------------------------------------------
# failover acceptance: kill the owner mid-stream, bytes stay bit-identical
# ---------------------------------------------------------------------------

def test_kill_owner_mid_stream_failover_bit_identical(fleet, big):
    path, data = big
    router, gws, _ = fleet()
    c = router.open(path)
    owner = c.peer
    got, n, killed = [], 0, False
    deadline = time.monotonic() + 120
    for chunk in c.stream(read_size=64 << 10):
        got.append(chunk)
        n += len(chunk)
        if not killed and n >= 1 << 20:
            killed = True
            _gw_for(gws, owner).close()  # peer death, mid-flight
        assert time.monotonic() < deadline
    assert killed
    assert b"".join(got) == data  # bit-identical: exact Range resume
    assert c.stats["failovers"] >= 1
    assert c.stats["resumed_streams"] >= 1
    assert c.peer != owner
    # pread keeps working on the failover peer
    assert c.pread(2 << 20, 8192) == data[2 << 20 : (2 << 20) + 8192]
    # the next probe sweep ejects the dead peer from membership
    router.membership.probe_once()
    snap = router.membership.snapshot()
    assert snap["alive"] == 2
    assert not snap["peers"][owner]["alive"]
    c.close()


def test_pread_failover_after_owner_death(fleet, small):
    path, data = small
    router, gws, _ = fleet()
    # tiny client-side block cache so the post-kill read must hit the wire
    # (a big cached block would serve it locally and mask the failover)
    c = router.open(path, block_size=16 << 10, cache_blocks=1)
    owner = c.peer
    assert c.pread(0, 1000) == data[:1000]
    _gw_for(gws, owner).close()
    # positional reads re-issue verbatim on the next-best peer
    assert c.pread(100_000, 1000) == data[100_000:101_000]
    assert c.pread(len(data) - 500, 500) == data[-500:]
    assert c.stats["failovers"] == 1
    assert c.peer != owner
    c.close()


# ---------------------------------------------------------------------------
# cross-node index exchange
# ---------------------------------------------------------------------------

def test_index_exchange_makes_cold_open_warm(fleet, small):
    path, data = small
    router, gws, stores = fleet()
    # build + persist the index on the owner (close persists finalized index)
    c = router.open(path)
    owner = c.peer
    assert b"".join(c.stream()) == data
    c.close()
    key = file_identity(path)
    assert stores[[gw.url for gw in gws].index(owner)].get_blob(key) is not None

    # a *different* peer now cold-opens the same archive: its local store
    # misses, the fallback imports the owner's index, and the open does
    # zero speculative work
    other = next(gw for gw in gws if gw.url != owner)
    oi = [gw.url for gw in gws].index(other.url)
    g = GatewayClient(other.url, source=path)
    try:
        stat = g.stat()
        assert stat["index_was_warm"] is True
        assert g.pread(5000, 4096) == data[5000 : 5000 + 4096]
        m = other.metrics()
        assert m["index_store"]["remote_hits"] == 1
        assert m["fleet"]["fetcher"]["nominal_tasks"] == 0  # no speculation
        assert m["fleet"]["frontier"]["lock_acquires"] == 0  # indexed reads only
        assert stores[oi].get_blob(key) is not None  # installed locally
    finally:
        g.close()


def test_index_endpoint_serves_blob_by_handle_and_key(fleet, small):
    path, data = small
    router, gws, _ = fleet(n=1)
    gw = gws[0]
    key = file_identity(path)
    g = GatewayClient(gw.url, source=path)
    try:
        assert b"".join(g.stream()) == data  # finalize the live index
        blob = g.fetch_index()
        assert blob is not None
        idx = GzipIndex.from_bytes(blob)
        assert idx.finalized
        # by content key (what a fetching peer knows) — needs the persisted
        # blob, which lands on handle close below
    finally:
        g.close()
    got = fetch_index_from_peers([gw.url], key)
    assert got is not None and GzipIndex.from_bytes(got).finalized
    # unknown key: every peer 404s, fetch degrades to None
    assert fetch_index_from_peers([gw.url], "0" * 64) is None


def test_index_endpoint_404_and_304(fleet, small):
    path, _ = small
    router, gws, _ = fleet(n=1)
    gw = gws[0]
    g = GatewayClient(gw.url, source=path)
    try:
        import http.client as hc

        host, port = gw.url[len("http://"):].rsplit(":", 1)

        def raw_get(p, headers=None):
            conn = hc.HTTPConnection(host, int(port), timeout=30)
            try:
                conn.request("GET", p, headers=headers or {})
                resp = conn.getresponse()
                return resp.status, dict(resp.getheaders()), resp.read()
            finally:
                conn.close()

        status, headers, _ = raw_get("/v1/archives/%s/index" % g.handle)
        assert status == 200
        key = headers["ETag"].strip('"')
        assert len(key) == 64  # bare content key as validator
        # revalidation: If-None-Match on the index answers 304, no body
        status, headers, body = raw_get(
            "/v1/archives/%s/index" % g.handle, {"If-None-Match": '"%s"' % key}
        )
        assert status == 304 and body == b""
        status, _, _ = raw_get("/v1/archives/%s/index" % ("9" * 64))
        assert status == 404
        status, _, _ = raw_get("/v1/archives/nosuch/index")
        assert status == 404
    finally:
        g.close()


# ---------------------------------------------------------------------------
# conditional GET / revalidation on bytes + stat
# ---------------------------------------------------------------------------

def test_if_none_match_304_on_bytes_and_stat(fleet, small):
    path, data = small
    router, gws, _ = fleet(n=1)
    gw = gws[0]
    g = GatewayClient(gw.url, source=path)
    try:
        import http.client as hc

        host, port = gw.url[len("http://"):].rsplit(":", 1)

        def raw_get(p, headers=None):
            conn = hc.HTTPConnection(host, int(port), timeout=30)
            try:
                conn.request("GET", p, headers=headers or {})
                resp = conn.getresponse()
                return resp.status, dict(resp.getheaders()), resp.read()
            finally:
                conn.close()

        bytes_path = "/v1/archives/%s/bytes" % g.handle
        status, headers, _ = raw_get(bytes_path, {"Range": "bytes=0-9"})
        assert status == 206
        etag = headers["ETag"]
        # match -> 304 with no body, even with a Range present
        for sent in (etag, "W/%s" % etag, '"zzz", %s' % etag, "*"):
            status, _, body = raw_get(
                bytes_path, {"If-None-Match": sent, "Range": "bytes=0-9"}
            )
            assert status == 304 and body == b"", sent
        # mismatch -> normal 206
        status, _, body = raw_get(
            bytes_path, {"If-None-Match": '"zzz"', "Range": "bytes=0-9"}
        )
        assert status == 206 and body == data[:10]
        # stat endpoint: same validator discipline
        stat_path = "/v1/archives/%s/stat" % g.handle
        status, headers, _ = raw_get(stat_path)
        assert status == 200
        status, _, body = raw_get(stat_path, {"If-None-Match": headers["ETag"]})
        assert status == 304 and body == b""
        assert gw.metrics()["gateway"]["not_modified_304"] >= 5
        # client-side sugar over the same wire exchange
        assert g.revalidate(etag) is True
        assert g.revalidate('"bogus"') is False
    finally:
        g.close()


# ---------------------------------------------------------------------------
# per-handle stream progress in /v1/metrics
# ---------------------------------------------------------------------------

def test_stream_progress_visible_in_metrics(fleet, big):
    path, data = big
    router, gws, _ = fleet(n=1)
    gw = gws[0]
    g = GatewayClient(gw.url, source=path)
    try:
        it = g.stream(read_size=64 << 10)
        n = 0
        for chunk in it:
            n += len(chunk)
            if n >= 1 << 20:
                break  # pause mid-stream, connection held open
        streams = gw.metrics()["gateway"]["streams_in_progress"]
        assert len(streams) == 1
        (info,) = streams.values()
        assert info["handle"] == g.handle
        assert info["total"] == len(data)
        assert 0 < info["sent"] <= len(data)
        it.close()  # abandon: server sees the disconnect
        deadline = time.monotonic() + 10
        while gw.metrics()["gateway"]["streams_in_progress"]:
            assert time.monotonic() < deadline, "stream entry never reaped"
            time.sleep(0.05)
    finally:
        g.close()


# ---------------------------------------------------------------------------
# admission-aware retry budget (scripted 429 server)
# ---------------------------------------------------------------------------

class _Scripted429Server:
    """Minimal gateway impostor: bytes HEAD/GET always work; the stat verb
    follows a script of (status, retry_after) entries, then succeeds."""

    def __init__(self, script):
        outer = self
        self.script = list(script)
        self.stat_requests = 0
        self._lock = threading.Lock()

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, *args):
                pass

            def _body(self, status, payload=b"{}", headers=()):
                self.send_response(status)
                for k, v in headers:
                    self.send_header(k, v)
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload)

            def do_HEAD(self):  # noqa: N802 - http.server API
                self.send_response(200)
                self.send_header("Content-Length", "100")
                self.send_header("Accept-Ranges", "bytes")
                self.send_header("ETag", '"imp-1"')
                self.end_headers()

            def do_GET(self):  # noqa: N802 - http.server API
                if self.path.endswith("/stat"):
                    with outer._lock:
                        outer.stat_requests += 1
                        step = outer.script.pop(0) if outer.script else None
                    if step is None:
                        self._body(200, json.dumps({"ok": True}).encode())
                        return
                    status, retry_after = step
                    headers = []
                    if retry_after is not None:
                        headers.append(("Retry-After", str(retry_after)))
                    self._body(status, b'{"error": "busy"}', headers)
                    return
                self._body(200, b"x" * 100)

        self._httpd = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True
        )
        self._thread.start()
        self.url = "http://127.0.0.1:%d" % self._httpd.server_address[1]

    def close(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=10)


def test_retry_budget_absorbs_429_bursts():
    srv = _Scripted429Server([(429, "0"), (429, None)])
    try:
        c = GatewayClient(srv.url, handle="f0", retry_budget=5.0)
        assert c.stat() == {"ok": True}
        assert c.retries_429 == 2  # both throttles absorbed, then success
        assert srv.stat_requests == 3
        c.close()
    finally:
        srv.close()


def test_retry_budget_exhaustion_surfaces_429():
    # Retry-After far beyond the budget: fail fast instead of sleeping
    srv = _Scripted429Server([(429, "60")] * 10)
    try:
        c = GatewayClient(srv.url, handle="f0", retry_budget=0.5)
        t0 = time.monotonic()
        with pytest.raises(GatewayError) as exc_info:
            c.stat()
        assert time.monotonic() - t0 < 5.0  # did not wait out the 60 s
        assert exc_info.value.status == 429
        assert exc_info.value.retry_after == 60.0
        assert srv.stat_requests == 1
        c.close()
    finally:
        srv.close()


def test_retry_budget_validation():
    with pytest.raises(ValueError):
        GatewayClient("http://127.0.0.1:1", handle="f0", retry_budget=-1)
