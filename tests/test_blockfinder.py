import numpy as np
import pytest

from repro.core import (
    BitReader,
    DeflateChunkDecoder,
    canonical_stored_offset,
    find_dynamic_skiplut,
    find_dynamic_trial,
    parse_gzip_header,
    scan_dynamic_candidates,
    scan_stored_candidates,
)
from repro.core.block_finder import CombinedBlockFinder, FilterStats
from repro.core.synth import stored_only_compress

from conftest import gzip_bytes, make_base64, make_random, make_text


def _true_blocks(comp):
    br = BitReader(comp)
    parse_gzip_header(br)
    dec = DeflateChunkDecoder(comp)
    res = dec.decode_chunk(br.bit_pos, len(comp) * 8, window=b"")
    return res.blocks


def test_finds_all_true_dynamic_blocks(rng):
    data = make_base64(rng, 500_000)
    comp = gzip_bytes(data, 6)
    blocks = _true_blocks(comp)
    dynamic = [b.bit_offset for b in blocks if b.block_type == 2 and not b.is_final]
    assert len(dynamic) >= 3
    found = set(scan_dynamic_candidates(comp, 0, len(comp) * 8))
    missing = [b for b in dynamic if b not in found]
    assert not missing, f"finder missed true blocks at {missing}"


def test_finds_stored_blocks_canonically(rng):
    data = make_random(rng, 400_000)
    comp = stored_only_compress(data)
    blocks = _true_blocks(comp)
    stored = [
        canonical_stored_offset(b.bit_offset)
        for b in blocks
        if b.block_type == 0 and not b.is_final
    ]
    assert stored
    found = set(scan_stored_candidates(comp, 0, len(comp) * 8))
    missing = [b for b in stored if b not in found]
    assert not missing


def test_combined_finder_orders_candidates(rng):
    data = make_text(rng, 200_000) + make_random(rng, 100_000)
    comp = gzip_bytes(data, 6)
    cands = []
    finder = CombinedBlockFinder(comp, 0, len(comp) * 8)
    for c in finder:
        cands.append(c)
        if len(cands) > 200:
            break
    assert cands == sorted(cands)
    assert len(cands) == len(set(cands))


def test_skiplut_agrees_with_vectorized(rng):
    blob = make_random(rng, 20_000)
    end = len(blob) * 8
    vec = list(scan_dynamic_candidates(blob, 0, end))
    lut = list(find_dynamic_skiplut(blob, 0, end))
    assert vec == lut


def test_trial_agrees_with_vectorized_small(rng):
    blob = make_random(rng, 2_000)
    end = len(blob) * 8
    vec = list(scan_dynamic_candidates(blob, 0, end))
    trial = list(find_dynamic_trial(blob, 0, end))
    assert vec == trial


def test_false_positive_rate_on_random_data(rng):
    """Paper Table 1: ~200 valid headers per 1e12 positions => random data
    yields very few candidates; the cascade must reject almost everything."""
    blob = make_random(rng, 125_000)  # 1e6 bit positions
    stats = FilterStats()
    cands = list(scan_dynamic_candidates(blob, 0, len(blob) * 8, stats=stats))
    assert stats.tested >= 990_000
    # Expected ~2e-10 * 1e6 << 1; allow a little slack for unlucky seeds.
    assert len(cands) <= 2
    # Cascade ordering sanity (paper Table 1 proportions).
    assert stats.invalid_final == pytest.approx(stats.tested * 0.5, rel=0.01)
    assert stats.invalid_type == pytest.approx(stats.tested * 0.375, rel=0.01)
    assert stats.invalid_hlit == pytest.approx(stats.tested * 0.0078, rel=0.15)
    assert stats.invalid_precode_histogram > stats.invalid_precode_data


def test_stored_finder_false_positive_rate(rng):
    """Paper §3.4.1: one false positive every ~514 KiB on random data."""
    blob = make_random(rng, 2 << 20)
    n = len(list(scan_stored_candidates(blob, 0, len(blob) * 8)))
    # 2 MiB / 514 KiB ~ 4; generous bounds:
    assert n <= 25
