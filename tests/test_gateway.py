"""Gateway wire front-end: protocol semantics, cancellation, admission.

Everything here talks HTTP only to an in-process loopback `GatewayServer`
(marker ``gateway`` — hermetic like the ``remote`` suite, tier-1 stays
offline). The fault tests (mid-stream disconnect, tenant flood) carry the
tier-2 ``stress`` marker as well and are bounded by explicit deadlines.
"""

import gzip as _gzip
import http.client
import json
import socket
import time

import numpy as np
import pytest

from conftest import gzip_bytes, make_base64, make_text
from repro.core import ParallelGzipReader
from repro.core.remote import RemoteFileReader
from repro.data.pipeline import GzipCorpusDataset
from repro.service import ArchiveServer, IndexStore
from repro.service.gateway import (
    AdmissionDenied,
    GatewayClient,
    GatewayError,
    GatewayServer,
    TenantAdmission,
)
from repro.service.gateway.admission import TenantLimit
from repro.service.gateway.server import _parse_range

pytestmark = pytest.mark.gateway


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    """Mixed text+base64 corpus fixtures written as .gz files."""
    rng = np.random.default_rng(0x6A7E)
    root = tmp_path_factory.mktemp("gwcorpus")
    fixtures = {}
    for name, data in {
        "text": make_text(rng, 300_000),
        "base64": make_base64(rng, 300_000),
        "mixed": make_text(rng, 150_000) + make_base64(rng, 150_000),
    }.items():
        path = root / f"{name}.gz"
        path.write_bytes(gzip_bytes(data, 6))
        fixtures[name] = (str(path), data)
    return fixtures


def _raw_conn(gw):
    host, port = gw.url[len("http://"):].rsplit(":", 1)
    return http.client.HTTPConnection(host, int(port), timeout=30)


def _get(gw, path, headers=None):
    conn = _raw_conn(gw)
    try:
        conn.request("GET", path, headers=headers or {})
        resp = conn.getresponse()
        return resp.status, dict(resp.getheaders()), resp.read()
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# acceptance: bit-identical bytes over the wire, cold and warm
# ---------------------------------------------------------------------------

def test_bit_identical_pread_and_stream_cold_and_warm(corpus, tmp_path):
    """For every corpus fixture: GatewayClient pread and a chunked-stream
    full read match ParallelGzipReader output exactly — on a cold open
    (speculative first pass server-side) and on a warm reopen (IndexStore
    hit, zero speculative tasks)."""
    store = IndexStore(tmp_path / "idx")
    for phase in ("cold", "warm"):
        server = ArchiveServer(
            cache_budget_bytes=4 << 20, max_workers=4, chunk_size=64 << 10,
            index_store=store,
        )
        with GatewayServer(server, stream_span=48 << 10) as gw:
            for name, (path, data) in corpus.items():
                expect = ParallelGzipReader(path).read()
                assert expect == data
                client = GatewayClient(gw.url, source=path,
                                       block_size=32 << 10, cache_blocks=8)
                assert client.size() == len(data)
                # positional reads, including straddles and the tail
                for off, n in ((0, 1000), (65_536, 4096), (123_457, 33_333),
                               (len(data) - 777, 10_000)):
                    assert client.pread(off, n) == expect[off : off + n]
                # chunked-stream full read (spans > stream_span go chunked)
                assert b"".join(client.stream()) == expect
                if phase == "warm":
                    assert client.stat()["index_was_warm"], (phase, name)
                client.close()
        server.shutdown()
        assert store.stats.puts >= 3  # cold pass persisted every fixture


# ---------------------------------------------------------------------------
# Range semantics on the wire
# ---------------------------------------------------------------------------

def test_range_edge_cases_on_the_wire(corpus):
    path, data = corpus["text"]
    with GatewayServer(
        cache_budget_bytes=2 << 20, max_workers=2, chunk_size=64 << 10,
        stream_span=1 << 20,
    ) as gw:
        client = GatewayClient(gw.url, source=path)
        url_path = "/v1/archives/%s/bytes" % client.handle
        size = len(data)

        # plain bounded range
        status, headers, body = _get(gw, url_path, {"Range": "bytes=100-299"})
        assert status == 206 and body == data[100:300]
        assert headers["Content-Range"] == "bytes 100-299/%d" % size

        # suffix range: last n bytes
        status, headers, body = _get(gw, url_path, {"Range": "bytes=-500"})
        assert status == 206 and body == data[-500:]
        assert headers["Content-Range"] == "bytes %d-%d/%d" % (size - 500, size - 1, size)

        # open-ended range
        status, headers, body = _get(gw, url_path, {"Range": "bytes=%d-" % (size - 100)})
        assert status == 206 and body == data[-100:]

        # end clamped to EOF
        status, _, body = _get(gw, url_path, {"Range": "bytes=%d-%d" % (size - 10, size + 100)})
        assert status == 206 and body == data[-10:]

        # start past EOF -> 416 with the unsatisfied Content-Range form
        status, headers, body = _get(gw, url_path, {"Range": "bytes=%d-%d" % (size, size + 1)})
        assert status == 416
        assert headers["Content-Range"] == "bytes */%d" % size

        # zero-length suffix -> 416 too
        status, _, _ = _get(gw, url_path, {"Range": "bytes=-0"})
        assert status == 416

        # syntactically invalid ranges degrade to a 200 full body
        status, _, body = _get(gw, url_path, {"Range": "lines=1-2"})
        assert status == 200 and body == data

        # multi-read over one keep-alive connection
        conn = _raw_conn(gw)
        try:
            for off in (0, 1000, 250_000, 13):
                conn.request("GET", url_path, headers={"Range": "bytes=%d-%d" % (off, off + 99)})
                resp = conn.getresponse()
                assert resp.status == 206
                assert resp.read() == data[off : off + 100]
        finally:
            conn.close()
        client.close()


def test_refuses_routable_bind_without_auth_or_jail(tmp_path):
    """Anonymous + unjailed + non-loopback = serve any local file to the
    network; the constructor must refuse that combination outright."""
    with pytest.raises(ValueError, match="refusing to bind"):
        GatewayServer(host="0.0.0.0", cache_budget_bytes=1 << 20)
    # tokens alone are not enough while a default tenant still admits
    # requests with no Authorization header
    with pytest.raises(ValueError, match="refusing to bind"):
        GatewayServer(
            host="0.0.0.0", cache_budget_bytes=1 << 20,
            admission=TenantAdmission(tokens={"t": "a"}),  # default "public"
        )
    # either real opt-in makes it constructible (not started: nothing bound)
    GatewayServer(
        host="0.0.0.0", cache_budget_bytes=1 << 20,
        admission=TenantAdmission(tokens={"t": "a"}, default_tenant=None),
    ).close()
    GatewayServer(
        host="0.0.0.0", cache_budget_bytes=1 << 20, open_roots=[str(tmp_path)]
    ).close()


@pytest.mark.stress
def test_stalled_client_releases_admission_slot(tmp_path):
    """A connected client that stops *reading* (slow-loris) must not pin its
    handler task and admission slot past idle_timeout: drain() is bounded,
    so the stall is treated as a disconnect and the slot is released."""
    rng = np.random.default_rng(3)
    data = rng.integers(0, 256, 8_000_000, dtype=np.uint8).tobytes()
    path = tmp_path / "stall.gz"
    path.write_bytes(_gzip.compress(data, 1))
    with GatewayServer(
        cache_budget_bytes=4 << 20, max_workers=2, chunk_size=128 << 10,
        stream_span=64 << 10, idle_timeout=2.0,
    ) as gw:
        client = GatewayClient(gw.url, source=str(path))
        host, port = gw.url[len("http://"):].rsplit(":", 1)
        sock = socket.create_connection((host, int(port)), timeout=10)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4096)
        sock.sendall(
            b"GET /v1/archives/%s/bytes HTTP/1.1\r\nHost: x\r\n\r\n"
            % client.handle.encode()
        )
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline:
            if gw.metrics()["admission"].get("public", {}).get("in_flight", 1) == 0:
                break
            time.sleep(0.25)
        snap = gw.metrics()["admission"]["public"]
        assert snap["in_flight"] == 0, "stalled client pinned its slot: %r" % snap
        assert client.pread(100, 100) == data[100:200]  # still serviceable
        sock.close()
        client.close()


def test_parse_range_unit():
    assert _parse_range(None, 100) is None
    assert _parse_range("bytes=0-9", 0) == "unsat"  # zero-length body
    assert _parse_range("bytes=-5", 0) == "unsat"
    assert _parse_range("bytes=0-9", 100) == (0, 10)
    assert _parse_range("bytes=90-", 100) == (90, 100)
    assert _parse_range("bytes=-5", 100) == (95, 100)
    assert _parse_range("bytes=-200", 100) == (0, 100)
    assert _parse_range("bytes=0-999", 100) == (0, 100)
    assert _parse_range("bytes=100-", 100) == "unsat"
    assert _parse_range("bytes=-0", 100) == "unsat"
    assert _parse_range("bytes=5-2", 100) == "invalid"
    assert _parse_range("bytes=1-2,5-6", 100) == "invalid"
    assert _parse_range("lines=1-2", 100) == "invalid"


def test_head_stat_delete_and_metrics(corpus):
    path, data = corpus["base64"]
    with GatewayServer(cache_budget_bytes=2 << 20, max_workers=2, chunk_size=64 << 10) as gw:
        client = GatewayClient(gw.url, source=path)
        url_path = "/v1/archives/%s/bytes" % client.handle

        conn = _raw_conn(gw)
        conn.request("HEAD", url_path)
        resp = conn.getresponse()
        resp.read()
        assert resp.status == 200
        assert int(resp.getheader("Content-Length")) == len(data)
        assert resp.getheader("ETag", "").strip('"')
        assert resp.getheader("Accept-Ranges") == "bytes"
        conn.close()

        stat = client.stat()
        assert stat["index_finalized"] is True
        assert stat["identity"]

        metrics = client.metrics()
        for section in ("gateway", "bridge", "admission", "scheduler", "fleet"):
            assert section in metrics, section
        sched = metrics["scheduler"]
        assert sched["submitted"] == sched["done"] + sched["cancelled"] + sched["queued"]

        client.close()  # DELETEs the handle
        status, _, _ = _get(gw, url_path, {"Range": "bytes=0-1"})
        assert status == 404


def test_gateway_chaining_via_remote_filereader_and_second_tier(corpus, tmp_path):
    """The bytes endpoint speaks RemoteFileReader's dialect: (a) a plain
    RemoteFileReader reads it directly; (b) a second gateway tier opens a
    first-tier bytes URL as a remote source (gzip-in-gzip: tier 1 strips
    the outer layer, tier 2 the inner) — tiered deployments for free."""
    _, data = corpus["text"]
    inner_gz = gzip_bytes(data, 6)
    outer = tmp_path / "double.gz.gz"
    outer.write_bytes(_gzip.compress(inner_gz, 6))

    with GatewayServer(cache_budget_bytes=2 << 20, max_workers=2, chunk_size=32 << 10) as gw1:
        c1 = GatewayClient(gw1.url, source=str(outer))
        # (a) direct RemoteFileReader over tier 1: sees the inner .gz bytes
        r = RemoteFileReader(gw1.bytes_url(c1.handle), block_size=16 << 10)
        assert r.pread(0, 2) == inner_gz[:2]  # gzip magic survives the hop
        assert r.size() == len(inner_gz)
        r.close()
        # (b) tier 2 opens tier 1's bytes URL as its archive source
        with GatewayServer(
            cache_budget_bytes=2 << 20, max_workers=2, chunk_size=32 << 10,
            remote_options={"block_size": 16 << 10},
        ) as gw2:
            c2 = GatewayClient(gw2.url, source=gw1.bytes_url(c1.handle))
            assert c2.size() == len(data)
            assert c2.pread(1234, 4321) == data[1234 : 1234 + 4321]
            assert b"".join(c2.stream()) == data
            c2.close()
        c1.close()


# ---------------------------------------------------------------------------
# auth, tenancy, admission
# ---------------------------------------------------------------------------

def test_auth_required_and_tenant_scoped_handles(corpus):
    path, data = corpus["text"]
    adm = TenantAdmission(
        tokens={"tok-a": "alpha", "tok-b": "beta"}, default_tenant=None
    )
    with GatewayServer(
        cache_budget_bytes=2 << 20, max_workers=2, chunk_size=64 << 10, admission=adm
    ) as gw:
        # no token -> 401 with a challenge
        conn = _raw_conn(gw)
        conn.request("POST", "/v1/archives", body=json.dumps({"source": path}))
        resp = conn.getresponse()
        resp.read()
        assert resp.status == 401
        assert resp.getheader("WWW-Authenticate") == "Bearer"
        conn.close()

        # unknown token -> 401; valid tokens work
        with pytest.raises(GatewayError) as exc_info:
            GatewayClient(gw.url, source=path, token="tok-x")
        assert exc_info.value.status == 401
        ca = GatewayClient(gw.url, source=path, token="tok-a")
        assert ca.pread(0, 100) == data[:100]

        # another tenant cannot even see alpha's handle
        status, _, _ = _get(
            gw, "/v1/archives/%s/bytes" % ca.handle,
            {"Authorization": "Bearer tok-b", "Range": "bytes=0-1"},
        )
        assert status == 404
        # explicit tenant in the body is rejected when tokens are configured
        with pytest.raises(GatewayError) as exc_info:
            GatewayClient(gw.url, source=path, token="tok-b", tenant="alpha")
        assert exc_info.value.status == 400
        ca.close()


def test_unauthenticated_tenant_override_keeps_handle_usable(corpus):
    """Regression: on a token-less gateway an open-time tenant override
    (benchmark accounting) must not lock the opener out of its own handle —
    follow-up requests resolve to the default tenant, and the owner check
    only applies when bearer auth is actually configured."""
    path, data = corpus["text"]
    with GatewayServer(cache_budget_bytes=1 << 20, max_workers=2) as gw:
        client = GatewayClient(gw.url, source=path, tenant="vip")
        assert client.tenant == "vip"  # accounting tenant server-side
        assert client.pread(0, 100) == data[:100]
        assert client.stat()["tenant"] == "vip"
        client.close()  # DELETE must succeed too
        status, _, _ = _get(gw, "/v1/archives/%s/bytes" % client.handle,
                            {"Range": "bytes=0-1"})
        assert status == 404  # actually closed, not leaked


def test_open_roots_jail(corpus, tmp_path):
    path, _ = corpus["text"]
    jail = tmp_path / "jail"
    jail.mkdir()
    inside = jail / "ok.gz"
    inside.write_bytes(gzip_bytes(b"jailed content", 6))
    with GatewayServer(
        cache_budget_bytes=1 << 20, max_workers=2,
        open_roots=[str(jail)], allow_remote_sources=False,
    ) as gw:
        ok = GatewayClient(gw.url, source=str(inside))
        assert b"".join(ok.stream()) == b"jailed content"
        ok.close()
        for bad in (path, str(jail) + "-sibling/x.gz", "http://127.0.0.1:1/x.gz"):
            with pytest.raises(GatewayError) as exc_info:
                GatewayClient(gw.url, source=bad)
            assert exc_info.value.status == 403, bad


@pytest.mark.stress
def test_tenant_flood_gets_429_other_tenant_unharmed(corpus):
    """A flooding tenant overruns its in-flight+queue budget and collects
    429 + Retry-After; a second tenant's requests all succeed meanwhile."""
    import threading

    path, data = corpus["mixed"]
    adm = TenantAdmission(
        tokens={"tok-f": "flood", "tok-v": "vip"},
        default_tenant=None,
        limits={"flood": TenantLimit(max_in_flight=1, max_queued=1)},
        retry_after=0.2,
    )
    with GatewayServer(
        cache_budget_bytes=1 << 20, max_workers=2, chunk_size=64 << 10,
        admission=adm, front_end_threads=4,
    ) as gw:
        cf = GatewayClient(gw.url, source=path, token="tok-f")
        cv = GatewayClient(gw.url, source=path, token="tok-v")
        results = {"flood": [], "vip": []}
        lock = threading.Lock()

        def hammer(tenant, handle, token, n):
            for _ in range(n):
                conn = _raw_conn(gw)
                try:
                    conn.request(
                        "GET", "/v1/archives/%s/bytes" % handle,
                        headers={"Authorization": "Bearer %s" % token,
                                 "Range": "bytes=0-65535"},
                    )
                    resp = conn.getresponse()
                    body = resp.read()
                    with lock:
                        results[tenant].append(
                            (resp.status, resp.getheader("Retry-After"), body)
                        )
                finally:
                    conn.close()

        threads = [
            threading.Thread(target=hammer, args=("flood", cf.handle, "tok-f", 6))
            for _ in range(5)
        ] + [threading.Thread(target=hammer, args=("vip", cv.handle, "tok-v", 6))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not any(t.is_alive() for t in threads), "flood scenario hung"

        flood_codes = [s for s, _, _ in results["flood"]]
        assert 429 in flood_codes, flood_codes
        for status, retry_after, _ in results["flood"]:
            if status == 429:
                assert retry_after is not None and float(retry_after) > 0
        # the flood tenant still gets *some* service (bounded, not starved)
        assert any(s == 206 for s in flood_codes)
        # the vip tenant never saw backpressure and got correct bytes
        assert all(s == 206 for s, _, _ in results["vip"]), results["vip"]
        assert all(b == data[:65536] for _, _, b in results["vip"])
        assert gw.metrics()["gateway"]["rejected_429"] >= 1
        cf.close()
        cv.close()


# ---------------------------------------------------------------------------
# cancellation: killed clients leave balanced books, no orphaned work
# ---------------------------------------------------------------------------

def _wait_books_balanced(server, timeout=20.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        snap = server.executor.snapshot()
        if (
            snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"]
            and snap["queued"] == 0
        ):
            return snap
        time.sleep(0.05)
    return server.executor.snapshot()


@pytest.mark.stress
def test_killed_mid_stream_client_leaves_no_orphaned_tasks(tmp_path):
    """A raw client starts a big chunked stream, reads a little, and drops
    the socket. The gateway must cancel end to end: handler cancelled,
    queued prefetches swept, and FairExecutor books balanced at quiescence
    (submitted == done + cancelled + queued) — no orphaned decompression
    tasks, and the gateway stays fully serviceable."""
    rng = np.random.default_rng(7)
    data = make_base64(rng, 2_000_000)
    path = tmp_path / "big.gz"
    path.write_bytes(gzip_bytes(data, 6))

    server = ArchiveServer(
        cache_budget_bytes=1 << 20,  # << working set: reads keep re-decoding
        max_workers=2, chunk_size=64 << 10, reader_parallelization=4,
    )
    with GatewayServer(server, stream_span=32 << 10) as gw:
        client = GatewayClient(gw.url, source=str(path))
        host, port = gw.url[len("http://"):].rsplit(":", 1)

        for round_ in range(3):
            sock = socket.create_connection((host, int(port)), timeout=10)
            sock.sendall(
                b"GET /v1/archives/%s/bytes HTTP/1.1\r\nHost: gw\r\n\r\n"
                % client.handle.encode()
            )
            assert sock.recv(4096)  # headers + first chunk(s) arrived
            sock.close()  # gone mid-stream

        snap = _wait_books_balanced(server)
        assert snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"], snap
        assert snap["queued"] == 0, snap

        gstats = gw.metrics()["gateway"]
        disconnects = (
            gstats.get("disconnects_mid_stream", 0)
            + gstats.get("disconnects_mid_request", 0)
            + gstats.get("cancelled_reads", 0)
        )
        assert disconnects >= 3, gstats

        # the gateway is still fully serviceable afterwards
        assert client.pread(1_000_000, 5000) == data[1_000_000:1_005_000]
        client.close()
    server.shutdown()


@pytest.mark.stress
def test_disconnect_during_cold_first_pass_cancels_bridged_await(tmp_path):
    """Disconnect while the handler is parked on a *cold* size() await: the
    books must still balance and later requests must succeed (the abandoned
    first pass either finishes in the background or is resumed on demand)."""
    rng = np.random.default_rng(8)
    data = make_base64(rng, 1_500_000)
    path = tmp_path / "cold.gz"
    path.write_bytes(gzip_bytes(data, 6))

    server = ArchiveServer(
        cache_budget_bytes=2 << 20, max_workers=2, chunk_size=32 << 10,
    )
    with GatewayServer(server, stream_span=32 << 10, front_end_threads=2) as gw:
        client = GatewayClient(gw.url, source=str(path))  # HEAD warms size
        h2 = server.open(str(path))  # second, never-touched handle: cold
        host, port = gw.url[len("http://"):].rsplit(":", 1)
        sock = socket.create_connection((host, int(port)), timeout=10)
        sock.sendall(
            b"GET /v1/archives/%s/bytes HTTP/1.1\r\nHost: gw\r\n\r\n"
            % h2.encode()
        )
        time.sleep(0.05)  # handler is now awaiting the cold size()
        sock.close()
        snap = _wait_books_balanced(server)
        assert snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"], snap
        # bridge accounting holds: nothing is both cancelled and started
        bridge = gw.metrics()["bridge"]
        assert bridge["submitted"] >= bridge["started"] + bridge["cancelled"]
        # gateway still serves the handle correctly afterwards
        status, _, body = _get(
            gw, "/v1/archives/%s/bytes" % h2, {"Range": "bytes=0-999"}
        )
        assert status == 206 and body == data[:1000]
        client.close()
    server.shutdown()


# ---------------------------------------------------------------------------
# datasets pointed at a gateway
# ---------------------------------------------------------------------------

def test_corpus_dataset_gateway_shard_matches_local(corpus):
    """A GzipCorpusDataset fed a ``gateway+http://...`` shard URL (and a
    GatewayClient instance) produces batches identical to a local dataset —
    decompression and index reuse live gateway-side."""
    path, _ = corpus["text"]
    kwargs = dict(seq_len=64, batch_size=2, read_block=16 * 1024, loop=False)
    local = GzipCorpusDataset([path], chunk_size=32 * 1024, parallelization=2, **kwargs)
    with GatewayServer(
        cache_budget_bytes=2 << 20, max_workers=2, chunk_size=32 << 10
    ) as gw:
        client = GatewayClient(gw.url, source=path)
        by_url = GzipCorpusDataset(
            ["gateway+" + gw.bytes_url(client.handle)], **kwargs
        )
        by_client = GzipCorpusDataset([client], **kwargs)
        for _ in range(4):
            lb = local.next_batch()
            ub = by_url.next_batch()
            cb = by_client.next_batch()
            assert lb is not None and ub is not None and cb is not None
            np.testing.assert_array_equal(lb["tokens"], ub["tokens"])
            np.testing.assert_array_equal(lb["tokens"], cb["tokens"])
        # checkpoint/restore seeks through the gateway in O(1)
        state = by_url.state_dict()
        by_url2 = GzipCorpusDataset(
            ["gateway+" + gw.bytes_url(client.handle)], **kwargs
        )
        by_url2.load_state_dict(state)
        np.testing.assert_array_equal(
            local.next_batch()["tokens"], by_url2.next_batch()["tokens"]
        )
        by_url.close()
        by_url2.close()
        by_client.close()  # must NOT close the caller-owned client
        assert client.pread(0, 4)  # still usable
        client.close()
    local.close()


# ---------------------------------------------------------------------------
# admission unit behavior (no sockets)
# ---------------------------------------------------------------------------

def test_admission_unit_limits_and_fifo():
    import asyncio

    adm = TenantAdmission(max_in_flight=1, max_queued=1, retry_after=0.3)

    async def scenario():
        await adm.acquire("t")  # occupies the slot
        waiter = asyncio.ensure_future(adm.acquire("t"))  # queues
        await asyncio.sleep(0)
        with pytest.raises(AdmissionDenied) as exc_info:
            await adm.acquire("t")  # over queue depth
        assert exc_info.value.retry_after == 0.3
        adm.release("t")  # hands the slot to the waiter
        await asyncio.wait_for(waiter, 5)
        snap = adm.snapshot()["t"]
        assert snap["admitted"] == 2 and snap["rejected"] == 1 and snap["waited"] == 1
        adm.release("t")
        assert adm.snapshot()["t"]["in_flight"] == 0

    asyncio.run(scenario())


def test_admission_waiter_cancelled_after_handoff_returns_slot():
    """Regression: release() hands its slot to a queued waiter via
    fut.set_result(); if that waiter's task is cancelled before it resumes,
    the slot must be returned — not leaked — or the tenant's capacity
    shrinks permanently."""
    import asyncio

    adm = TenantAdmission(max_in_flight=1, max_queued=2)

    async def scenario():
        await adm.acquire("t")
        waiter = asyncio.ensure_future(adm.acquire("t"))
        await asyncio.sleep(0)  # waiter is queued
        adm.release("t")  # slot handed to waiter's future...
        waiter.cancel()  # ...but the task dies before resuming
        await asyncio.gather(waiter, return_exceptions=True)
        snap = adm.snapshot()["t"]
        assert snap["in_flight"] == 0, snap  # slot came back
        assert snap["waiting"] == 0, snap
        # and the tenant still has full capacity
        await adm.acquire("t")
        adm.release("t")

    asyncio.run(scenario())


def test_missing_source_file_answers_404_not_disconnect(tmp_path):
    """Regression: open is lazy, so a registered-but-missing path fails at
    first read — the client must get a 404 response, not a bare connection
    drop booked as a disconnect (which chained RemoteFileReaders would
    retry through their whole backoff budget)."""
    with GatewayServer(cache_budget_bytes=1 << 20, max_workers=2) as gw:
        conn = _raw_conn(gw)
        try:
            conn.request(
                "POST", "/v1/archives",
                body=json.dumps({"source": str(tmp_path / "ghost.gz")}),
            )
            resp = conn.getresponse()
            handle = json.loads(resp.read())["handle"]
            assert resp.status == 201  # registration alone succeeds (lazy)
            conn.request("GET", "/v1/archives/%s/bytes" % handle,
                         headers={"Range": "bytes=0-9"})
            resp = conn.getresponse()
            resp.read()
            assert resp.status == 404, resp.status
        finally:
            conn.close()
        assert gw.metrics()["gateway"].get("disconnects_mid_stream", 0) == 0


def test_oversized_request_line_answered_431(corpus):
    path, _ = corpus["text"]
    with GatewayServer(cache_budget_bytes=1 << 20, max_workers=2) as gw:
        host, port = gw.url[len("http://"):].rsplit(":", 1)
        sock = socket.create_connection((host, int(port)), timeout=10)
        try:
            sock.sendall(b"GET /" + b"a" * 70_000 + b" HTTP/1.1\r\n\r\n")
            resp = sock.recv(4096)
            assert b"431" in resp.split(b"\r\n")[0], resp[:80]
        finally:
            sock.close()
        # gateway unharmed
        client = GatewayClient(gw.url, source=path)
        assert client.pread(0, 10)
        client.close()


def test_malformed_content_length_answered_400(corpus):
    path, _ = corpus["text"]
    with GatewayServer(cache_budget_bytes=1 << 20, max_workers=2) as gw:
        conn = _raw_conn(gw)
        try:
            conn.putrequest("POST", "/v1/archives", skip_accept_encoding=True)
            conn.putheader("Content-Length", "abc")
            conn.endheaders()
            resp = conn.getresponse()
            resp.read()
            assert resp.status == 400
        finally:
            conn.close()
        # the gateway survives and keeps serving
        client = GatewayClient(gw.url, source=path)
        assert client.pread(0, 10)
        client.close()


def test_admission_resolve_tokens():
    adm = TenantAdmission(tokens={"secret": "alpha"}, default_tenant="anon")
    assert adm.resolve(None) == "anon"
    assert adm.resolve("Bearer secret") == "alpha"
    assert adm.resolve("bearer secret") == "alpha"
    from repro.service.gateway.admission import Unauthorized

    with pytest.raises(Unauthorized):
        adm.resolve("Bearer wrong")
    with pytest.raises(Unauthorized):
        adm.resolve("Basic dXNlcjpwdw==")
    strict = TenantAdmission(tokens={"s": "a"}, default_tenant=None)
    with pytest.raises(Unauthorized):
        strict.resolve(None)


# ---------------------------------------------------------------------------
# byte-rate limiting: token bucket over bytes streamed
# ---------------------------------------------------------------------------

def test_charge_bytes_token_bucket_unit():
    """Deterministic bucket math via the `now` override: burst admits,
    overdraft admits once, deficit denies with the exact refill delay."""
    adm = TenantAdmission(byte_rate=1_000.0, byte_burst=10_000)
    # primed to the full burst on first charge
    adm.charge_bytes("t", 8_000, now=100.0)
    # 2_000 left: overdraft is allowed while the balance is positive
    adm.charge_bytes("t", 8_000, now=100.0)
    # balance is now -6_000: denied, Retry-After = deficit / rate
    with pytest.raises(AdmissionDenied) as exc_info:
        adm.charge_bytes("t", 100, now=100.0)
    assert exc_info.value.retry_after == pytest.approx(6.0)
    # refill: 6.5 s later the balance is +500 — admitted again (overdraft)
    adm.charge_bytes("t", 2_000, now=106.5)
    snap = adm.snapshot()["t"]
    assert snap["bytes_charged"] == 18_000
    assert snap["bytes_rejected"] == 100
    assert snap["byte_tokens"] == pytest.approx(-1_500.0)


def test_charge_bytes_per_tenant_overrides_and_unlimited_default():
    """TenantLimit.byte_rate scopes the bucket to one tenant; everyone
    else stays unlimited when no admission-wide rate is set."""
    adm = TenantAdmission(
        limits={"metered": TenantLimit(byte_rate=100.0, byte_burst=1_000)},
    )
    adm.charge_bytes("free", 10**9, now=0.0)  # unlimited: only counted
    assert adm.snapshot()["free"]["bytes_charged"] == 10**9
    adm.charge_bytes("metered", 900, now=0.0)
    adm.charge_bytes("metered", 900, now=0.0)  # overdraft (100 left)
    with pytest.raises(AdmissionDenied):
        adm.charge_bytes("metered", 1, now=0.0)


def test_byte_rate_rejects_zero_rate():
    with pytest.raises(ValueError):
        TenantAdmission(byte_rate=0.0)


def test_byte_flood_gets_429_other_tenant_unharmed(corpus):
    """A tenant streaming past its byte budget collects 429 + Retry-After
    on a clean connection (headers never went out); HEAD stays free; an
    unmetered tenant is untouched."""
    path, data = corpus["text"]
    span = 64 << 10
    adm = TenantAdmission(
        tokens={"tok-m": "metered", "tok-u": "unmetered"},
        default_tenant=None,
        limits={
            # burst covers one span plus change: request 1 charges the
            # bucket, request 2 overdrafts, request 3 must 429.
            "metered": TenantLimit(
                max_in_flight=4, max_queued=4,
                byte_rate=1_000.0, byte_burst=span + 1_000,
            ),
        },
    )
    with GatewayServer(
        cache_budget_bytes=2 << 20, max_workers=2, chunk_size=64 << 10,
        admission=adm,
    ) as gw:
        cm = GatewayClient(gw.url, source=path, token="tok-m")
        cu = GatewayClient(gw.url, source=path, token="tok-u")
        rng_hdr = {"Range": "bytes=0-%d" % (span - 1)}

        def req(handle, token, method="GET"):
            conn = _raw_conn(gw)
            try:
                conn.request(
                    method, "/v1/archives/%s/bytes" % handle,
                    headers={"Authorization": "Bearer %s" % token, **rng_hdr},
                )
                resp = conn.getresponse()
                return resp.status, dict(resp.getheaders()), resp.read()
            finally:
                conn.close()

        s1, _, b1 = req(cm.handle, "tok-m")
        s2, _, b2 = req(cm.handle, "tok-m")
        assert (s1, s2) == (206, 206)
        assert b1 == b2 == data[:span]
        s3, h3, b3 = req(cm.handle, "tok-m")
        assert s3 == 429
        assert int(h3["Retry-After"]) >= 1
        assert b"byte rate" in b3
        # HEAD is never charged: metadata stays reachable under deficit
        sh, hh, _ = req(cm.handle, "tok-m", method="HEAD")
        assert sh == 206 and int(hh["Content-Length"]) == span
        # the unmetered tenant streams freely throughout
        for _ in range(3):
            su, _, bu = req(cu.handle, "tok-u")
            assert su == 206 and bu == data[:span]
        snap = gw.metrics()["admission"]
        assert snap["metered"]["bytes_rejected"] >= span
        assert snap["unmetered"]["bytes_charged"] >= 3 * span
        assert gw.metrics()["gateway"]["rejected_429"] >= 1
        cm.close()
        cu.close()
