"""FairExecutor: byte-weighted DRR, priority lanes, cancel accounting.

The scheduler is task-count fair no more: tasks declare byte costs, tenant
queues bank deficit in quanta, interactive tasks jump their own tenant's
batch backlog. These tests pin the arbitration semantics the service layer
relies on (see src/repro/service/scheduler.py).
"""

import threading
import time

import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.service import FairExecutor

Q = 1000  # quantum for these tests: small ints keep the math readable


def _gated_executor(tenant="light", **kwargs):
    """FairExecutor(1) whose single worker is parked on a gate task, so
    everything submitted afterwards queues up and dispatches in one
    deterministic burst once the gate opens."""
    ex = FairExecutor(1, quantum_bytes=Q, **kwargs)
    gate = threading.Event()
    started = threading.Event()

    def blocker():
        started.set()
        gate.wait(10)

    ex.submit(tenant, blocker)
    assert started.wait(5)
    return ex, gate


def test_drr_byte_skew_heavy_tenant_bounded_by_share():
    """100:1 byte skew: a heavy tenant's dispatched bytes can never lead the
    light tenant's by more than one task plus one quantum while both queues
    are non-empty."""
    ex, gate = _gated_executor()
    order = []
    lock = threading.Lock()

    def run(tenant, cost):
        with lock:
            order.append((tenant, cost))

    heavy_cost = 100 * Q
    futs = []
    # Heavy queue first: under task-count RR it would get every other slot.
    for _ in range(5):
        futs.append(ex.submit("heavy", run, "heavy", heavy_cost, _cost=heavy_cost))
    for _ in range(600):
        futs.append(ex.submit("light", run, "light", Q, _cost=Q))
    gate.set()
    for f in futs:
        f.result(30)

    # Prefix invariant at every heavy dispatch: by the time a heavy task is
    # granted 100Q of work, the light tenant must have received within one
    # task+quantum as much.
    heavy_bytes = light_bytes = 0
    heavy_seen = 0
    for tenant, cost in order:
        if tenant == "heavy":
            heavy_bytes += cost
            heavy_seen += 1
            if heavy_seen <= 5 and light_bytes + heavy_cost + Q < heavy_bytes:
                raise AssertionError(
                    "heavy tenant over its byte share: heavy=%d light=%d"
                    % (heavy_bytes, light_bytes)
                )
        else:
            light_bytes += cost
    # And the first heavy dispatch had to bank ~100 quanta of deficit first.
    first_heavy = next(i for i, (t, _) in enumerate(order) if t == "heavy")
    assert first_heavy >= 50, f"heavy dispatched too early: position {first_heavy}"

    snap = ex.snapshot()
    assert snap["dispatched_bytes_per_tenant"]["heavy"] == 5 * heavy_cost
    assert snap["dispatched_bytes_per_tenant"]["light"] >= 600 * Q
    ex.shutdown(wait=True)


def test_task_rr_mode_restores_legacy_task_count_fairness():
    """fairness='task_rr' ignores costs: heavy and light alternate."""
    ex, gate = _gated_executor(fairness="task_rr")
    order = []
    lock = threading.Lock()

    def run(tag):
        with lock:
            order.append(tag)

    futs = [ex.submit("heavy", run, "h", _cost=100 * Q) for _ in range(10)]
    futs += [ex.submit("light2", run, "l", _cost=Q) for _ in range(10)]
    gate.set()
    for f in futs:
        f.result(10)
    # Legacy RR alternates tenants task-by-task regardless of cost.
    assert order.index("h") <= 2
    ex.shutdown(wait=True)


def test_priority_lane_jumps_own_tenant_batch_backlog():
    ex, gate = _gated_executor(tenant="t")
    order = []
    lock = threading.Lock()

    def run(tag):
        with lock:
            order.append(tag)

    view = ex.view("t")
    view.submit_hinted(run, "batch1", cost=Q, priority=False)
    view.submit_hinted(run, "batch2", cost=Q, priority=False)
    view.submit_hinted(run, "interactive", cost=Q, priority=True)
    gate.set()
    time.sleep(0)
    for _ in range(100):
        with lock:
            if len(order) == 3:
                break
        time.sleep(0.05)
    assert order == ["interactive", "batch1", "batch2"]
    ex.shutdown(wait=True)


def test_boost_promotes_queued_batch_task_to_priority_lane():
    """A blocking read that joins an already-queued batch prefetch upgrades
    it in place (dedup would otherwise drop the priority hint)."""
    ex, gate = _gated_executor(tenant="t")
    order = []
    lock = threading.Lock()

    def run(tag):
        with lock:
            order.append(tag)

    view = ex.view("t")
    b1 = view.submit_hinted(run, "b1", cost=Q, priority=False)
    shared = view.submit_hinted(run, "shared", cost=Q, priority=False)
    assert view.boost(shared) is True
    assert view.boost(shared) is False  # already in the priority lane
    gate.set()
    shared.result(5)
    b1.result(5)
    assert order[0] == "shared"
    done = object()
    fut_done = ex.submit("t", lambda: done)
    assert fut_done.result(5) is done
    assert ex.boost(fut_done) is False  # finished tasks cannot be promoted
    ex.shutdown(wait=True)


def test_priority_does_not_buy_cross_tenant_bandwidth():
    """A tenant cannot starve others by marking everything interactive: the
    lane only reorders within the tenant; DRR still charges full cost."""
    ex, gate = _gated_executor()
    order = []
    lock = threading.Lock()

    def run(tag):
        with lock:
            order.append(tag)

    vh = ex.view("hog")
    for i in range(5):
        vh.submit_hinted(run, ("hog", i), cost=100 * Q, priority=True)
    for i in range(200):
        ex.submit("light", run, ("light", i), _cost=Q)
    gate.set()
    ex_futs_done = threading.Event()

    def wait_done():
        while True:
            with lock:
                if len(order) == 205:
                    ex_futs_done.set()
                    return
            time.sleep(0.02)

    threading.Thread(target=wait_done, daemon=True).start()
    assert ex_futs_done.wait(30)
    first_hog = next(i for i, t in enumerate(order) if t[0] == "hog")
    assert first_hog >= 50, "priority lane leaked across tenants"
    ex.shutdown(wait=True)


# ---------------------------------------------------------------------------
# cancel accounting: submitted == done + cancelled + queued, always
# ---------------------------------------------------------------------------

def _books(ex):
    snap = ex.snapshot()
    return snap["submitted"], snap["done"], snap["cancelled"], snap["queued"]


def _drain(ex, timeout=5.0):
    """Wait until nothing is queued or running."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        snap = ex.snapshot()
        if snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"]:
            return snap
        time.sleep(0.01)
    return ex.snapshot()


def test_snapshot_books_balance_after_cancel_view():
    ex, gate = _gated_executor(tenant="t")
    view = ex.view("t")
    futs = [view.submit(lambda: None) for _ in range(7)]
    other = ex.submit("u", lambda: "u-ran")
    cancelled = view.cancel_pending()
    assert cancelled == 7
    gate.set()
    assert other.result(5) == "u-ran"
    snap = _drain(ex)
    assert snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"], snap
    assert snap["queued"] == 0
    assert all(f.cancelled() for f in futs)
    ex.shutdown(wait=True)


def test_snapshot_books_balance_after_cancel_tenant():
    ex, gate = _gated_executor(tenant="t")
    for _ in range(5):
        ex.submit("victim", lambda: None)
    keep = ex.submit("t", lambda: "kept")
    assert ex.cancel_tenant("victim") == 5
    gate.set()
    assert keep.result(5) == "kept"
    snap = _drain(ex)
    assert snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"], snap
    ex.shutdown(wait=True)


def test_snapshot_books_balance_after_direct_future_cancel():
    """A future cancelled by its owner while queued still reaches a worker
    (set_running_or_notify_cancel -> False) and must be booked cancelled."""
    ex, gate = _gated_executor(tenant="t")
    fut = ex.submit("t", lambda: "never")
    assert fut.cancel()
    gate.set()
    snap = _drain(ex)
    assert snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"], snap
    assert snap["queued"] == 0
    ex.shutdown(wait=True)


def test_snapshot_books_balance_after_arbitrary_cancel_sequence():
    ex, gate = _gated_executor(tenant="seed")
    views = [ex.view("a"), ex.view("a"), ex.view("b")]
    futs = []
    for i in range(30):
        v = views[i % 3]
        if i % 4 == 0:
            futs.append(v.submit_hinted(lambda: None, cost=(i + 1) * 100, priority=bool(i % 2)))
        else:
            futs.append(v.submit(lambda: None))
    views[0].cancel_pending()
    ex.cancel_tenant("b")
    for f in futs[::5]:
        f.cancel()
    gate.set()
    snap = _drain(ex)
    assert snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"], snap
    assert snap["queued"] == 0
    ex.shutdown(wait=True)
    # shutdown(cancel_futures) path also keeps the books closed
    ex2 = FairExecutor(1, quantum_bytes=Q)
    ev = threading.Event()
    ex2.submit("x", ev.wait, 5)
    for _ in range(4):
        ex2.submit("x", lambda: None)
    time.sleep(0.05)
    ex2.shutdown(wait=False, cancel_futures=True)
    ev.set()
    snap = _drain(ex2)
    assert snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"], snap


# ---------------------------------------------------------------------------
# property: DRR never starves a non-empty queue
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    st.lists(
        st.tuples(
            st.sampled_from(["a", "b", "c"]),
            st.integers(min_value=1, max_value=20 * Q),
            st.booleans(),
        ),
        min_size=1,
        max_size=80,
    )
)
def test_drr_never_starves_any_queue(tasks):
    """Whatever the mix of tenants, costs, and lanes, every submitted task
    eventually dispatches (DRR credits every non-empty queue each pass, so a
    huge head-of-line task only delays, never blocks)."""
    ex = FairExecutor(2, quantum_bytes=Q)
    try:
        futs = [
            ex.submit(tenant, lambda: True, _cost=cost, _priority=pri)
            for tenant, cost, pri in tasks
        ]
        for f in futs:
            assert f.result(20) is True
        snap = ex.snapshot()
        assert snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"]
        assert snap["queued"] == 0
    finally:
        ex.shutdown(wait=False, cancel_futures=True)


def test_rejects_bad_config():
    with pytest.raises(ValueError):
        FairExecutor(0)
    with pytest.raises(ValueError):
        FairExecutor(1, quantum_bytes=0)
    with pytest.raises(ValueError):
        FairExecutor(1, fairness="priority-inversion")
    ex = FairExecutor(1, quantum_bytes=Q)
    with pytest.raises(ValueError):
        ex.set_tenant_quantum("t", 0)
    ex.shutdown(wait=True)


# ---------------------------------------------------------------------------
# weighted DRR quanta (paying tenants get a larger quantum)
# ---------------------------------------------------------------------------

def test_weighted_quantum_scales_dispatched_byte_share():
    """Two tenants with identical equal-cost backlogs and one worker: a
    tenant with quantum factor 4 must receive ~4x the dispatched bytes of a
    factor-1 tenant at every prefix of the dispatch order."""
    ex, gate = _gated_executor()
    ex.set_tenant_quantum("vip", 4.0)
    order = []
    lock = threading.Lock()

    def run(tenant):
        with lock:
            order.append(tenant)

    futs = []
    for _ in range(80):
        futs.append(ex.submit("vip", run, "vip", _cost=4 * Q))
        futs.append(ex.submit("std", run, "std", _cost=4 * Q))
    gate.set()
    for f in futs:
        f.result(30)
    # Prefix shares *while both queues are non-empty* (classic WDRR bound):
    # vip's task count should run ~4x std's, with one-task slack per side.
    # Once vip's backlog drains (vip == 80) std catches up alone.
    vip = std = 0
    for tenant in order:
        if tenant == "vip":
            vip += 1
        else:
            std += 1
        if vip >= 80:
            break
        if std >= 2:
            assert vip + 1 >= 3 * (std - 1), (
                "vip under its weighted share at prefix: vip=%d std=%d" % (vip, std)
            )
    # At the moment vip's backlog drained, std must not have received more
    # than ~1/4 of vip's dispatches (plus slack for the startup transient).
    assert std <= 80 // 4 + 4, "std over its share during contention: %d" % std
    snap = ex.snapshot()
    assert snap["tenant_quanta"] == {"vip": 4.0}
    assert snap["dispatched_bytes_per_tenant"]["vip"] == 80 * 4 * Q
    ex.shutdown(wait=True)


def test_cancel_view_batch_only_spares_priority_lane():
    """The gateway's disconnect sweep cancels only queued *batch* tasks —
    priority-lane tasks (someone blocks on them) survive."""
    ex, gate = _gated_executor(tenant="t")
    view = ex.view("t")
    batch = [view.submit_hinted(lambda: "b", priority=False) for _ in range(3)]
    pri = view.submit_hinted(lambda: "p", priority=True)
    assert view.cancel_pending(batch_only=True) == 3
    gate.set()
    assert pri.result(5) == "p"
    assert all(f.cancelled() for f in batch)
    snap = _drain(ex)
    assert snap["submitted"] == snap["done"] + snap["cancelled"] + snap["queued"], snap
    assert snap["cancelled"] == 3
    ex.shutdown(wait=True)


# ---------------------------------------------------------------------------
# EWMA cost correction: observed runtime re-prices claimed byte costs
# ---------------------------------------------------------------------------

def test_cost_correction_off_by_default():
    """Default behavior is the documented pure-DRR arbitration: no
    correction state accumulates and the snapshot says so."""
    ex = FairExecutor(1)
    fut = ex.submit("t", lambda: None, _cost=5 * Q)
    fut.result(10)
    snap = ex.snapshot()["cost_correction"]
    assert snap == {"enabled": False, "throughput_bps": None, "per_tenant": {}}
    ex.shutdown(wait=True)


def test_cost_correction_validates_alpha():
    with pytest.raises(ValueError):
        FairExecutor(1, cost_correction=True, correction_alpha=0.0)
    with pytest.raises(ValueError):
        FairExecutor(1, cost_correction=True, correction_alpha=1.5)


def test_cost_correction_learns_underclaimed_costs():
    """Two tenants run identical work, but one claims 100x fewer bytes.
    The EWMA of observed runtime must drive the under-claimer's correction
    factor above the honest tenant's (its tasks run far longer than their
    claimed bytes imply at the fleet's observed throughput)."""
    ex = FairExecutor(1, cost_correction=True, correction_alpha=0.5)

    def work():
        time.sleep(0.02)

    futs = []
    for _ in range(8):
        futs.append(ex.submit("honest", work, _cost=100 * Q))
        futs.append(ex.submit("liar", work, _cost=Q))
    for f in futs:
        f.result(30)
    cc = ex.snapshot()["cost_correction"]
    assert cc["enabled"]
    assert cc["throughput_bps"] > 0
    liar = cc["per_tenant"]["liar"]
    honest = cc["per_tenant"]["honest"]
    assert liar > honest, (liar, honest)
    assert liar > 2.0, liar  # clamped EWMA converges toward x16
    assert honest < 2.0, honest
    # Raw claimed bytes are still what the ledger books (the correction
    # re-prices arbitration, not accounting).
    snap = ex.snapshot()
    assert snap["dispatched_bytes_per_tenant"]["honest"] == 8 * 100 * Q
    assert snap["dispatched_bytes_per_tenant"]["liar"] == 8 * Q
    ex.shutdown(wait=True)


def test_cost_correction_factor_is_clamped():
    """Even an absurd claim (1 byte for a long task) stays within the
    [1/16, 16] clamp, so a misbehaving tenant cannot push another into
    starvation through the correction itself."""
    ex = FairExecutor(1, cost_correction=True, correction_alpha=1.0)
    futs = [ex.submit("wild", time.sleep, 0.03, _cost=1) for _ in range(3)]
    futs += [ex.submit("calm", time.sleep, 0.001, _cost=10**9) for _ in range(3)]
    for f in futs:
        f.result(30)
    per = ex.snapshot()["cost_correction"]["per_tenant"]
    assert 1.0 / 16.0 <= per["wild"] <= 16.0
    assert 1.0 / 16.0 <= per["calm"] <= 16.0
    ex.shutdown(wait=True)
