#!/usr/bin/env python3
"""Regenerate the committed third-party conformance fixtures.

Deliberately standard-library only and independent of the ``repro``
package: these archives are built straight from the published format
specifications (the htslib SAM/BGZF spec and the zstd seekable-format
RFC draft), so the tests that read them back exercise our parsers against
an implementation that shares no code with them. Output is byte-for-byte
deterministic — re-running this script must produce identical files.

    python tests/data/make_fixtures.py [outdir]

Fixtures:

``conformance_payload.bin``
    The shared decompressed payload (~96 KiB, seeded LCG text).
``conformance_bgzip.gz``
    BGZF, bgzip-style: several full members, one member carrying an
    *extra* FEXTRA subfield before the BC subfield (spec-legal — parsers
    must walk subfields, not assume BC comes first), and the canonical
    28-byte EOF member.
``conformance_seekable.zst``
    Zstd seekable format, zstd-CLI style: independent frames (raw blocks,
    so no compressor is needed to build them and any conformant
    decompressor can read them), footer seek table WITH per-frame XXH64
    checksums (descriptor bit 7 — 12-byte entries).
"""

import os
import struct
import sys
import zlib

# --------------------------------------------------------------------------
# deterministic payload (LCG; no randomness sources)
# --------------------------------------------------------------------------

WORDS = (
    b"annotate", b"archive", b"block", b"checksum", b"decode", b"frame",
    b"gzip", b"huffman", b"index", b"member", b"offset", b"parallel",
    b"random", b"seek", b"stream", b"window",
)


def make_payload(nbytes: int = 96 << 10, seed: int = 0x2545F491) -> bytes:
    state = seed
    out = bytearray()
    while len(out) < nbytes:
        state = (state * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        out += WORDS[(state >> 33) % len(WORDS)]
        out += b" " if (state >> 21) % 13 else b"\n"
    return bytes(out[:nbytes])


# --------------------------------------------------------------------------
# BGZF (htslib SAM spec section 4.1)
# --------------------------------------------------------------------------

BGZF_EOF = bytes.fromhex("1f8b08040000000000ff0600424302001b0003000000000000000000")


def bgzf_member(block: bytes, extra_subfields: bytes = b"") -> bytes:
    """One BGZF member; ``extra_subfields`` go *before* the BC subfield."""
    c = zlib.compressobj(6, zlib.DEFLATED, -15)
    raw = c.compress(block) + c.flush(zlib.Z_FINISH)
    bc = b"BC" + struct.pack("<HH", 2, 0)  # BSIZE patched below
    xtra = extra_subfields + bc
    header = b"\x1f\x8b\x08\x04\x00\x00\x00\x00\x00\xff" + struct.pack("<H", len(xtra)) + xtra
    footer = struct.pack("<II", zlib.crc32(block) & 0xFFFFFFFF, len(block) & 0xFFFFFFFF)
    member = bytearray(header + raw + footer)
    bsize_at = 12 + len(extra_subfields) + 4  # after the BC id + length
    member[bsize_at : bsize_at + 2] = struct.pack("<H", len(member) - 1)
    return bytes(member)


def make_bgzf(payload: bytes, block_size: int = 24 << 10) -> bytes:
    members = []
    for i, off in enumerate(range(0, len(payload), block_size)):
        # Second member: a vendor subfield ahead of BC (4-byte payload),
        # like bgzip files postprocessed by annotating tools.
        extra = b"RG" + struct.pack("<H", 4) + b"conf" if i == 1 else b""
        members.append(bgzf_member(payload[off : off + block_size], extra))
    members.append(BGZF_EOF)
    return b"".join(members)


# --------------------------------------------------------------------------
# zstd seekable (raw-block frames; no compressor required)
# --------------------------------------------------------------------------


def xxh64(data: bytes, seed: int = 0) -> int:
    """Pure-python XXH64 (the seekable format's per-frame checksum)."""
    P1, P2, P3 = 0x9E3779B185EBCA87, 0xC2B2AE3D27D4EB4F, 0x165667B19E3779F9
    P4, P5 = 0x85EBCA77C2B2AE63, 0x27D4EB2F165667C5
    M = (1 << 64) - 1

    def rotl(x, r):
        return ((x << r) | (x >> (64 - r))) & M

    n = len(data)
    pos = 0
    if n >= 32:
        v1 = (seed + P1 + P2) & M
        v2 = (seed + P2) & M
        v3 = seed & M
        v4 = (seed - P1) & M
        while pos <= n - 32:
            for i, v in enumerate((v1, v2, v3, v4)):
                lane = struct.unpack_from("<Q", data, pos + 8 * i)[0]
                v = (v + lane * P2) & M
                v = rotl(v, 31)
                v = (v * P1) & M
                if i == 0:
                    v1 = v
                elif i == 1:
                    v2 = v
                elif i == 2:
                    v3 = v
                else:
                    v4 = v
            pos += 32
        h = (rotl(v1, 1) + rotl(v2, 7) + rotl(v3, 12) + rotl(v4, 18)) & M
        for v in (v1, v2, v3, v4):
            v = rotl((v * P2) & M, 31)
            v = (v * P1) & M
            h = (((h ^ v) * P1) + P4) & M
    else:
        h = (seed + P5) & M
    h = (h + n) & M
    while pos <= n - 8:
        k = struct.unpack_from("<Q", data, pos)[0]
        k = rotl((k * P2) & M, 31)
        k = (k * P1) & M
        h = ((rotl(h ^ k, 27) * P1) + P4) & M
        pos += 8
    if pos <= n - 4:
        k = struct.unpack_from("<I", data, pos)[0]
        h = ((rotl(h ^ (k * P1) & M, 23) * P2) + P3) & M
        pos += 4
    while pos < n:
        h = ((rotl(h ^ (data[pos] * P5) & M, 11) * P1)) & M
        pos += 1
    h ^= h >> 33
    h = (h * P2) & M
    h ^= h >> 29
    h = (h * P3) & M
    h ^= h >> 32
    return h


def zstd_raw_frame(content: bytes, block_size: int = 16 << 10) -> bytes:
    """A spec-valid zstd frame holding ``content`` in raw (stored) blocks.

    Frame header descriptor 0xA0: single-segment, 4-byte frame content
    size, no dictionary, no content checksum.
    """
    out = bytearray()
    out += struct.pack("<I", 0xFD2FB528)  # frame magic
    out += bytes([0xA0]) + struct.pack("<I", len(content))
    offsets = list(range(0, len(content), block_size)) or [0]
    for i, off in enumerate(offsets):
        block = content[off : off + block_size]
        last = 1 if i == len(offsets) - 1 else 0
        # 3-byte block header: last(1) | type(2, 0=raw) | size(21)
        hdr = last | (0 << 1) | (len(block) << 3)
        out += struct.pack("<I", hdr)[:3] + block
    return bytes(out)


def make_zstd_seekable(payload: bytes, frame_size: int = 32 << 10) -> bytes:
    out = bytearray()
    entries = bytearray()
    for off in range(0, len(payload), frame_size):
        content = payload[off : off + frame_size]
        frame = zstd_raw_frame(content)
        out += frame
        entries += struct.pack(
            "<III", len(frame), len(content), xxh64(content) & 0xFFFFFFFF
        )
    n = len(entries) // 12
    table = bytes(entries) + struct.pack("<IBI", n, 0x80, 0x8F92EAB1)
    out += struct.pack("<II", 0x184D2A5E, len(table)) + table
    return bytes(out)


def main(outdir: str) -> None:
    payload = make_payload()
    fixtures = {
        "conformance_payload.bin": payload,
        "conformance_bgzip.gz": make_bgzf(payload),
        "conformance_seekable.zst": make_zstd_seekable(payload),
    }
    for name, blob in fixtures.items():
        path = os.path.join(outdir, name)
        with open(path, "wb") as f:
            f.write(blob)
        print("%s: %d bytes" % (path, len(blob)))


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else os.path.dirname(os.path.abspath(__file__)))
