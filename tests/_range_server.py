"""Hermetic threaded HTTP range server with fault injection.

Serves one byte payload on a 127.0.0.1 ephemeral port with single-range
GET/HEAD, ETag + Last-Modified validators, and ``If-Range`` semantics
(mismatched validator -> 200 full body, per RFC 9110). A thread-safe
``FaultPlan`` injects the failure modes a real object store exhibits:

  * ``inject_503(n)``     — next n requests answer 503 (retryable)
  * ``inject_short(n)``   — next n range bodies are cut in half mid-wire
                            (Content-Length promises more; connection drops)
  * ``drop_ranges``       — ignore Range headers entirely (200 full body)
  * ``latency``           — per-request sleep, for benchmark latency models
  * ``flip_etag()``       — swap payload/ETag at runtime (object replaced)

Used by the FileReader contract suite, the remote-backend tests, and
``benchmarks/bench_service.bench_remote``. Loopback only — no external
network — so tier-1 stays offline-safe.
"""

from __future__ import annotations

import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Tuple


class FaultPlan:
    """Mutable, thread-safe schedule of injected faults."""

    def __init__(self):
        self._lock = threading.Lock()
        self.fail_503 = 0
        self.short_bodies = 0
        self.misaligned = 0
        self.drop_ranges = False
        self.strip_etag = False  # model an intermediary stripping ETag
        self.latency = 0.0

    def inject_503(self, n: int = 1) -> None:
        with self._lock:
            self.fail_503 += n

    def inject_short(self, n: int = 1) -> None:
        with self._lock:
            self.short_bodies += n

    def inject_misaligned(self, n: int = 1) -> None:
        """Next n range responses answer for a shifted start offset (a
        misbehaving cache serving a differently-aligned partial object)."""
        with self._lock:
            self.misaligned += n

    def _take(self, attr: str) -> bool:
        with self._lock:
            n = getattr(self, attr)
            if n > 0:
                setattr(self, attr, n - 1)
                return True
            return False

    def take_503(self) -> bool:
        return self._take("fail_503")

    def take_short(self) -> bool:
        return self._take("short_bodies")

    def take_misaligned(self) -> bool:
        return self._take("misaligned")


class RangeHTTPServer:
    """One-payload HTTP server: ``with RangeHTTPServer(blob) as srv: srv.url``."""

    def __init__(
        self,
        payload: bytes,
        *,
        etag: Optional[str] = '"rs-1"',
        send_validators: bool = True,
        latency: float = 0.0,
    ):
        self._lock = threading.Lock()
        self._payload = bytes(payload)
        # send_validators=False models gateways that return neither ETag nor
        # Last-Modified (clients must fall back to content digests).
        self._etag = etag if send_validators else None
        self._last_modified = (
            "Mon, 27 Jul 2026 00:00:00 GMT" if send_validators else None
        )
        self.faults = FaultPlan()
        self.faults.latency = latency
        self.request_count = 0
        self.range_requests = 0
        self.head_requests = 0

        outer = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"  # keep-alive: exercise conn reuse

            def log_message(self, *args):  # noqa: D102 - silence stderr
                pass

            def _snapshot(self) -> Tuple[bytes, Optional[str], Optional[str]]:
                with outer._lock:
                    outer.request_count += 1
                    return outer._payload, outer._etag, outer._last_modified

            def _common_headers(self, etag: Optional[str], lm: Optional[str]) -> None:
                if etag is not None and not outer.faults.strip_etag:
                    self.send_header("ETag", etag)
                if lm is not None:
                    self.send_header("Last-Modified", lm)
                self.send_header("Accept-Ranges", "bytes")

            def _send_503(self) -> None:
                body = b"injected server error"
                self.send_response(503)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_HEAD(self):  # noqa: N802 - http.server API
                payload, etag, lm = self._snapshot()
                with outer._lock:
                    outer.head_requests += 1
                if outer.faults.latency:
                    time.sleep(outer.faults.latency)
                if outer.faults.take_503():
                    self._send_503()
                    return
                self.send_response(200)
                self._common_headers(etag, lm)
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()

            def do_GET(self):  # noqa: N802 - http.server API
                payload, etag, lm = self._snapshot()
                if outer.faults.latency:
                    time.sleep(outer.faults.latency)
                if outer.faults.take_503():
                    self._send_503()
                    return

                rng = _parse_range(self.headers.get("Range"), len(payload))
                if_range = self.headers.get("If-Range")
                use_range = (
                    rng is not None
                    and not outer.faults.drop_ranges
                    # RFC 9110 If-Range: serve the range only if the
                    # validator still matches, else the full current body.
                    and not (if_range is not None and if_range != etag)
                )
                if rng is not None:
                    with outer._lock:
                        outer.range_requests += 1
                if use_range:
                    a, b = rng
                    if outer.faults.take_misaligned() and a > 0:
                        a, b = a - 1, b - 1  # answer for a shifted window
                    if a >= len(payload):
                        self.send_response(416)
                        self._common_headers(etag, lm)
                        self.send_header("Content-Range", "bytes */%d" % len(payload))
                        self.send_header("Content-Length", "0")
                        self.end_headers()
                        return
                    body = payload[a : b + 1]
                    self.send_response(206)
                    self._common_headers(etag, lm)
                    self.send_header(
                        "Content-Range", "bytes %d-%d/%d" % (a, a + len(body) - 1, len(payload))
                    )
                else:
                    body = payload
                    self.send_response(200)
                    self._common_headers(etag, lm)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                if outer.faults.take_short():
                    # Promise the full body, deliver half, drop the
                    # connection: the client sees IncompleteRead.
                    self.wfile.write(body[: len(body) // 2])
                    self.close_connection = True
                    return
                self.wfile.write(body)

        self._httpd = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(target=self._httpd.serve_forever, daemon=True)
        self._thread.start()

    # -- runtime control ----------------------------------------------------

    @property
    def url(self) -> str:
        return "http://127.0.0.1:%d/payload.gz" % self._httpd.server_address[1]

    @property
    def etag(self) -> str:
        with self._lock:
            return self._etag

    def set_payload(self, payload: bytes, etag: Optional[str]) -> None:
        """Replace the object (new content, new validator — or none)."""
        with self._lock:
            self._payload = bytes(payload)
            self._etag = etag
            if etag is not None or self._last_modified is not None:
                self._last_modified = "Tue, 28 Jul 2026 00:00:00 GMT"

    def flip_etag(self, etag: str = '"rs-2"') -> None:
        """Change the validator without changing content (metadata rewrite)."""
        with self._lock:
            self._etag = etag
            self._last_modified = "Tue, 28 Jul 2026 00:00:00 GMT"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self) -> "RangeHTTPServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


_RANGE_RE = re.compile(r"^bytes=(\d+)-(\d*)$")


def _parse_range(value: Optional[str], total: int) -> Optional[Tuple[int, int]]:
    """'bytes=a-b' / 'bytes=a-' -> (a, b_inclusive); None when absent/odd."""
    if not value:
        return None
    m = _RANGE_RE.match(value.strip())
    if not m:
        return None
    a = int(m.group(1))
    b = int(m.group(2)) if m.group(2) else total - 1
    return a, min(b, total - 1)
