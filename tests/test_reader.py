import gzip as _gzip
import io
import threading

import numpy as np
import pytest

from repro.core import GzipIndex, ParallelGzipReader
from repro.core.errors import GzipFooterError
from repro.core.synth import COMPRESSORS

from conftest import gzip_bytes, make_base64, make_random, make_text


@pytest.mark.parametrize("parallelization", [1, 3])
@pytest.mark.parametrize("kind", ["text", "base64", "random"])
def test_roundtrip(rng, kind, parallelization):
    data = {"text": make_text, "base64": make_base64, "random": make_random}[kind](rng, 700_000)
    comp = gzip_bytes(data, 6)
    with ParallelGzipReader(comp, parallelization=parallelization, chunk_size=64 * 1024) as r:
        assert r.read() == data


@pytest.mark.parametrize("name", sorted(COMPRESSORS))
def test_roundtrip_all_compressor_variants(rng, name):
    data = make_text(rng, 400_000)
    comp = COMPRESSORS[name](data)
    assert _gzip.decompress(comp) == data  # sanity: variants are valid gzip
    with ParallelGzipReader(comp, parallelization=3, chunk_size=48 * 1024) as r:
        assert r.read() == data


@pytest.mark.parametrize("parallelization", [1, 3])
def test_roundtrip_codecs(rng, codec_case, parallelization):
    """The same reader machinery serves every codec bit-identically; the
    resolved codec is the one the archive was written with (auto-detected —
    no tag passed anywhere)."""
    data = make_text(rng, 500_000)
    comp = codec_case.compress(data)
    with ParallelGzipReader(comp, parallelization=parallelization, chunk_size=64 * 1024) as r:
        assert r.codec.tag == codec_case.tag
        assert r.index.codec_tag == codec_case.tag
        assert r.read() == data


def test_random_access_codecs(rng, codec_case):
    data = make_base64(rng, 600_000)
    comp = codec_case.compress(data)
    with ParallelGzipReader(comp, parallelization=2, chunk_size=64 * 1024) as r:
        for off in [0, 123_457, 599_000, 5, 300_000]:
            r.seek(off)
            assert r.read(1000) == data[off : off + 1000]


def test_index_export_import_codecs(rng, codec_case):
    """A codec's index round-trips through the versioned blob format and a
    re-open with it needs zero speculative work."""
    data = make_text(rng, 400_000)
    comp = codec_case.compress(data)
    r = ParallelGzipReader(comp, parallelization=2, chunk_size=48 * 1024)
    assert r.read() == data
    blob = io.BytesIO()
    r.export_index(blob)
    r.close()

    idx = GzipIndex.from_bytes(blob.getvalue())
    assert idx.codec_tag == codec_case.tag
    assert idx.finalized and idx.decompressed_size == len(data)
    with ParallelGzipReader(comp, parallelization=2, chunk_size=48 * 1024, index=idx) as r2:
        assert r2.codec.tag == codec_case.tag
        r2.seek(123_456)
        assert r2.read(20_000) == data[123_456:143_456]
        assert r2.stats()["fetcher"]["nominal_tasks"] == 0


def test_indexed_second_pass(rng):
    data = make_base64(rng, 900_000)
    comp = gzip_bytes(data, 6)
    r = ParallelGzipReader(comp, parallelization=3, chunk_size=64 * 1024)
    assert r.read() == data
    buf = io.BytesIO()
    r.export_index(buf)
    st1 = r.stats()
    r.close()
    assert st1["fetcher"]["nominal_tasks"] + st1["fetcher"]["exact_tasks"] > 3

    idx = GzipIndex.from_bytes(buf.getvalue())
    assert idx.finalized and idx.decompressed_size == len(data)
    r2 = ParallelGzipReader(comp, parallelization=3, chunk_size=64 * 1024, index=idx)
    assert r2.read() == data
    st2 = r2.stats()
    # Indexed pass delegates to zlib (paper §1.3) — no speculative decoding.
    assert st2["fetcher"]["zlib_delegations"] > 0
    assert st2["fetcher"]["nominal_tasks"] == 0
    r2.close()


def test_random_access_and_seek_lazy(rng):
    data = make_text(rng, 800_000)
    comp = gzip_bytes(data, 6)
    with ParallelGzipReader(comp, parallelization=2, chunk_size=64 * 1024) as r:
        # backwards/forwards seeks at arbitrary offsets
        for off in [0, 123_457, 700_001, 5, 799_000, 400_000]:
            r.seek(off)
            assert r.tell() == off
            got = r.read(1000)
            assert got == data[off : off + 1000]


def test_size_and_seek_end(rng):
    data = make_text(rng, 300_000)
    with ParallelGzipReader(gzip_bytes(data), parallelization=2, chunk_size=64 * 1024) as r:
        assert r.seek(0, io.SEEK_END) == len(data)
        assert r.read(10) == b""
        r.seek(-5, io.SEEK_END)
        assert r.read() == data[-5:]


def test_crc_verification_catches_corruption(rng):
    data = make_base64(rng, 500_000)
    comp = bytearray(gzip_bytes(data, 6))
    comp[-6] ^= 0x5A  # flip a CRC byte
    with ParallelGzipReader(bytes(comp), parallelization=2, chunk_size=64 * 1024) as r:
        with pytest.raises(GzipFooterError):
            r.read()
    # verify=False tolerates it
    with ParallelGzipReader(bytes(comp), parallelization=2, chunk_size=64 * 1024, verify=False) as r:
        assert r.read() == data


def test_multi_member_with_index(rng):
    parts = [make_text(rng, 150_000), make_base64(rng, 200_000), b"x" * 10_000]
    comp = b"".join(gzip_bytes(p) for p in parts)
    truth = b"".join(parts)
    r = ParallelGzipReader(comp, parallelization=3, chunk_size=32 * 1024)
    assert r.read() == truth
    buf = io.BytesIO(); r.export_index(buf); r.close()
    r2 = ParallelGzipReader(comp, parallelization=3, chunk_size=32 * 1024,
                            index=GzipIndex.from_bytes(buf.getvalue()))
    r2.seek(140_000)
    assert r2.read(20_000) == truth[140_000:160_000]
    r2.close()


def test_bgzf_fast_path(rng):
    from repro.core.synth import bgzf_compress

    data = make_text(rng, 500_000)
    comp = bgzf_compress(data, 6)
    with ParallelGzipReader(comp, parallelization=3) as r:
        assert r.index.finalized  # metadata path: index exists immediately
        assert r.read() == data
        st = r.stats()
        assert st["fetcher"]["zlib_delegations"] > 0


def test_concurrent_access_two_offsets(rng):
    """Paper §3: fast concurrent access at two different offsets (ratarmount)."""
    data = make_text(rng, 600_000)
    comp = gzip_bytes(data, 6)
    r = ParallelGzipReader(comp, parallelization=3, chunk_size=64 * 1024,
                           access_cache_size=4)
    r.read()  # build index
    results = {}

    def reader_thread(name, start, n):
        # own file-position per thread via independent reader over same index
        buf = io.BytesIO(); r.index.export_file(buf)
        r2 = ParallelGzipReader(comp, parallelization=2, chunk_size=64 * 1024,
                                index=GzipIndex.from_bytes(buf.getvalue()))
        r2.seek(start)
        results[name] = r2.read(n)
        r2.close()

    t1 = threading.Thread(target=reader_thread, args=("a", 10_000, 50_000))
    t2 = threading.Thread(target=reader_thread, args=("b", 400_000, 50_000))
    t1.start(); t2.start(); t1.join(); t2.join()
    assert results["a"] == data[10_000:60_000]
    assert results["b"] == data[400_000:450_000]
    r.close()


def test_python_file_like_source(rng):
    data = make_text(rng, 200_000)
    comp = gzip_bytes(data)
    fileobj = io.BytesIO(comp)
    with ParallelGzipReader(fileobj, parallelization=2, chunk_size=64 * 1024) as r:
        assert r.read() == data


def _gzip_with_big_header(data: bytes, extra_len: int, name_len: int) -> bytes:
    """Valid gzip member whose FEXTRA+FNAME push the header past 64 KiB."""
    import struct
    import zlib

    flg = 4 | 8  # FEXTRA | FNAME
    header = bytes([0x1F, 0x8B, 8, flg]) + b"\0\0\0\0" + b"\0\xff"
    header += struct.pack("<H", extra_len) + b"\0" * extra_len
    header += b"n" * name_len + b"\0"
    co = zlib.compressobj(6, zlib.DEFLATED, -15)
    raw = co.compress(data) + co.flush()
    footer = struct.pack("<II", zlib.crc32(data) & 0xFFFFFFFF, len(data) & 0xFFFFFFFF)
    return header + raw + footer


def test_large_fextra_fname_header(rng):
    """Regression: a fixed 64 KiB header pread truncated large FEXTRA/FNAME
    headers; the parse now retries with doubled reads."""
    data = make_text(rng, 300_000)
    comp = _gzip_with_big_header(data, extra_len=65_000, name_len=60_000)
    assert _gzip.decompress(comp) == data  # sanity: stdlib agrees it's valid
    assert len(comp) > (1 << 16)  # header alone exceeds the old fixed read
    with ParallelGzipReader(comp, parallelization=2, chunk_size=64 * 1024) as r:
        assert r.read() == data


def test_index_split_points_bound_spacing(rng):
    """Interior seek points bound decompressed chunk spans (paper §1.4).

    Splits can only land on deflate block boundaries, so the data uses
    frequent full-flush blocks (pigz-like) to make fine splitting possible.
    """
    from repro.core.synth import pigz_like_compress

    data = make_text(rng, 2_000_000)  # highly compressible -> big ratio
    comp = pigz_like_compress(data, 6, block_size=16 << 10)
    r = ParallelGzipReader(comp, parallelization=2, chunk_size=32 * 1024,
                           index_spacing=100_000)
    r.read()
    pts = r.index.points()
    spans = [b.decompressed_byte - a.decompressed_byte for a, b in zip(pts, pts[1:])]
    r.close()
    # spacing bounded up to one block (16 KiB uncompressed) of slack
    assert len(pts) > 5
    assert max(spans) < 100_000 + 2 * (16 << 10), spans


def test_filereader_view_is_public_zero_copy_api(rng, tmp_path):
    """The fetcher's in-memory fast path goes through FileReader.view(), not
    a private attribute grab — a backend without a view just returns None."""
    from repro.core.chunk_fetcher import GzipChunkFetcher
    from repro.core.filereader import (
        BytesFileReader,
        FileReader,
        PythonFileReader,
        SharedFileReader,
    )

    data = make_text(rng, 100_000)
    comp = gzip_bytes(data)
    mem = BytesFileReader(comp)
    v = mem.view()
    assert isinstance(v, memoryview)
    assert len(v) == len(comp) and bytes(v[:16]) == comp[:16]

    # Default implementations opt out (pread-served backends).
    p = tmp_path / "x.gz"
    p.write_bytes(comp)
    shared = SharedFileReader(str(p))
    assert shared.view() is None
    assert PythonFileReader(io.BytesIO(comp)).view() is None
    assert FileReader.view(FileReader()) is None
    shared.close()

    # The fetcher consumes the view without copying or sniffing types.
    f = GzipChunkFetcher(mem, chunk_size=32 << 10, parallelization=1)
    buf, base = f._buffer(10, 20)
    assert base == 0 and len(buf) == len(comp)
    f.shutdown()

    # And decompression over a memoryview-backed buffer stays byte-exact.
    with ParallelGzipReader(comp, parallelization=2, chunk_size=32 << 10) as r:
        assert r.read() == data


def test_cache_lookup_records_exactly_one_hit_or_miss(rng):
    """One logical lookup -> exactly one hit or one miss across the two
    caches (a prefetch hit used to also record an access miss, deflating
    the fleet hit-rate in service metrics)."""
    from repro.core.chunk_fetcher import GzipChunkFetcher
    from repro.core.filereader import BytesFileReader

    comp = gzip_bytes(make_text(rng, 50_000))
    f = GzipChunkFetcher(BytesFileReader(comp), chunk_size=16 << 10, parallelization=1)

    def totals():
        a, p = f.access_cache.stats, f.prefetch_cache.stats
        return a.hits + a.misses + p.hits + p.misses

    # miss in both caches: exactly one recorded event
    before = totals()
    assert f._cache_lookup(("ix", 99)) is None
    assert totals() == before + 1
    assert f.access_cache.stats.misses == 0  # prefetch owns the miss

    # prefetch hit: one hit, no access miss
    f.prefetch_cache.insert(("ix", 1), b"payload")
    before_h = (f.access_cache.stats.hits, f.prefetch_cache.stats.hits)
    before = totals()
    assert f._cache_lookup(("ix", 1)) == b"payload"
    assert totals() == before + 1
    assert f.prefetch_cache.stats.hits == before_h[1] + 1
    assert f.access_cache.stats.misses == 0

    # promoted: the next lookup is a single access-cache hit
    before = totals()
    assert f._cache_lookup(("ix", 1)) == b"payload"
    assert totals() == before + 1
    assert f.access_cache.stats.hits == before_h[0] + 1
    f.shutdown()


def test_reader_fleet_hit_rate_invariant(rng):
    """End-to-end: after arbitrary traffic, total recorded lookups stay
    consistent — no double counting inflates misses past logical lookups."""
    data = make_text(rng, 300_000)
    comp = gzip_bytes(data)
    with ParallelGzipReader(comp, parallelization=2, chunk_size=32 << 10) as r:
        rng2 = np.random.default_rng(7)
        for _ in range(20):
            off = int(rng2.integers(0, len(data)))
            r.seek(off)
            assert r.read(1000) == data[off : off + 1000]
        rep = r.stats()
    acc, pre = rep["access"], rep["prefetch"]
    # With the combined-stats path, an access miss can only come from a
    # lookup that also missed prefetch — so access misses never exceed
    # prefetch lookups, and totals stay plausible.
    assert acc["misses"] == 0
    assert pre["hits"] + pre["misses"] > 0
