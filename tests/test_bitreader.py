import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bitreader import BitReader
from repro.core.errors import EndOfStream


def ref_bits(data: bytes, offset: int, n: int) -> int:
    """Independent LSB-first reference extraction."""
    val = 0
    for j in range(n):
        bit_index = offset + j
        byte = data[bit_index // 8]
        val |= ((byte >> (bit_index % 8)) & 1) << j
    return val


def test_basic_reads():
    br = BitReader(bytes([0b10110100, 0xFF, 0x00]))
    assert br.read(1) == 0
    assert br.read(2) == 0b10
    assert br.read(5) == 0b10110
    assert br.bit_pos == 8
    assert br.read(8) == 0xFF


def test_seek_and_peek():
    data = bytes(range(64))
    br = BitReader(data)
    br.seek(13)
    assert br.bit_pos == 13
    v = br.peek(11)
    assert br.bit_pos == 13  # peek does not consume
    assert v == ref_bits(data, 13, 11)
    br.skip(11)
    assert br.bit_pos == 24


def test_align_to_byte():
    br = BitReader(b"\xff\xff")
    br.read(3)
    skipped = br.align_to_byte()
    assert skipped == 5
    assert br.bit_pos == 8
    assert br.align_to_byte() == 0


def test_read_bytes_requires_alignment():
    br = BitReader(b"abcdef")
    br.read(4)
    with pytest.raises(ValueError):
        br.read_bytes(2)
    br.align_to_byte()
    assert br.read_bytes(2) == b"bc"


def test_eof_behaviour():
    br = BitReader(b"\x01")
    assert br.read(8) == 1
    assert br.eof()
    assert br.peek(8) == 0  # zero-padded peek
    with pytest.raises(EndOfStream):
        br.read(1)


@settings(max_examples=60, deadline=None)
@given(
    data=st.binary(min_size=4, max_size=64),
    reads=st.lists(st.integers(min_value=1, max_value=24), min_size=1, max_size=16),
)
def test_reads_match_reference(data, reads):
    br = BitReader(data)
    pos = 0
    total_bits = len(data) * 8
    for n in reads:
        if pos + n > total_bits:
            break
        assert br.read(n) == ref_bits(data, pos, n)
        pos += n
        assert br.bit_pos == pos


@settings(max_examples=30, deadline=None)
@given(data=st.binary(min_size=8, max_size=64), seek=st.integers(min_value=0, max_value=300))
def test_seek_anywhere(data, seek):
    br = BitReader(data)
    total = len(data) * 8
    seek = min(seek, total - 1)
    br.seek(seek)
    n = min(8, total - seek)
    assert br.read(n) == ref_bits(data, seek, n)
