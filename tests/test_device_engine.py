"""DeviceDecodeEngine: batched stage-2 dispatch on the serving hot path.

Covers the engine's whole contract surface:
  * bit-identical parity vs the host reference under interpret=True,
    including ragged last tiles, empty chunks, and >1-slab requests;
  * coalescing of interleaved multi-tenant submissions into shared batches;
  * CRC parity (device lanes + GF(2) combine + ragged host tail) vs zlib;
  * crossover routing (small/singleton requests take the CPU path and are
    counted as fallbacks) and the derive_crossover math itself;
  * shutdown-while-queued — futures error, never hang;
  * the threading through codec -> fetcher -> reader -> server, with
    engine stats exported from ``ArchiveServer.metrics()``.
"""

import gzip
import io
import threading
import zlib

import numpy as np
import pytest

from repro.core.markers import replace_markers as cpu_replace
from repro.kernels.engine import (
    DeviceDecodeEngine,
    EngineClosedError,
    derive_crossover,
)

from conftest import make_random, make_text

pytestmark = pytest.mark.kernels

TABLE_SIZE = 256 + 32768


def make_engine(**kw):
    kw.setdefault("force_device", True)
    kw.setdefault("crossover", None)
    kw.setdefault("max_delay_s", 0.005)
    return DeviceDecodeEngine(**kw)


def make_syms(rng, n):
    return rng.integers(0, TABLE_SIZE, n, dtype=np.int64).astype(np.uint16)


def make_window(rng, n=32768):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


# ---------------------------------------------------------------------------
# replace parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "n", [0, 1, 100, 8191, 8192, 8193, 3 * 8192 + 17]
)
def test_replace_parity_ragged_sizes(rng, n):
    """Empty, sub-tile, exact-tile, and ragged multi-tile requests all come
    back bit-identical to the host gather."""
    with make_engine() as eng:
        syms = make_syms(rng, n)
        window = make_window(rng)
        out = eng.submit_replace(syms, window).result(timeout=60)
        assert out.dtype == np.uint8
        np.testing.assert_array_equal(out, cpu_replace(syms, window))


@pytest.mark.parametrize("wlen", [0, 1, 300, 32768, 40000])
def test_replace_parity_window_lengths(rng, wlen):
    window = make_window(rng, wlen)
    if wlen == 0:
        syms = rng.integers(0, 256, 500, dtype=np.int64).astype(np.uint16)
    else:
        # markers must reference the defined (right-aligned) window region
        lo = 256 + (32768 - min(wlen, 32768))
        syms = rng.integers(lo, TABLE_SIZE, 500, dtype=np.int64).astype(np.uint16)
    with make_engine() as eng:
        out = eng.submit_replace(syms, window).result(timeout=60)
        np.testing.assert_array_equal(out, cpu_replace(syms, window))


def test_replace_oversized_request_spans_slabs(rng):
    """A single request larger than max_batch_tiles tiles is slabbed across
    several kernel launches and reassembled in order."""
    with make_engine(max_batch_tiles=2) as eng:
        syms = make_syms(rng, 5 * 8192 + 123)  # 6 tiles > 2-tile slabs
        window = make_window(rng)
        out = eng.submit_replace(syms, window).result(timeout=60)
        np.testing.assert_array_equal(out, cpu_replace(syms, window))
        assert eng.stats()["dispatches"] >= 3


def test_replace_uint8_passthrough(rng):
    with make_engine() as eng:
        data = np.frombuffer(make_random(rng, 100), np.uint8)
        out = eng.submit_replace(data, b"").result(timeout=60)
        np.testing.assert_array_equal(out, data)
        # resolved inline: no device work for already-resolved chunks
        assert eng.stats()["batches"] == 0


def test_interleaved_multi_tenant_batches(rng):
    """Concurrent submitters with distinct windows coalesce into shared
    dispatches (batched_requests > batches) and every result stays
    bit-identical to its own window's host gather."""
    with make_engine(max_delay_s=0.02, max_batch_tiles=32) as eng:
        windows = [make_window(rng) for _ in range(3)]
        cases = []
        for i in range(24):
            cases.append((make_syms(rng, 2000 + 37 * i), windows[i % 3]))

        results = [None] * len(cases)
        errors = []

        def submit(lo, hi):
            try:
                futs = [
                    (j, eng.submit_replace(cases[j][0], cases[j][1]))
                    for j in range(lo, hi)
                ]
                for j, f in futs:
                    results[j] = f.result(timeout=60)
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)

        threads = [
            threading.Thread(target=submit, args=(t * 8, (t + 1) * 8))
            for t in range(3)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors
        for (syms, window), out in zip(cases, results):
            np.testing.assert_array_equal(out, cpu_replace(syms, window))
        stats = eng.stats()
        assert stats["batched_requests"] == len(cases)
        # coalescing happened: strictly fewer dispatch groups than requests
        assert stats["batches"] < len(cases)
        assert stats["occupancy"] > 0.0


# ---------------------------------------------------------------------------
# crc parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [0, 1, 1023, 1024, 4096, 50_000])
def test_crc_parity_sizes(rng, n):
    blob = make_random(rng, n)
    with make_engine() as eng:
        assert eng.submit_crc(blob).result(timeout=60) == (
            zlib.crc32(blob) & 0xFFFFFFFF
        )


def test_crc_accepts_ndarray(rng):
    arr = np.frombuffer(make_random(rng, 5000), np.uint8)
    with make_engine() as eng:
        assert eng.crc32(arr) == (zlib.crc32(arr.tobytes()) & 0xFFFFFFFF)


def test_crc_batch_of_mixed_sizes(rng):
    blobs = [make_random(rng, n) for n in (10, 1024, 3333, 20_000)]
    with make_engine(max_delay_s=0.02) as eng:
        futs = [eng.submit_crc(b) for b in blobs]
        for blob, fut in zip(blobs, futs):
            assert fut.result(timeout=60) == (zlib.crc32(blob) & 0xFFFFFFFF)


# ---------------------------------------------------------------------------
# routing / crossover
# ---------------------------------------------------------------------------

def test_singleton_requests_take_cpu_path(rng):
    """Default engine on an interpret host: the derived crossover never lets
    the device win, so interactive singletons go to the CPU inline and the
    stats record them as fallbacks (never queued, never batched)."""
    eng = DeviceDecodeEngine()  # crossover="auto"
    try:
        syms = make_syms(rng, 5000)
        window = make_window(rng)
        np.testing.assert_array_equal(
            eng.replace_markers(syms, window), cpu_replace(syms, window)
        )
        blob = make_random(rng, 10_000)
        assert eng.crc32(blob) == (zlib.crc32(blob) & 0xFFFFFFFF)
        stats = eng.stats()
        assert stats["fallbacks"]["replace"] >= 1
        assert stats["fallbacks"]["crc"] >= 1
        assert stats["batches"] == 0
    finally:
        eng.shutdown()


def test_explicit_crossover_routes_by_size(rng):
    """With an explicit byte threshold, only requests at/above it reach the
    device queue; smaller ones fall back."""
    eng = DeviceDecodeEngine(
        crossover={"replace": 4096, "crc": None}, max_delay_s=0.005
    )
    try:
        small = make_syms(rng, 100)
        big = make_syms(rng, 8192)
        window = make_window(rng)
        np.testing.assert_array_equal(
            eng.replace_markers(small, window), cpu_replace(small, window)
        )
        np.testing.assert_array_equal(
            eng.replace_markers(big, window), cpu_replace(big, window)
        )
        stats = eng.stats()
        assert stats["fallbacks"]["replace"] == 1
        assert stats["batches"] == 1
    finally:
        eng.shutdown()


def test_derive_crossover_math():
    rows = [
        {"name": "kernel_engine_cpu_replace", "value_us": 50.0,
         "derived": "100MB/s"},
        {"name": "kernel_engine_batched_b16", "value_us": 100.0,
         "derived": "400MB/s"},
        {"name": "kernel_engine_batched_b1", "value_us": 120.0,
         "derived": "70MB/s"},
    ]
    out = derive_crossover(rows)
    # overhead = 120us - 8192B/400MBps (~20us) ~ 100us;
    # crossover = overhead / (1/100MBps - 1/400MBps) ~ 13.4 KB
    assert out["replace"] is not None
    assert 8_000 < out["replace"] < 20_000
    assert out["crc"] is None  # no crc rows given


def test_derive_crossover_device_never_wins():
    rows = [
        {"name": "kernel_engine_cpu_replace", "value_us": 10.0,
         "derived": "500MB/s"},
        {"name": "kernel_engine_batched_b16", "value_us": 5000.0,
         "derived": "30MB/s"},
        {"name": "kernel_engine_batched_b1", "value_us": 700.0,
         "derived": "11MB/s"},
    ]
    assert derive_crossover(rows)["replace"] is None
    assert derive_crossover([])["replace"] is None


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------

def test_shutdown_errors_queued_futures(rng):
    """Requests still queued at shutdown get EngineClosedError — a future
    the worker will never serve must fail loudly, not hang."""
    eng = make_engine(max_delay_s=0.5)  # long coalescing window: stay queued
    futs = [
        eng.submit_replace(make_syms(rng, 1000), make_window(rng))
        for _ in range(8)
    ]
    eng.shutdown()
    errored = completed = 0
    for f in futs:
        try:
            out = f.result(timeout=10)
        except EngineClosedError:
            errored += 1
        else:
            # an in-flight batch at shutdown is allowed to complete
            assert out.dtype == np.uint8
            completed += 1
    assert errored + completed == len(futs)
    assert errored > 0  # with a 500ms window, most never dispatched


def test_submit_after_shutdown_raises(rng):
    eng = make_engine()
    eng.shutdown()
    with pytest.raises(EngineClosedError):
        eng.submit_replace(make_syms(rng, 1000), b"")
    with pytest.raises(EngineClosedError):
        eng.submit_crc(b"data")
    # the blocking surface degrades to CPU instead of raising
    syms = make_syms(rng, 1000)
    np.testing.assert_array_equal(
        eng.replace_markers(syms, b""), cpu_replace(syms, b"")
    )
    assert eng.crc32(b"data") == (zlib.crc32(b"data") & 0xFFFFFFFF)


def test_shutdown_idempotent():
    eng = make_engine()
    eng.shutdown()
    eng.shutdown()
    assert eng.stats()["closed"]


# ---------------------------------------------------------------------------
# threading: codec -> fetcher -> reader -> server
# ---------------------------------------------------------------------------

def test_reader_roundtrip_bit_identical_with_engine(rng):
    """Full ParallelGzipReader round-trip through the engine (forced device)
    is bit-identical to the input, with CRC verification on."""
    data = make_text(rng, 300_000)
    gz = gzip.compress(data, 6)
    with make_engine(max_delay_s=0.002) as eng:
        from repro.core.reader import ParallelGzipReader

        with ParallelGzipReader(
            io.BytesIO(gz), chunk_size=32 << 10, parallelization=4,
            resolver=eng, verify=True,
        ) as r:
            assert r.read() == data
        stats = eng.stats()
        assert stats["batches"] > 0  # stage 2 actually ran on the engine
        assert stats["crc_bytes"] > 0  # CRC verification routed through too


def test_reader_pread_with_engine(rng):
    data = make_text(rng, 200_000)
    gz = gzip.compress(data, 6)
    with make_engine() as eng:
        from repro.core.reader import ParallelGzipReader

        with ParallelGzipReader(
            io.BytesIO(gz), chunk_size=32 << 10, resolver=eng
        ) as r:
            for start, ln in ((0, 100), (50_000, 9999), (199_000, 5000)):
                assert r.pread(start, ln) == data[start : start + ln]


def test_codec_resolver_hook(rng):
    """DeflateCodec.replace_markers routes through the resolver when set and
    falls back to the host path when cleared."""
    from repro.core.codec import DeflateCodec

    class CountingResolver:
        def __init__(self):
            self.calls = 0

        def replace_markers(self, symbols, window):
            self.calls += 1
            return cpu_replace(symbols, window)

        def crc32(self, data):
            if isinstance(data, np.ndarray):
                data = data.tobytes()
            return zlib.crc32(data) & 0xFFFFFFFF

    codec = DeflateCodec()
    res = CountingResolver()
    codec.set_stage2_resolver(res)
    syms = make_syms(rng, 1000)
    window = make_window(rng)
    np.testing.assert_array_equal(
        codec.replace_markers(syms, window), cpu_replace(syms, window)
    )
    assert res.calls == 1
    # uint8 input short-circuits before the resolver
    plain = np.frombuffer(make_random(rng, 64), np.uint8)
    np.testing.assert_array_equal(codec.replace_markers(plain, None), plain)
    assert res.calls == 1
    codec.set_stage2_resolver(None)
    codec.replace_markers(syms, window)
    assert res.calls == 1


def test_server_owns_engine_and_exports_stats(rng, tmp_path):
    """ArchiveServer("auto") owns a shared engine, serves bit-identical
    reads, exports engine stats in metrics(), and records CPU fallbacks for
    interactive traffic on an interpret host."""
    from repro.service.server import ArchiveServer

    # big enough that the *compressed* stream spans several chunks, so
    # stage 2 actually produces marker chunks to route
    data = make_text(rng, 600_000)
    path = tmp_path / "x.gz"
    path.write_bytes(gzip.compress(data, 6))
    with ArchiveServer(chunk_size=16 << 10) as srv:
        assert srv.device_engine is not None
        h = srv.open(str(path), tenant="t1")
        got = srv.read_range(h, 0, len(data))
        assert bytes(got) == data
        m = srv.metrics()
        assert m["engine"]["available"]
        # interactive scenario on an interpret host: every stage-2 request
        # fell back to the CPU and the stats prove it
        assert m["engine"]["fallbacks"]["replace"] > 0
        assert m["engine"]["requests"]["replace"] >= m["engine"]["fallbacks"]["replace"]
        from repro.service.metrics import format_summary

        assert any(
            line.startswith("engine[") for line in format_summary(m).splitlines()
        )
    assert srv.device_engine.stats()["closed"]


def test_server_forced_device_engine_batches(rng, tmp_path):
    """An externally owned force_device engine threads through the server and
    actually batches; the server must NOT shut it down."""
    from repro.service.server import ArchiveServer

    data = make_text(rng, 600_000)
    path = tmp_path / "x.gz"
    path.write_bytes(gzip.compress(data, 6))
    with make_engine() as eng:
        with ArchiveServer(chunk_size=16 << 10, device_engine=eng) as srv:
            h = srv.open(str(path))
            assert bytes(srv.read_range(h, 0, len(data))) == data
            assert srv.metrics()["engine"]["batches"] > 0
        assert not eng.stats()["closed"]  # external engine survives server


def test_server_engine_off():
    from repro.service.server import ArchiveServer

    with ArchiveServer(device_engine="off") as srv:
        assert srv.device_engine is None
        assert "engine" not in srv.metrics()
