"""Remote range-GET backend: fault injection + end-to-end stack tests.

All tests run against the hermetic loopback server in ``_range_server.py``
(no external network). Covers: retry-then-succeed on 503s and short bodies,
bounded-retry exhaustion, ETag flips raising ``RemoteFileChangedError``
instead of serving corrupt bytes, readahead-cache behavior, and the full
stack — ``ParallelGzipReader`` / ``ArchiveServer`` / ``IndexStore`` /
``GzipCorpusDataset`` — over remote sources, cold and warm-index.
"""

import io

import numpy as np
import pytest

from _range_server import RangeHTTPServer
from conftest import gzip_bytes, make_base64, make_text
from repro.core import GzipIndex, NoPrefetch, ParallelGzipReader
from repro.core.errors import RemoteFileChangedError, RemoteIOError
from repro.core.remote import RemoteFileReader, is_remote_url, remote_identity
from repro.data.pipeline import GzipCorpusDataset
from repro.service import ArchiveServer, IndexStore, file_identity

pytestmark = pytest.mark.remote

_NO_SLEEP = {"sleep": lambda _s: None}


def _reader(srv, **kw):
    opts = dict(block_size=4096, cache_blocks=8, **_NO_SLEEP)
    opts.update(kw)
    return RemoteFileReader(srv.url, **opts)


# ---------------------------------------------------------------------------
# fault injection at the FileReader level
# ---------------------------------------------------------------------------


def test_retry_then_succeed_on_503(rng):
    data = make_base64(rng, 50_000)
    with RangeHTTPServer(data) as srv:
        with _reader(srv) as r:
            srv.faults.inject_503(2)
            assert r.pread(1000, 5000) == data[1000:6000]
            assert r.stats.retries >= 2


def test_retry_then_succeed_on_short_body(rng):
    data = make_base64(rng, 50_000)
    with RangeHTTPServer(data) as srv:
        with _reader(srv) as r:
            srv.faults.inject_short(2)
            assert r.pread(0, 20_000) == data[:20_000]
            assert r.stats.retries >= 1


def test_503_storm_exhausts_retries(rng):
    data = make_base64(rng, 10_000)
    with RangeHTTPServer(data) as srv:
        with _reader(srv, max_retries=2) as r:
            srv.faults.inject_503(50)
            with pytest.raises(RemoteIOError):
                r.pread(0, 1000)


def test_503_at_open_retries_then_succeeds(rng):
    data = make_base64(rng, 10_000)
    with RangeHTTPServer(data) as srv:
        srv.faults.inject_503(2)
        with _reader(srv) as r:
            assert r.size() == len(data)
            assert r.etag == srv.etag


def test_etag_flip_raises_not_serves(rng):
    old = make_base64(rng, 60_000)
    new = make_base64(rng, 60_000)
    with RangeHTTPServer(old) as srv:
        with _reader(srv, cache_blocks=2) as r:
            assert r.pread(0, 4096) == old[:4096]
            srv.set_payload(new, '"rs-2"')
            # Uncached range: the response carries the new validator ->
            # clean error, never bytes from a mix of object versions.
            with pytest.raises(RemoteFileChangedError):
                r.pread(30_000, 4096)
            # Cached blocks of the open-time version are still consistent.
            assert r.pread(0, 4096) == old[:4096]


def test_misaligned_content_range_retried(rng):
    data = make_base64(rng, 50_000)
    with RangeHTTPServer(data) as srv:
        with _reader(srv) as r:
            srv.faults.inject_misaligned(1)
            # A shifted 206 window must never be sliced as if aligned —
            # detected via Content-Range start, retried, then correct.
            assert r.pread(8192, 4096) == data[8192:12_288]
            assert r.stats.retries >= 1


def test_etag_stripped_last_modified_still_detects_change(rng):
    old = make_base64(rng, 40_000)
    new = make_base64(rng, 40_000)
    with RangeHTTPServer(old) as srv:
        with _reader(srv, cache_blocks=2) as r:
            assert r.pread(0, 4096) == old[:4096]
            # Replace the object, then model an intermediary that strips
            # ETag from responses: the changed Last-Modified must still be
            # compared (not skipped just because an ETag was captured).
            srv.set_payload(new, '"rs-2"')
            srv.faults.strip_etag = True
            with pytest.raises(RemoteFileChangedError):
                r.pread(20_000, 4096)


def test_dropped_range_header_served_via_full_body(rng):
    data = make_base64(rng, 30_000)
    with RangeHTTPServer(data) as srv:
        srv.faults.drop_ranges = True  # server answers 200 + full body
        with _reader(srv) as r:
            assert r.pread(12_000, 5000) == data[12_000:17_000]
            # The full body we paid for was banked forward into the block
            # cache: the next sequential reads issue no further requests.
            n = srv.request_count
            assert r.pread(17_000, 5000) == data[17_000:22_000]
            assert r.pread(20_480, 4096) == data[20_480:24_576]
            assert srv.request_count == n


def test_no_validator_server_uses_content_digest_identity(rng):
    old = make_base64(rng, 40_000)
    new = make_base64(rng, 40_000)  # same size, different bytes
    with RangeHTTPServer(old, send_validators=False) as srv:
        with _reader(srv) as r:
            assert r.etag is None and r.last_modified is None
            assert r.identity() is None  # no cheap identity claimed
        key_old = file_identity(srv.url)
        assert key_old == file_identity(srv.url)  # stable across probes
        # A same-size replacement must change the key even without
        # validators — the head/tail content digest catches it.
        srv.set_payload(new, etag=None)
        assert file_identity(srv.url) != key_old


def test_readahead_blocks_prefetch_sequential(rng):
    data = make_base64(rng, 64 * 1024)
    with RangeHTTPServer(data) as srv:
        with _reader(srv, block_size=4096, cache_blocks=32, readahead_blocks=4) as r:
            assert r.pread(0, 4096) == data[:4096]
            hits_before = r.cache_stats.hits
            # The next sequential blocks ride the readahead of the first.
            assert r.pread(4096, 4096) == data[4096:8192]
            assert r.cache_stats.hits > hits_before


def test_single_flight_concurrent_same_block(rng):
    import threading

    data = make_base64(rng, 16 * 1024)
    with RangeHTTPServer(data, latency=0.05) as srv:
        with _reader(srv, block_size=8192) as r:
            barrier = threading.Barrier(6)
            errors = []

            def worker():
                try:
                    barrier.wait()
                    assert r.pread(100, 1000) == data[100:1100]
                except BaseException as exc:  # noqa: BLE001
                    errors.append(exc)

            threads = [threading.Thread(target=worker) for _ in range(6)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errors, errors[0]
            # All six racing readers shared ONE range GET for the cold
            # block (the workers-race-on-margins dedup the chunk fetcher
            # relies on for cold reads not fetching ~2x the archive).
            assert srv.range_requests == 1


def test_block_cache_collapses_repeat_probes(rng):
    data = make_base64(rng, 32 * 1024)
    with RangeHTTPServer(data) as srv:
        with _reader(srv, block_size=16 * 1024) as r:
            r.pread(0, 100)
            n = srv.range_requests
            # Header/footer-style tiny probes within one block: no new GETs.
            r.pread(50, 200)
            r.pread(1000, 1)
            assert srv.range_requests == n


def test_remote_identity_and_file_identity(rng):
    data = make_base64(rng, 10_000)
    with RangeHTTPServer(data) as srv:
        key_url = file_identity(srv.url)
        with _reader(srv) as r:
            assert file_identity(r) == key_url  # reader and URL agree
        ident_before = remote_identity(srv.url, **_NO_SLEEP)
        srv.flip_etag('"rs-2"')
        # A changed validator yields a new identity -> stale indexes age out.
        assert remote_identity(srv.url, **_NO_SLEEP) != ident_before
        assert file_identity(srv.url) != key_url
        assert is_remote_url(srv.url) and not is_remote_url("/tmp/x.gz")


# ---------------------------------------------------------------------------
# full stack: ParallelGzipReader over the remote backend
# ---------------------------------------------------------------------------


def test_parallel_reader_remote_bit_identical_cold_and_warm(rng):
    data = make_text(rng, 700_000)
    blob = gzip_bytes(data, 6)
    with RangeHTTPServer(blob) as srv:
        # Cold: speculative first pass over the wire, with transient faults
        # injected mid-decompression — retried transparently.
        srv.faults.inject_503(3)
        srv.faults.inject_short(2)
        r = ParallelGzipReader(
            _reader(srv, block_size=64 * 1024),
            parallelization=3,
            chunk_size=64 * 1024,
        )
        assert r.read() == data
        buf = io.BytesIO()
        r.export_index(buf)
        remote_stats = r._reader.stats  # noqa: SLF001 - introspection
        assert remote_stats.retries >= 1
        r.close()

        # Warm: imported index, zlib-delegated O(range) reads, more faults.
        srv.faults.inject_503(2)
        idx = GzipIndex.from_bytes(buf.getvalue())
        r2 = ParallelGzipReader(
            _reader(srv, block_size=64 * 1024),
            parallelization=3,
            chunk_size=64 * 1024,
            index=idx,
        )
        assert r2.read() == data
        st = r2.stats()
        assert st["fetcher"]["nominal_tasks"] == 0  # first pass skipped
        r2.close()


def test_parallel_reader_remote_random_access(rng):
    data = make_text(rng, 500_000)
    blob = gzip_bytes(data, 6)
    with RangeHTTPServer(blob) as srv:
        with ParallelGzipReader(
            _reader(srv, block_size=32 * 1024),
            parallelization=2,
            chunk_size=64 * 1024,
        ) as r:
            for off in [400_000, 5, 250_000, 499_000, 0]:
                r.seek(off)
                assert r.read(1024) == data[off : off + 1024]


def test_parallel_reader_etag_flip_mid_read_raises(rng):
    # base64-like data: low compression ratio, so the file spans several
    # compressed chunks and later chunks must hit the network again.
    data = make_base64(rng, 600_000)
    blob = gzip_bytes(data, 6)
    with RangeHTTPServer(blob) as srv:
        r = ParallelGzipReader(
            _reader(srv, block_size=16 * 1024, cache_blocks=2),
            parallelization=2,
            chunk_size=64 * 1024,
            prefetch_strategy=NoPrefetch(),  # deterministic: fetch on demand
        )
        assert r.read(50_000) == data[:50_000]
        srv.set_payload(gzip_bytes(data[::-1], 6), '"rs-2"')
        with pytest.raises(RemoteFileChangedError):
            while r.read(100_000):  # must error, never return wrong bytes
                pass
        r.close()


# ---------------------------------------------------------------------------
# service + data layers over URLs
# ---------------------------------------------------------------------------


def test_archive_server_url_open_cold_then_warm(rng, tmp_path):
    data = make_text(rng, 400_000)
    blob = gzip_bytes(data, 6)
    store = IndexStore(tmp_path / "idx")
    with RangeHTTPServer(blob) as srv:
        remote_opts = {"block_size": 32 * 1024, "cache_blocks": 8}
        with ArchiveServer(
            index_store=store, chunk_size=64 * 1024, remote_options=remote_opts
        ) as server:
            h = server.open(srv.url, tenant="remote-client")
            assert server.read_range(h, 100_000, 4096) == data[100_000:104_096]
            assert server.size(h) == len(data)
            server.close(h)  # persists the finalized index

        assert store.stats.puts == 1
        with ArchiveServer(index_store=store, chunk_size=64 * 1024) as server:
            h = server.open(srv.url)
            assert server.read_range(h, 200_000, 4096) == data[200_000:204_096]
            stat = server.stat(h)
            assert stat.index_was_warm  # ETag-keyed store hit
            m = server.metrics()
            # Warm open: no speculative first pass ran anywhere.
            assert m["fleet"]["fetcher"]["nominal_tasks"] == 0


def test_remote_block_cache_charged_to_tenant_pool(rng):
    """The per-reader remote block cache (cache_blocks x block_size) is
    pool-backed: resident block bytes show up under the owning tenant's
    CachePool accounting while the handle is open, and are released back to
    the budget on close — they no longer sit beside the shared budget."""
    data = make_base64(rng, 300_000)
    blob = gzip_bytes(data, 6)
    with RangeHTTPServer(blob) as srv:
        with ArchiveServer(
            cache_budget_bytes=8 << 20,
            chunk_size=64 * 1024,
            remote_options={"block_size": 16 * 1024, "cache_blocks": 4},
        ) as server:
            h = server.open(srv.url, tenant="edge")
            assert server.read_range(h, 50_000, 2000) == data[50_000:52_000]
            held = server.cache_pool.tenant_stats()["edge"]["bytes_held"]
            # Compressed blocks (16 KiB each) are charged alongside the
            # decompressed chunks — strictly more than chunk bytes alone.
            reader = server._entries[h].reader  # noqa: SLF001 - test introspection
            block_bytes = sum(
                len(v) for v in reader._reader._cache._data.values()  # noqa: SLF001
            )
            assert block_bytes > 0
            assert held >= block_bytes
            server.close(h)
            # Every charge returned: caches released on reader close.
            assert server.cache_pool.tenant_stats()["edge"]["bytes_held"] == 0
            assert server.cache_pool.bytes_held() == 0


def test_remote_block_cache_eviction_bounded_by_pool_budget(rng):
    """A tiny pool budget forces the remote block cache to shed blocks via
    pool-chosen eviction (not just its own entry capacity)."""
    data = make_base64(rng, 400_000)
    blob = gzip_bytes(data, 6)
    with RangeHTTPServer(blob) as srv:
        with ArchiveServer(
            cache_budget_bytes=64 << 10,  # far below blocks + chunks
            chunk_size=32 * 1024,
            remote_options={"block_size": 16 * 1024, "cache_blocks": 16},
        ) as server:
            h = server.open(srv.url, tenant="edge")
            for off in range(0, 300_000, 60_000):
                assert server.read_range(h, off, 1000) == data[off : off + 1000]
            snap = server.cache_pool.snapshot()
            assert server.cache_pool.bytes_held() <= 2 * (64 << 10), snap["tiers"]
            assert sum(t["evictions"] for t in snap["tiers"].values()) > 0


def test_corpus_dataset_remote_shard_matches_local(rng, tmp_path):
    data = make_text(rng, 200_000)
    blob = gzip_bytes(data, 6)
    path = tmp_path / "shard-0.gz"
    path.write_bytes(blob)
    kwargs = dict(
        seq_len=64, batch_size=2, chunk_size=32 * 1024, read_block=16 * 1024,
        parallelization=2, loop=False,
    )
    local = GzipCorpusDataset([str(path)], **kwargs)
    with RangeHTTPServer(blob) as srv:
        store = IndexStore()
        remote = GzipCorpusDataset([srv.url], index_store=store, **kwargs)
        for _ in range(4):
            lb, rb = local.next_batch(), remote.next_batch()
            assert lb is not None and rb is not None
            np.testing.assert_array_equal(lb["tokens"], rb["tokens"])
        # One shard open = one HEAD: identity, warm lookup, and reads all
        # share the reader's open-time validators (no per-step re-probes
        # that could key the index under a replaced object's identity).
        assert srv.head_requests == 1
        remote.close()  # persists the shard's index under the ETag key
        local.close()
        assert srv.head_requests == 1  # close-time put reuses the open key
        assert store.stats.puts == 1
        # Reopen: the warm index is found under the same remote identity.
        remote2 = GzipCorpusDataset([srv.url], index_store=store, **kwargs)
        b = remote2.next_batch()
        assert b is not None
        remote2.close()
        assert store.stats.hits >= 1
