"""Gzip-corpus input pipeline: the paper's engine as a training substrate.

``GzipCorpusDataset`` streams documents out of gzip-compressed shards
through ``ParallelGzipReader`` (speculative parallel decompression +
prefetch), tokenizes, and packs fixed-length LM sequences. This is the
deployment the paper motivates (§1.1: Common-Crawl-scale ML pipelines).
Shards may be local paths, in-memory bytes, or ``http(s)://`` URLs —
remote shards stream through range-GET preads (core/remote.py) and are
never fully downloaded; with a warm ``index_store`` a restore seeks in
O(range) network traffic.

Shards can also point at a **network gateway** (service/gateway/): a
``gateway+http(s)://...`` URL naming a gateway ``/bytes`` endpoint, or a
`GatewayClient` instance. Gateway shards arrive *already decompressed* —
the archive service on the other end runs the paper's machinery and this
pipeline does positional reads over the wire — so checkpoint restores seek
in O(1) against the gateway's warm index, and a training fleet shares one
central decompression tier instead of N per-host ones.

Fault tolerance: the iterator state is (shard index, *decompressed byte
offset*, partial-buffer digest) — restoring seeks in O(1) through the seek
index instead of re-decompressing the shard prefix, the paper's random
access capability doing real work. State is saved/restored with the model
checkpoint (checkpoint/checkpoint.py).

In a multi-host deployment every host runs one pipeline over its own shard
subset (shard_id=process_index) and feeds its addressable devices;
decompression parallelism comes from the chunk fetcher's thread pool —
exactly the paper's architecture, one instance per host.

When several pipelines (or a pipeline and a serving path) share one host,
pass ``cache_pool``/``executor``/``index_store`` (service layer) so all
shard readers draw from one memory budget and one fair thread pool, and
shard seek-indexes persist across epochs and restarts instead of being
rebuilt by a speculative first pass each time the shard is reopened.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from ..core.index import GzipIndex
from ..core.reader import ParallelGzipReader
from ..core.remote import RemoteFileReader, is_remote_url
from .tokenizer import ByteTokenizer, EOS


@dataclasses.dataclass
class PipelineState:
    shard_idx: int
    byte_offset: int  # decompressed offset within the current shard
    buffered_tokens: int  # tokens already emitted from the current read block

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d) -> "PipelineState":
        return cls(int(d["shard_idx"]), int(d["byte_offset"]), int(d["buffered_tokens"]))


class GzipCorpusDataset:
    """Packed LM batches from gzip shards, checkpointable and shardable."""

    def __init__(
        self,
        shards: Sequence[str],  # paths, http(s):// URLs, or bytes of .gz shards
        *,
        tokenizer: Optional[ByteTokenizer] = None,
        seq_len: int = 1024,
        batch_size: int = 8,
        parallelization: int = 4,
        chunk_size: int = 1 << 20,
        read_block: int = 1 << 20,
        shard_id: int = 0,
        num_shards: int = 1,
        indexes: Optional[Dict[int, GzipIndex]] = None,
        loop: bool = True,
        cache_pool=None,  # service.CachePool: shared memory budget
        executor=None,  # service.FairExecutor (or any Executor) to share threads
        index_store=None,  # service.IndexStore: persistent shard indexes
        tenant: Optional[str] = None,  # accounting id in the shared pool
        remote_options: Optional[Dict] = None,  # RemoteFileReader kwargs for URL shards
        codec: Optional[str] = None,  # format tag for all shards; None = per-shard probe
    ):
        if not shards:
            raise ValueError("no shards")
        self.shards = list(shards)
        self.tokenizer = tokenizer or ByteTokenizer()
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.parallelization = parallelization
        self.chunk_size = chunk_size
        self.read_block = read_block
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.indexes = indexes or {}
        self.loop = loop
        self.cache_pool = cache_pool
        self.executor = executor
        self.index_store = index_store
        self.tenant = tenant or f"pipeline-shard{shard_id}"
        self.remote_options = dict(remote_options or {})
        self.codec = codec

        self._my_shards = [i for i in range(len(self.shards)) if i % num_shards == shard_id]
        if not self._my_shards:
            raise ValueError("shard_id has no shards")
        self.state = PipelineState(0, 0, 0)
        # ParallelGzipReader for local/remote gzip shards; a plain FileReader
        # of decompressed bytes for gateway shards (both serve pread).
        self._reader = None
        self._reader_owned = True  # False when the shard IS a client object
        self._reader_shard: Optional[int] = None
        self._reader_key: Optional[str] = None  # index-store key at open time
        self._token_buf = np.empty(0, np.int32)
        self._exhausted = False

    # -- reader management ---------------------------------------------------

    @staticmethod
    def _is_gateway_shard(source) -> bool:
        if isinstance(source, str):
            return source.startswith(("gateway+http://", "gateway+https://"))
        # Lazy import: only pipelines that actually use gateway shards pay it.
        from ..service.gateway.client import GatewayClient

        return isinstance(source, GatewayClient)

    def _open_gateway(self, source):
        """FileReader of a gateway shard's *decompressed* bytes.

        Decompression, caching, and index reuse all happen gateway-side;
        locally this is positional HTTP range reads — no gzip machinery, no
        pool registration, and checkpoint restores cost one range GET.
        """
        if isinstance(source, str):
            url = source[len("gateway+"):]
            return RemoteFileReader(url, **self.remote_options), True
        return source, False  # caller-owned GatewayClient: never close it

    def _open(self, local_idx: int):
        global_idx = self._my_shards[local_idx % len(self._my_shards)]
        if self._reader is not None and self._reader_shard == global_idx:
            return self._reader
        self._close_reader()
        source = self.shards[global_idx]
        if self._is_gateway_shard(source):
            self._reader, self._reader_owned = self._open_gateway(source)
            self._reader_shard = global_idx
            self._reader_key = None  # the gateway owns the seek index
            return self._reader
        if is_remote_url(source):
            # Open the remote backend once: the identity used for the warm
            # index lookup and the reader's reads then share one set of
            # open-time validators (one HEAD total), and the close-time put
            # below keys the index by the version that was actually read —
            # not by a fresh probe that could see a replaced object.
            source = RemoteFileReader(source, **self.remote_options)
        access_cache = prefetch_cache = None
        try:
            store_key = None
            if self.index_store is not None:
                # Codec-qualified key: a gzip shard and a zstd shard of the
                # same logical text must never share a stored index.
                store_key = self.index_store.key_for(source, codec=self.codec)
            index = self.indexes.get(global_idx)
            if index is None and store_key is not None:
                # Warm open: a stored index skips the speculative first pass.
                index = self.index_store.get(store_key)
            if self.cache_pool is not None:
                access_cache, prefetch_cache = self.cache_pool.reader_caches(self.tenant)
            executor = self.executor
            if executor is not None and hasattr(executor, "view"):
                executor = executor.view(self.tenant)
            self._reader = ParallelGzipReader(
                source,
                parallelization=self.parallelization,
                chunk_size=self.chunk_size,
                index=index,
                codec=self.codec,
                executor=executor,
                access_cache=access_cache,
                prefetch_cache=prefetch_cache,
            )
        except BaseException:
            # Don't leak pool registrations (or remote connections) when any
            # open step fails — key derivation and the warm-index lookup can
            # raise for remote shards too (e.g. a 503 burst).
            if access_cache is not None:
                access_cache.release()
                prefetch_cache.release()
            if source is not self.shards[global_idx]:
                source.close()
            raise
        self._reader_shard = global_idx
        self._reader_owned = True
        self._reader_key = store_key
        return self._reader

    def _close_reader(self) -> None:
        """Close the current shard reader, persisting its index if possible."""
        if self._reader is None:
            return
        if self._reader_key is not None and self._reader.index.finalized:
            self.index_store.put(self._reader_key, self._reader.index)
        if self._reader_owned:
            self._reader.close()
        self._reader = None
        self._reader_shard = None
        self._reader_key = None

    # -- iteration -------------------------------------------------------------

    def _refill(self) -> bool:
        """Read the next block of the corpus into the token buffer."""
        while True:
            if not self.loop and self._exhausted:
                return False
            reader = self._open(self.state.shard_idx)
            # Stateless positional read: no cursor on the reader, so a
            # pipeline sharing its shard reader with other consumers (e.g. a
            # serving path behind the same ArchiveServer budgets) never
            # races a seek+read pair.
            data = reader.pread(self.state.byte_offset, self.read_block)
            if not data:
                # next shard (wrapping if looping)
                nxt = self.state.shard_idx + 1
                if not self.loop and nxt >= len(self._my_shards):
                    self._exhausted = True
                    return False
                self.state = PipelineState(nxt % len(self._my_shards), 0, 0)
                continue
            tokens = self.tokenizer.encode(data, add_bos=self.state.byte_offset == 0, add_eos=False)
            skip = self.state.buffered_tokens
            if skip:
                tokens = tokens[skip:]
            self._token_buf = np.concatenate([self._token_buf, tokens])
            self.state.byte_offset += len(data)
            self.state.buffered_tokens = 0
            return True

    def next_batch(self) -> Optional[Dict[str, np.ndarray]]:
        """Packed {tokens: [B, seq_len+1]} batch (causal LM layout)."""
        need = self.batch_size * (self.seq_len + 1)
        while self._token_buf.shape[0] < need:
            if not self._refill():
                if self._token_buf.shape[0] == 0:
                    return None
                pad = np.full(need - self._token_buf.shape[0], EOS, np.int32)
                self._token_buf = np.concatenate([self._token_buf, pad])
        batch = self._token_buf[:need].reshape(self.batch_size, self.seq_len + 1).copy()
        self._token_buf = self._token_buf[need:]
        return {"tokens": batch}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.next_batch()
            if b is None:
                return
            yield b

    # -- checkpointing -----------------------------------------------------------

    def state_dict(self) -> Dict[str, int]:
        # The buffer itself is not persisted; instead record how many tokens
        # of the current block were already consumed so restore can skip them.
        st = dataclasses.replace(self.state)
        # tokens consumed from past blocks = everything not in _token_buf
        return {
            **st.as_dict(),
            "pending_buffer": int(self._token_buf.shape[0]),
        }

    def load_state_dict(self, d: Dict[str, int]) -> None:
        self.state = PipelineState.from_dict(d)
        # Rewind to the start of the partially-consumed region: drop the
        # buffered remainder and re-read it (idempotent, O(1) via the index).
        pending = int(d.get("pending_buffer", 0))
        self.state.byte_offset = max(0, self.state.byte_offset - pending)
        self._token_buf = np.empty(0, np.int32)
        self._exhausted = False
        self._close_reader()

    def export_indexes(self) -> Dict[int, bytes]:
        """Seek indexes of every opened shard (reusable across restarts).

        Gateway shards export nothing — their index lives server-side.
        """
        out = {}
        if self._reader is not None and self._reader_shard is not None:
            index = getattr(self._reader, "index", None)
            if index is not None:
                out[self._reader_shard] = index.to_bytes()
        return out

    def close(self) -> None:
        self._close_reader()
