"""Byte-level tokenizer with reserved specials and vocab folding.

Training the assigned architectures needs nothing fancier than a robust
byte-level scheme: tokens 0..255 are raw bytes; specials follow. Vocab
sizes above 256+specials are simply sparse (real BPE slots unused) — the
embedding math is identical, which is what the substrate needs.
"""

from __future__ import annotations

from typing import Iterable, List

import numpy as np

PAD = 256
BOS = 257
EOS = 258
N_SPECIALS = 3


class ByteTokenizer:
    def __init__(self, vocab_size: int = 512):
        if vocab_size < 256 + N_SPECIALS:
            raise ValueError("vocab_size must be >= 259")
        self.vocab_size = vocab_size

    def encode(self, data: bytes, *, add_bos: bool = True, add_eos: bool = True) -> np.ndarray:
        parts: List[np.ndarray] = []
        if add_bos:
            parts.append(np.array([BOS], np.int32))
        parts.append(np.frombuffer(data, np.uint8).astype(np.int32))
        if add_eos:
            parts.append(np.array([EOS], np.int32))
        return np.concatenate(parts)

    def decode(self, tokens: Iterable[int]) -> bytes:
        return bytes(int(t) for t in tokens if 0 <= int(t) < 256)
