from .pipeline import GzipCorpusDataset, PipelineState
from .tokenizer import BOS, EOS, PAD, ByteTokenizer

__all__ = ["BOS", "ByteTokenizer", "EOS", "GzipCorpusDataset", "PAD", "PipelineState"]
