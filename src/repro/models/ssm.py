"""Selective SSM (Mamba-style) branch and the Hymba hybrid block.

Hymba (arXiv:2411.13676) runs attention heads and SSM heads *in parallel*
inside each block and fuses their (normalized) outputs. The SSM branch is a
selective scan: per-channel state ``h_t = exp(dt*A) h_{t-1} + dt*B_t x_t``,
``y_t = C_t . h_t + D_skip x_t``, computed with a *chunked* associative scan
(sequential over chunks, parallel within a chunk) to bound activation
memory at ``B x chunk x d_inner x N``.

Decode carries O(1) state: the SSM state [B, d_inner, N] plus the causal
conv tail [B, K-1, d_inner] — this is what makes ``long_500k`` feasible for
the hybrid family while pure attention archs skip it (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamDef

CONV_K = 4  # causal depthwise conv kernel (mamba default)


def ssm_defs(n_layers: int, d_model: int, d_inner: int, n_state: int) -> Dict[str, Any]:
    L = (n_layers,) if n_layers else ()
    pl = (None,) * len(L)
    return {
        "w_in": ParamDef(L + (d_model, 2 * d_inner), pl + ("embed", "ssm_inner")),
        "conv": ParamDef(L + (CONV_K, d_inner), pl + ("conv_k", "ssm_inner"), scale=0.5),
        "w_dt": ParamDef(L + (d_inner,), pl + ("ssm_inner",), init="zeros"),
        "w_bc": ParamDef(L + (d_inner, 2 * n_state), pl + ("ssm_inner", None)),
        "a_log": ParamDef(L + (d_inner, n_state), pl + ("ssm_inner", "ssm_state"), init="zeros"),
        "d_skip": ParamDef(L + (d_inner,), pl + ("ssm_inner",), init="ones"),
        "w_out": ParamDef(L + (d_inner, d_model), pl + ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array, tail: Optional[jax.Array] = None):
    """Depthwise causal conv. x: [B,S,C]; kernel: [K,C]; tail: [B,K-1,C]."""
    k = kernel.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * kernel[i] for i in range(k))
    new_tail = xp[:, -(k - 1) :] if k > 1 else None
    return out, new_tail


def _ssm_scan_chunk(carry, a, bx):
    """Associative scan within one chunk given an incoming state.

    a, bx: [B, C, D, N] per-step decay and input. carry: [B, D, N].
    """
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_acc, b_acc = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = a_acc * carry[:, None] + b_acc  # [B, C, D, N]
    return h[:, -1], h


def selective_ssm(
    params: Dict[str, Any],
    x: jax.Array,  # [B, S, D_model]
    *,
    chunk: int = 256,
    state: Optional[Dict[str, jax.Array]] = None,
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Mamba-style selective scan. Returns (y [B,S,D_model], new_state)."""
    B, S, _ = x.shape
    d_inner = params["w_in"].shape[-1] // 2
    n_state = params["a_log"].shape[-1]

    zx = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xc = jnp.split(zx, 2, axis=-1)
    conv_tail = state["conv"] if state is not None else None
    xc, new_tail = _causal_conv(xc, params["conv"], conv_tail)
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus(xc.astype(jnp.float32) + params["w_dt"].astype(jnp.float32))
    bc = jnp.einsum("bse,en->bsn", xc, params["w_bc"]).astype(jnp.float32)
    b_in, c_out = jnp.split(bc, 2, axis=-1)  # [B,S,N] each
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # [D,N], negative

    decay = jnp.exp(dt[..., None] * a)  # [B,S,D,N]
    drive = (dt * xc.astype(jnp.float32))[..., None] * b_in[:, :, None, :]  # [B,S,D,N]

    h0 = state["h"] if state is not None else jnp.zeros((B, d_inner, n_state), jnp.float32)
    if S == 1:
        h = decay[:, 0] * h0 + drive[:, 0]
        hs = h[:, None]
        h_last = h
    else:
        n_chunks = -(-S // chunk)
        pad = n_chunks * chunk - S
        if pad:
            decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
            drive = jnp.pad(drive, ((0, 0), (0, pad), (0, 0), (0, 0)))
        decay = decay.reshape(B, n_chunks, chunk, d_inner, n_state).swapaxes(0, 1)
        drive = drive.reshape(B, n_chunks, chunk, d_inner, n_state).swapaxes(0, 1)
        h_last, hs = jax.lax.scan(
            lambda c, ab: _ssm_scan_chunk(c, ab[0], ab[1]), h0, (decay, drive),
            unroll=True if unroll else 1,
        )
        hs = hs.swapaxes(0, 1).reshape(B, n_chunks * chunk, d_inner, n_state)[:, :S]

    y = jnp.einsum("bsdn,bsn->bsd", hs, c_out)  # [B,S,D_inner] fp32
    y = y + params["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    new_state = None
    if state is not None:
        new_state = {"h": h_last, "conv": new_tail}
    return y, new_state


def init_ssm_state(batch: int, d_inner: int, n_state: int) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, d_inner, n_state), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, d_inner), jnp.bfloat16),
    }
