"""Unified model facade: one object per architecture family exposing

    init / abstract / logical            (parameter trees)
    loss(params, batch, ctx)             (train forward + CE)
    prefill(params, batch, ctx)          (logits + per-layer cache tensors)
    decode_step(params, tokens, caches, cache_pos, ctx)

Families: dense / moe / hybrid -> transformer.py; ssm (xLSTM) -> xlstm.py;
audio (Whisper) -> encdec.py; vlm -> transformer.py with prefix embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from . import encdec, transformer, xlstm
from .layers import ParamDef, abstract_tree, init_tree, logical_tree, rms_norm
from .transformer import ModelContext


def cross_entropy(
    logits: jax.Array,  # [B, S, V]
    labels: jax.Array,  # [B, S] int32; negative = masked
    *,
    z_loss: float = 1e-4,
    ctx: Optional["ModelContext"] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Vocab-sharded CE: both the fp32 logits and the one-hot targets are
    constrained to the ("batch", None, "vocab") layout so GSPMD keeps every
    [B, S, V] intermediate vocab-sharded — without the constraint the
    one-hot materializes replicated and XLA all-gathers the fp32 logits
    (~TB-scale wire for 256k vocabs; see EXPERIMENTS.md §Perf iteration 1).
    """
    V = logits.shape[-1]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    onehot = jax.nn.one_hot(safe, V, dtype=jnp.float32)
    if ctx is not None:
        lf = constrain(lf, ctx.rules, "batch", None, "vocab")
        onehot = constrain(onehot, ctx.rules, "batch", None, "vocab")
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    picked = jnp.einsum("bsv,bsv->bs", lf, onehot)
    nll = (lse - picked) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    metrics = {"nll": loss, "tokens": denom}
    if z_loss:
        zl = z_loss * jnp.sum(jnp.square(lse) * mask) / denom
        loss = loss + zl
        metrics["z_loss"] = zl
    return loss, metrics


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    defs: Any
    loss: Callable  # (params, batch, ctx) -> (loss, metrics)
    prefill: Callable  # (params, batch, ctx) -> (logits, caches)
    decode_step: Callable  # (params, tokens, caches, cache_pos, ctx) -> (logits, caches)
    init_decode_caches: Callable  # (batch, max_len) -> caches

    def init(self, key) -> Any:
        return init_tree(self.defs, key)

    def abstract(self) -> Any:
        return abstract_tree(self.defs)

    def logical(self) -> Any:
        return logical_tree(self.defs)


# ---------------------------------------------------------------------------
# decoder-only families (dense / moe / hybrid / vlm)
# ---------------------------------------------------------------------------

def _decoder_model(cfg: ModelConfig) -> Model:
    defs = transformer.decoder_defs(cfg)

    def _prefix(params, batch):
        if cfg.family == "vlm":
            return batch["patches"]
        return None

    def loss(params, batch, ctx):
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        prefix = _prefix(params, batch)
        logits, aux, _ = transformer.forward(
            cfg, ctx, params, inputs, mode="train", prefix_embeds=prefix
        )
        if prefix is not None:
            logits = logits[:, prefix.shape[1] :]
        ce, metrics = cross_entropy(logits, labels, ctx=ctx)
        total = ce + 0.01 * aux
        metrics["aux_loss"] = aux
        return total, metrics

    def prefill(params, batch, ctx):
        prefix = _prefix(params, batch)
        logits, _, caches = transformer.forward(
            cfg, ctx, params, batch["tokens"], mode="prefill", prefix_embeds=prefix
        )
        return logits[:, -1:], caches

    def decode_step(params, tokens, caches, cache_pos, ctx):
        logits, _, caches = transformer.forward(
            cfg, ctx, params, tokens, mode="decode", caches=caches, cache_pos=cache_pos
        )
        return logits, caches

    def init_decode_caches(batch, max_len):
        return transformer.init_caches(cfg, batch, max_len)

    return Model(cfg, defs, loss, prefill, decode_step, init_decode_caches)


# ---------------------------------------------------------------------------
# xLSTM (ssm family)
# ---------------------------------------------------------------------------

def _xlstm_defs(cfg: ModelConfig):
    V, D, H = cfg.vocab_size, cfg.d_model, cfg.n_heads
    every = max(1, cfg.slstm_every)
    n_groups = cfg.n_layers // every
    n_m = every - 1
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=D ** -0.5),
        "final_norm": ParamDef((D,), ("embed",), init="zeros"),
        "unembed": ParamDef((D, V), ("embed", "vocab")),
        # groups of (every-1) mLSTM blocks + 1 sLSTM block, scanned over groups
        "mlstm": _nest_stack(xlstm.mlstm_defs(n_m, D, H), n_groups),
        "slstm": _nest_stack(xlstm.slstm_defs(0, D, H), n_groups),
    }


def _nest_stack(defs, n: int):
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (None,) + d.logical, d.init, d.scale, d.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _xlstm_model(cfg: ModelConfig) -> Model:
    defs = _xlstm_defs(cfg)
    every = max(1, cfg.slstm_every)
    n_m = every - 1

    def run(params, tokens, ctx, *, mode, caches=None):
        x = jnp.take(params["embed"], tokens, axis=0)
        x = constrain(x, ctx.rules, "batch", None, None)
        want_state = mode != "train"

        def group(carry, layer_in):
            x_in = carry
            p, cache = layer_in

            def m_body(xm, m_in):
                pm, mc = m_in
                xm, new_state = xlstm.mlstm_block(
                    pm, xm, cfg.n_heads,
                    state=mc if mode == "decode" else None,
                    return_state=want_state,
                    unroll=cfg.scan_unroll,
                )
                return xm, new_state

            if n_m:
                x_mid, m_states = jax.lax.scan(
                    m_body, x_in, (p["m"], cache["m"] if cache is not None else None),
                    unroll=True if cfg.scan_unroll else 1,
                )
            else:
                x_mid, m_states = x_in, None
            x_out, s_state_out = xlstm.slstm_block(
                p["s"], x_mid, cfg.n_heads,
                state=cache["s"] if (cache is not None and mode == "decode") else None,
                return_state=want_state,
            )
            cache_out = {"m": m_states, "s": s_state_out} if want_state else None
            return x_out, cache_out

        x, caches_out = jax.lax.scan(
            group, x, ({"m": params["mlstm"], "s": params["slstm"]}, caches),
            unroll=True if cfg.scan_unroll else 1,
        )
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
        return constrain(logits, ctx.rules, "batch", None, "vocab"), caches_out

    def loss(params, batch, ctx):
        tokens = batch["tokens"]
        logits, _ = run(params, tokens[:, :-1], ctx, mode="train")
        ce, metrics = cross_entropy(logits, tokens[:, 1:], ctx=ctx)
        return ce, metrics

    def prefill(params, batch, ctx):
        # Prefill builds recurrent state via the chunkwise form (no caches in).
        logits, caches = run(params, batch["tokens"], ctx, mode="prefill")
        return logits[:, -1:], caches

    def decode_step(params, tokens, caches, cache_pos, ctx):
        logits, caches = run(params, tokens, ctx, mode="decode", caches=caches)
        return logits, caches

    def init_decode_caches(batch, max_len):
        every_ = max(1, cfg.slstm_every)
        n_groups = cfg.n_layers // every_
        m_state = xlstm.init_mlstm_state(batch, cfg.d_model, cfg.n_heads)
        s_state = xlstm.init_slstm_state(batch, cfg.d_model, cfg.n_heads)
        stack_m = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None, None], (n_groups, every_ - 1) + l.shape).copy(),
            m_state,
        )
        stack_s = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_groups,) + l.shape).copy(), s_state
        )
        return {"m": stack_m, "s": stack_s}

    return Model(cfg, defs, loss, prefill, decode_step, init_decode_caches)


# ---------------------------------------------------------------------------
# Whisper (audio family)
# ---------------------------------------------------------------------------

def _encdec_model(cfg: ModelConfig) -> Model:
    defs = encdec.encdec_defs(cfg)

    def loss(params, batch, ctx):
        tokens = batch["tokens"]
        enc = encdec.encode(cfg, params, batch["frames"])
        logits, _ = encdec.decode_stack(cfg, params, tokens[:, :-1], enc, mode="train")
        ce, metrics = cross_entropy(logits, tokens[:, 1:], ctx=ctx)
        return ce, metrics

    def prefill(params, batch, ctx):
        enc = encdec.encode(cfg, params, batch["frames"])
        logits, caches = encdec.decode_stack(
            cfg, params, batch["tokens"], enc, mode="prefill"
        )
        return logits[:, -1:], caches

    def decode_step(params, tokens, caches, cache_pos, ctx):
        logits, caches = encdec.decode_stack(
            cfg, params, tokens, None, mode="decode", caches=caches, cache_pos=cache_pos
        )
        return logits, caches

    def init_decode_caches(batch, max_len):
        return encdec.init_decoder_caches(cfg, batch, max_len, cfg.encoder_frames)

    return Model(cfg, defs, loss, prefill, decode_step, init_decode_caches)


# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "ssm":
        return _xlstm_model(cfg)
    if cfg.family == "audio":
        return _encdec_model(cfg)
    return _decoder_model(cfg)
