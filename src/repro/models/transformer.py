"""Decoder-only transformer assembly for dense / MoE / MLA / hybrid configs.

Layers are *stacked* ([L, ...] leading dim) and traversed with
``jax.lax.scan`` + configurable remat — the HLO stays one-block-sized, which
keeps 236B-parameter dry-run compiles tractable and is also what a real
deployment wants (faster compiles, better fusion reuse).

Three execution modes:
  * train   — no caches; chunked causal attention bounds memory.
  * prefill — emits per-layer cache tensors ([L, B, S, ...] via scan ys).
  * decode  — one token against caches (linear or ring for sliding window;
              MLA decodes in the absorbed compressed-cache form).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import ShardingRules, constrain
from .layers import (
    ParamDef,
    apply_rope,
    causal_attention,
    gated_mlp,
    gated_mlp_defs,
    gqa_defs,
    gqa_attention_block,
    init_kv_cache,
    rms_norm,
)
from .moe import moe_defs, moe_layer
from .ssm import init_ssm_state, selective_ssm, ssm_defs


@dataclasses.dataclass(frozen=True)
class ModelContext:
    mesh: Any
    rules: ShardingRules


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------

def _stack(defs: Dict[str, Any], n: int) -> Dict[str, Any]:
    def add_dim(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + d.shape, (None,) + d.logical, d.init, d.scale, d.dtype)

    return jax.tree.map(add_dim, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _attn_defs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.use_mla:
        qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        return {
            "w_dq": ParamDef((cfg.d_model, cfg.q_lora_rank), ("embed", "qk_lora")),
            "q_norm": ParamDef((cfg.q_lora_rank,), ("qk_lora",), init="zeros"),
            "w_uq": ParamDef((cfg.q_lora_rank, cfg.n_heads, qk_dim), ("qk_lora", "heads", None)),
            "w_dkv": ParamDef(
                (cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim), ("embed", "qk_lora")
            ),
            "kv_norm": ParamDef((cfg.kv_lora_rank,), ("qk_lora",), init="zeros"),
            "w_uk": ParamDef(
                (cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_head_dim), ("qk_lora", "heads", None)
            ),
            "w_uv": ParamDef(
                (cfg.kv_lora_rank, cfg.n_heads, cfg.v_head_dim), ("qk_lora", "heads", None)
            ),
            "wo": ParamDef((cfg.n_heads, cfg.v_head_dim, cfg.d_model), ("heads", None, "embed")),
        }
    return gqa_defs(
        cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias
    )


def decoder_defs(cfg: ModelConfig) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.vocab_size
    defs: Dict[str, Any] = {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=D ** -0.5),
        "final_norm": ParamDef((D,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((D, V), ("embed", "vocab"))

    def block_defs(moe: bool) -> Dict[str, Any]:
        blk: Dict[str, Any] = {
            "norm1": ParamDef((D,), ("embed",), init="zeros"),
            "norm2": ParamDef((D,), ("embed",), init="zeros"),
            "attn": _attn_defs(cfg),
        }
        if moe:
            # moe_defs stacks itself; handled below.
            pass
        else:
            blk["mlp"] = gated_mlp_defs(D, cfg.d_ff)
        if cfg.family == "hybrid":
            blk["ssm"] = ssm_defs(0, D, cfg.ssm_expand * D, cfg.ssm_state)
            blk["attn_scale"] = ParamDef((D,), ("embed",), init="zeros")
            blk["ssm_scale"] = ParamDef((D,), ("embed",), init="zeros")
        return blk

    def stacked_block(n: int, moe: bool) -> Dict[str, Any]:
        blk = _stack(block_defs(moe), n)
        if moe:
            blk["moe"] = moe_defs(n, D, cfg.n_experts, cfg.d_ff_expert, cfg.n_shared_experts)
        return blk

    if cfg.n_experts and cfg.first_dense_layers:
        defs["dense_layers"] = stacked_block(cfg.first_dense_layers, moe=False)
        defs["moe_layers"] = stacked_block(cfg.n_layers - cfg.first_dense_layers, moe=True)
    elif cfg.n_experts:
        defs["moe_layers"] = stacked_block(cfg.n_layers, moe=True)
    else:
        defs["layers"] = stacked_block(cfg.n_layers, moe=False)
    return defs


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_attention(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
    q_chunk: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Multi-head Latent Attention. Decode runs the *absorbed* form against
    the compressed cache [B, S, kv_lora] + [B, S, rope_d] — the MLA win."""
    B, S, _ = x.shape
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    scale = float((nope + rope_d) ** -0.5)

    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rms_norm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(ckv_full[:, :, None, cfg.kv_lora_rank :], positions, cfg.rope_theta)[:, :, 0]

    if mode != "decode":
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rhv->bshv", c_kv, p["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, cfg.n_heads, rope_d))], axis=-1
        )
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = causal_attention(qq, k, v, q_chunk=q_chunk, softmax_scale=scale)
        y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
        cache_out = {"c_kv": c_kv, "k_rope": k_rope} if mode == "prefill" else None
        return y, cache_out

    assert S == 1 and cache is not None and cache_pos is not None
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, cache_pos, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, cache_pos, axis=1)
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])  # absorb W_uk
    scores = (
        jnp.einsum("bshr,btr->bhst", q_c, ckv_cache, preferred_element_type=jnp.float32)
        + jnp.einsum("bshk,btk->bhst", q_rope, kr_cache, preferred_element_type=jnp.float32)
    ) * scale
    t_pos = jnp.arange(ckv_cache.shape[1])
    scores = jnp.where((t_pos <= cache_pos)[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_c = jnp.einsum("bhst,btr->bshr", probs.astype(ckv_cache.dtype), ckv_cache)
    out = jnp.einsum("bshr,rhv->bshv", ctx_c, p["w_uv"])
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, {"c_kv": ckv_cache, "k_rope": kr_cache}


# ---------------------------------------------------------------------------
# blocks & stacks
# ---------------------------------------------------------------------------

def _block(
    cfg: ModelConfig,
    ctx: ModelContext,
    p: Dict[str, Any],
    x: jax.Array,
    positions: jax.Array,
    *,
    moe: bool,
    mode: str,
    cache: Optional[Dict[str, Any]] = None,
    cache_pos: Optional[jax.Array] = None,
    q_chunk: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    rules = ctx.rules
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"])

    attn_cache = cache.get("attn") if cache else None
    if cfg.use_mla:
        attn_out, attn_cache_out = _mla_attention(
            cfg, p["attn"], h, positions, mode=mode,
            cache=attn_cache, cache_pos=cache_pos, q_chunk=q_chunk,
        )
    else:
        attn_out, attn_cache_out = gqa_attention_block(
            p["attn"], h, positions,
            rope_theta=cfg.rope_theta, mode=mode,
            cache=attn_cache, cache_pos=cache_pos,
            sliding_window=cfg.sliding_window or None, q_chunk=q_chunk,
        )
    cache_out: Dict[str, Any] = {}
    if attn_cache_out is not None:
        cache_out["attn"] = attn_cache_out

    if cfg.family == "hybrid":
        if mode == "train":
            ssm_state = None
        elif mode == "prefill":
            ssm_state = init_ssm_state(x.shape[0], cfg.ssm_expand * cfg.d_model, cfg.ssm_state)
        else:
            ssm_state = cache.get("ssm") if cache else None
        ssm_out, ssm_state_out = selective_ssm(p["ssm"], h, state=ssm_state, unroll=cfg.scan_unroll)
        if ssm_state_out is not None:
            cache_out["ssm"] = ssm_state_out
        fused = 0.5 * (rms_norm(attn_out, p["attn_scale"]) + rms_norm(ssm_out, p["ssm_scale"]))
        x = x + fused
    else:
        x = x + attn_out
    x = constrain(x, rules, "batch", None, None)

    h2 = rms_norm(x, p["norm2"])
    if moe:
        routed, aux = moe_layer(
            p["moe"], h2,
            mesh=ctx.mesh, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            activation=cfg.activation, dp_axes=("pod", "data"),
        )
        from jax.ad_checkpoint import checkpoint_name

        routed = checkpoint_name(routed, "moe_routed_out")
        mlp_out = routed
        if "shared" in p["moe"]:
            mlp_out = mlp_out + gated_mlp(p["moe"]["shared"], h2, cfg.activation)
    else:
        mlp_out = gated_mlp(p["mlp"], h2, cfg.activation)
    x = x + mlp_out
    x = constrain(x, rules, "batch", None, None)
    return x, (cache_out or None), aux


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if policy == "dots_plus_collectives":
        # 'dots' recomputes the whole block in backward — including the MoE
        # all-to-all dispatch, doubling wire per step. Saving the named
        # routed-expert output keeps the recompute but not the collectives
        # (§Perf iteration 5).
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_from_both_policies(
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                jax.checkpoint_policies.save_only_these_names("moe_routed_out"),
            ),
        )
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def _scan_stack(
    cfg: ModelConfig,
    ctx: ModelContext,
    stack_params: Dict[str, Any],
    x: jax.Array,
    positions: jax.Array,
    *,
    moe: bool,
    mode: str,
    caches: Optional[Dict[str, Any]] = None,
    cache_pos: Optional[jax.Array] = None,
    q_chunk: Optional[int] = None,
):
    def body(carry, layer_in):
        x_in, aux_in = carry
        p, cache = layer_in
        x_out, cache_out, aux = _block(
            cfg, ctx, p, x_in, positions,
            moe=moe, mode=mode, cache=cache, cache_pos=cache_pos, q_chunk=q_chunk,
        )
        return (x_out, aux_in + aux), cache_out

    body = _remat(body, cfg.remat_policy if mode == "train" else "none")
    (x, aux), caches_out = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stack_params, caches),
        unroll=True if cfg.scan_unroll else 1,
    )
    return x, aux, caches_out


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, ctx: ModelContext, params: Dict[str, Any], tokens: jax.Array) -> jax.Array:
    x = sharded_embed_lookup(ctx, params["embed"], tokens)
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return x


def sharded_embed_lookup(ctx: ModelContext, table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Vocab-sharded embedding lookup without gathering the table.

    ``jnp.take`` from a row-sharded table makes GSPMD all-gather the whole
    [V, D] table every step (§Perf iteration 2). The TP-native form looks up
    locally with masked ids and psums the [B, S, D] partials — wire cost
    B*S*D instead of V*D (plus it reverses in backward to a local
    scatter-add). Falls back to plain take when the mesh/vocab don't permit.
    """
    mesh = ctx.mesh
    try:
        tp = mesh.shape.get("model", 1)
    except AttributeError:
        tp = 1
    V = table.shape[0]
    B = tokens.shape[0]
    if tp <= 1 or V % tp != 0:
        return jnp.take(table, tokens, axis=0)

    from jax.sharding import PartitionSpec as P

    from ..distributed.sharding import batch_partition

    tok_spec = batch_partition(mesh, B)
    tok_parts = list(tok_spec) + [None] * (tokens.ndim - len(tok_spec))
    out_parts = tok_parts + [None]

    def inner(tab_l, tok_l):
        mi = jax.lax.axis_index("model")
        v_l = tab_l.shape[0]
        rel = tok_l - mi * v_l
        ok = (rel >= 0) & (rel < v_l)
        x = jnp.take(tab_l, jnp.clip(rel, 0, v_l - 1), axis=0)
        x = jnp.where(ok[..., None], x, jnp.zeros((), x.dtype))
        return jax.lax.psum(x, "model")

    from ..distributed.sharding import shard_map_compat

    return shard_map_compat(
        inner,
        mesh=mesh,
        in_specs=(P("model", None), P(*tok_parts)),
        out_specs=P(*out_parts),
        check_vma=False,
    )(table, tokens)


def unembed(cfg: ModelConfig, ctx: ModelContext, params: Dict[str, Any], x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return constrain(logits, ctx.rules, "batch", None, "vocab")


def forward(
    cfg: ModelConfig,
    ctx: ModelContext,
    params: Dict[str, Any],
    tokens: jax.Array,  # [B, S]
    *,
    mode: str = "train",
    prefix_embeds: Optional[jax.Array] = None,
    caches: Optional[Dict[str, Any]] = None,
    cache_pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, Any]]]:
    """Returns (logits, aux_loss, caches_out)."""
    x = embed_tokens(cfg, ctx, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if mode == "decode":
        positions = (jnp.zeros((B, 1), jnp.int32) + cache_pos)
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = constrain(x, ctx.rules, "batch", None, None)
    q_chunk = cfg.attn_q_chunk if (mode != "decode" and S > cfg.attn_q_chunk) else None

    aux_total = jnp.zeros((), jnp.float32)
    caches_out: Dict[str, Any] = {}
    for stack_name, moe in (("layers", False), ("dense_layers", False), ("moe_layers", True)):
        if stack_name not in params:
            continue
        x, aux, nc = _scan_stack(
            cfg, ctx, params[stack_name], x, positions,
            moe=moe, mode=mode,
            caches=caches.get(stack_name) if caches else None,
            cache_pos=cache_pos, q_chunk=q_chunk,
        )
        aux_total += aux
        if nc is not None:
            caches_out[stack_name] = nc

    x = rms_norm(x, params["final_norm"])
    logits = unembed(cfg, ctx, params, x)
    return logits, aux_total, (caches_out or None)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Stacked decode caches. Sliding-window archs get ring buffers."""

    def one_layer_cache() -> Dict[str, Any]:
        c: Dict[str, Any] = {}
        if cfg.use_mla:
            c["attn"] = {
                "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.dtype),
                "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), cfg.dtype),
            }
        else:
            ring = bool(cfg.sliding_window) and cfg.sliding_window < max_len
            kv_len = cfg.sliding_window if ring else max_len
            c["attn"] = init_kv_cache(
                batch, kv_len, cfg.n_kv_heads, cfg.resolved_head_dim, cfg.dtype, ring=ring
            )
        if cfg.family == "hybrid":
            c["ssm"] = init_ssm_state(batch, cfg.ssm_expand * cfg.d_model, cfg.ssm_state)
        return c

    def stacked(n: int):
        return jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf[None], (n,) + leaf.shape).copy(),
            one_layer_cache(),
        )

    caches: Dict[str, Any] = {}
    if cfg.n_experts and cfg.first_dense_layers:
        caches["dense_layers"] = stacked(cfg.first_dense_layers)
        caches["moe_layers"] = stacked(cfg.n_layers - cfg.first_dense_layers)
    elif cfg.n_experts:
        caches["moe_layers"] = stacked(cfg.n_layers)
    else:
        caches["layers"] = stacked(cfg.n_layers)
    return caches
