from .model import Model, build_model, cross_entropy
from .transformer import ModelContext, init_caches

__all__ = ["Model", "ModelContext", "build_model", "cross_entropy", "init_caches"]
