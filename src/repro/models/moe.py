"""Mixture-of-Experts layer with explicit expert parallelism (DeepSeek-style).

Sharding design (DESIGN.md §6):
  * experts sharded over the ``data`` axis (EP) — all-to-all stays intra-pod;
  * token-slot pairs additionally split over the ``model`` axis, so dispatch
    activation volume per chip is T*k*D / (ep*tp);
  * expert weights are replicated across ``model`` within a data row (their
    optimizer states are ZeRO-sharded over ``model`` instead — see
    train/optimizer.py);
  * shared experts (DeepSeek's always-on experts) run as a plain TP MLP.

The dispatch is a shard_map region: top-k routing, capacity-bounded
scatter into per-destination send buffers, ``jax.lax.all_to_all`` over
``data``, a second capacity-bounded dispatch onto local experts, grouped
expert matmul, and the inverse path. Tokens over capacity are dropped
(GShard semantics, capacity_factor configurable); an auxiliary
load-balancing loss is returned to the trainer.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import ParamDef, act_fn


def moe_defs(
    n_layers: int,
    d_model: int,
    n_experts: int,
    d_ff_expert: int,
    n_shared: int,
) -> Dict[str, Any]:
    L = (n_layers,) if n_layers else ()
    pl = (None,) * len(L)
    defs: Dict[str, Any] = {
        "router": ParamDef(L + (d_model, n_experts), pl + ("embed", None), dtype=jnp.float32),
        "w_gate": ParamDef(L + (n_experts, d_model, d_ff_expert), pl + ("experts", "embed", None)),
        "w_up": ParamDef(L + (n_experts, d_model, d_ff_expert), pl + ("experts", "embed", None)),
        "w_down": ParamDef(L + (n_experts, d_ff_expert, d_model), pl + ("experts", None, "embed")),
    }
    if n_shared:
        d_sh = n_shared * d_ff_expert
        defs["shared"] = {
            "w_gate": ParamDef(L + (d_model, d_sh), pl + ("embed", "ffn")),
            "w_up": ParamDef(L + (d_model, d_sh), pl + ("embed", "ffn")),
            "w_down": ParamDef(L + (d_sh, d_model), pl + ("ffn", "embed")),
        }
    return defs


def _axis_size(name: str) -> int:
    try:
        return jax.lax.axis_size(name)
    except NameError:
        return 1


def _dispatch(flat_idx, values, n_dest, capacity, fill=0):
    """Scatter ``values`` [P, ...] into [n_dest, capacity, ...] buffers.

    flat_idx: [P] destination ids (−1 = invalid). Returns (buffers, slot,
    kept) where ``slot`` is each pair's row in its destination buffer
    (capacity overflow and invalid pairs land in a trash row that is sliced
    off — GShard-style token dropping). ``fill`` sets the empty-slot value
    (use −1 for id buffers so empty slots are not mistaken for expert 0).
    """
    onehot = jax.nn.one_hot(flat_idx, n_dest, dtype=jnp.int32)  # invalid -> 0s
    slot = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(slot * onehot, axis=1)  # [P]
    valid = (flat_idx >= 0) & (slot < capacity)
    dest = jnp.where(valid, flat_idx, n_dest - 1)
    row = jnp.where(valid, slot, capacity)  # trash row
    buf_shape = (n_dest, capacity + 1) + values.shape[1:]
    buffers = jnp.full(buf_shape, fill, values.dtype).at[dest, row].set(values)
    return buffers[:, :capacity], slot, valid


def moe_layer(
    params: Dict[str, Any],
    x: jax.Array,  # [B, S, D] (batch sharded over dp axes, replicated over model)
    *,
    mesh,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
    dp_axes: Tuple[str, ...] = ("data",),
    ep_axis: str = "data",
    tp_axis: str = "model",
) -> Tuple[jax.Array, jax.Array]:
    """Routed experts. Returns (y, aux_loss)."""
    n_experts = params["w_gate"].shape[0]
    d_model = x.shape[-1]
    ep = mesh.shape[ep_axis] if ep_axis in mesh.axis_names else 1
    tp = mesh.shape[tp_axis] if tp_axis in mesh.axis_names else 1
    assert n_experts % ep == 0, (n_experts, ep)
    e_local = n_experts // ep

    batch_spec = tuple(a for a in dp_axes if a in mesh.axis_names)
    x_spec = P(batch_spec if len(batch_spec) > 1 else (batch_spec[0] if batch_spec else None), None, None)
    w_spec = P(ep_axis, None, None)  # experts sharded over data
    r_spec = P(None, None)

    def inner(x_l, router_w, w_gate, w_up, w_down):
        B_l, S, D = x_l.shape
        T = B_l * S
        xf = x_l.reshape(T, D)

        # ---- routing (computed redundantly per model shard; cheap) -------
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w)
        probs = jax.nn.softmax(logits, axis=-1)
        w_topk, idx_topk = jax.lax.top_k(probs, top_k)  # [T, k]
        w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)

        # load-balance aux loss (Switch/GShard form)
        me = probs.mean(axis=0)
        ce = jnp.zeros((n_experts,), jnp.float32).at[idx_topk.reshape(-1)].add(1.0)
        ce = ce / jnp.maximum(ce.sum(), 1.0)
        aux = n_experts * jnp.sum(me * ce)

        # ---- split token-slot pairs over the model axis -------------------
        mi = jax.lax.axis_index(tp_axis) if tp > 1 else jnp.int32(0)
        P_total = T * top_k
        pair_token = jnp.repeat(jnp.arange(T), top_k)
        pair_expert = idx_topk.reshape(-1)
        pair_w = w_topk.reshape(-1)
        P_pad = -(-P_total // tp) * tp
        pad = P_pad - P_total
        pair_token = jnp.pad(pair_token, (0, pad))
        pair_expert = jnp.pad(pair_expert, (0, pad), constant_values=-1)
        pair_w = jnp.pad(pair_w, (0, pad))
        P_l = P_pad // tp
        sl = mi * P_l
        my_token = jax.lax.dynamic_slice_in_dim(pair_token, sl, P_l)
        my_expert = jax.lax.dynamic_slice_in_dim(pair_expert, sl, P_l)
        my_w = jax.lax.dynamic_slice_in_dim(pair_w, sl, P_l)

        # ---- first dispatch: to expert-owning data shards ------------------
        cap1 = max(8, int(math.ceil(P_l / ep * capacity_factor)))
        dest = jnp.where(my_expert >= 0, my_expert // e_local, -1)
        x_pairs = xf[my_token]  # [P_l, D]
        send_x, slot1, valid1 = _dispatch(dest, x_pairs, ep, cap1)
        meta = jnp.where(valid1, my_expert % e_local, -1)
        send_m, _, _ = _dispatch(dest, meta, ep, cap1, fill=-1)
        if ep > 1:
            recv_x = jax.lax.all_to_all(send_x, ep_axis, 0, 0, tiled=False)
            recv_m = jax.lax.all_to_all(send_m, ep_axis, 0, 0, tiled=False)
        else:
            recv_x, recv_m = send_x[None], send_m[None]
        recv_x = recv_x.reshape(ep * cap1, D)
        recv_m = recv_m.reshape(ep * cap1)

        # ---- second dispatch: onto local experts ---------------------------
        cap2 = max(8, int(math.ceil(ep * cap1 / e_local * capacity_factor)))
        xe, slot2, valid2 = _dispatch(recv_m, recv_x, e_local, cap2)  # [E_l, C2, D]

        # ---- grouped expert MLP -------------------------------------------
        a = act_fn(activation)
        gate = jnp.einsum("ecd,edf->ecf", xe, w_gate)
        up = jnp.einsum("ecd,edf->ecf", xe, w_up)
        ye = jnp.einsum("ecf,efd->ecd", a(gate) * up, w_down)  # [E_l, C2, D]

        # ---- inverse path ---------------------------------------------------
        e_ids = jnp.where(recv_m >= 0, recv_m, 0)
        row2 = jnp.where(valid2, slot2, cap2 - 1)
        back = ye[e_ids, row2] * valid2[:, None].astype(ye.dtype)  # [ep*cap1, D]
        back = back.reshape(ep, cap1, D)
        if ep > 1:
            ret = jax.lax.all_to_all(back, ep_axis, 0, 0, tiled=False)
        else:
            ret = back[0][None]
        ret = ret.reshape(ep, cap1, D)
        d1 = jnp.where(valid1, dest, 0)
        r1 = jnp.where(valid1, slot1, 0)
        pair_out = ret[d1, jnp.minimum(r1, cap1 - 1)] * valid1[:, None].astype(ret.dtype)
        pair_out = pair_out * my_w[:, None].astype(pair_out.dtype)

        # combine pairs back onto local tokens, then sum over model shards
        y = jnp.zeros((T, D), pair_out.dtype).at[my_token].add(
            jnp.where(valid1[:, None], pair_out, 0)
        )
        if tp > 1:
            y = jax.lax.psum(y, tp_axis)
            aux = jax.lax.pmean(aux, tp_axis)
        for ax in batch_spec:
            aux = jax.lax.pmean(aux, ax)
        if ep > 1 and ep_axis not in batch_spec:
            aux = jax.lax.pmean(aux, ep_axis)
        return y.reshape(B_l, S, D).astype(x_l.dtype), aux

    from ..distributed.sharding import shard_map_compat

    y, aux = shard_map_compat(
        inner,
        mesh=mesh,
        in_specs=(x_spec, r_spec, w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return y, aux
