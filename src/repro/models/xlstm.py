"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent).

mLSTM has a stabilized parallel ("attention-like") form used for training
and an O(1) recurrent form used for decode:

    C_t = f_t C_{t-1} + i_t v_t k_t^T        (matrix memory, per head)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

with exponential gating stabilized by the running max m_t. The parallel
form materializes the decay matrix D[t,s] = exp(log i_s + cumlogf_t -
cumlogf_s - m_t) and computes h = ((Q K^T / sqrt(d)) o D) V normalized.

sLSTM keeps per-head scalar memories with recurrent (block-diagonal) gate
connections — no parallel form exists, so training scans over time; this is
the memory-bound roofline case among the assigned archs (EXPERIMENTS.md).

The 350M config interleaves blocks 7:1 (mLSTM:sLSTM), grouped so layers
scan as stacked groups.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamDef, rms_norm

PROJ_FACTOR = 2  # mLSTM block up-projection factor


def mlstm_defs(n_layers: int, d_model: int, n_heads: int) -> Dict[str, Any]:
    d_in = PROJ_FACTOR * d_model
    L = (n_layers,) if n_layers else ()
    pl = (None,) * len(L)
    return {
        "norm": ParamDef(L + (d_model,), pl + ("embed",), init="zeros"),
        "w_up": ParamDef(L + (d_model, 2 * d_in), pl + ("embed", "ssm_inner")),
        "w_qkv": ParamDef(L + (d_in, 3 * d_in), pl + ("ssm_inner", None)),
        "w_if": ParamDef(L + (d_in, 2 * n_heads), pl + ("ssm_inner", None), scale=0.01),
        "b_if": ParamDef(L + (2 * n_heads,), pl + (None,), init="zeros"),
        "out_norm": ParamDef(L + (d_in,), pl + ("ssm_inner",), init="zeros"),
        "w_down": ParamDef(L + (d_in, d_model), pl + ("ssm_inner", "embed")),
    }


def slstm_defs(n_layers: int, d_model: int, n_heads: int) -> Dict[str, Any]:
    dh = d_model // n_heads
    L = (n_layers,) if n_layers else ()
    pl = (None,) * len(L)
    return {
        "norm": ParamDef(L + (d_model,), pl + ("embed",), init="zeros"),
        "w_gates": ParamDef(L + (d_model, 4 * d_model), pl + ("embed", "ssm_inner")),
        "r_gates": ParamDef(L + (n_heads, dh, 4 * dh), pl + (None, None, None), scale=0.02),
        "b_gates": ParamDef(L + (4 * d_model,), pl + ("ssm_inner",), init="zeros"),
        "out_norm": ParamDef(L + (d_model,), pl + ("embed",), init="zeros"),
        "w_out": ParamDef(L + (d_model, d_model), pl + ("embed", "embed")),
    }


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_parallel(q, k, v, log_i, log_f):
    """Stabilized parallel mLSTM. q,k,v: [B,S,H,Dh]; gates: [B,S,H] (fp32)."""
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    cum_f = jnp.cumsum(log_f, axis=1)  # [B,S,H]
    # log D[t, u] = log_i[u] + cum_f[t] - cum_f[u], valid for u <= t
    log_d = (
        cum_f[:, :, None, :]
        - cum_f[:, None, :, :]
        + log_i[:, None, :, :]
    )  # [B, T, U, H]
    tri = jnp.tril(jnp.ones((s, s), bool))
    log_d = jnp.where(tri[None, :, :, None], log_d, -jnp.inf)
    m = jnp.max(log_d, axis=2, keepdims=True)  # [B,T,1,H] stabilizer
    d = jnp.exp(log_d - m)
    scores = jnp.einsum("bthd,buhd->btuh", q, k, preferred_element_type=jnp.float32) * scale
    weighted = scores * d
    norm = jnp.maximum(jnp.abs(weighted.sum(axis=2)), jnp.exp(-m[:, :, 0]))  # [B,T,H]
    out = jnp.einsum("btuh,buhd->bthd", weighted, v.astype(jnp.float32))
    return (out / norm[..., None]).astype(q.dtype)


def _mlstm_chunkwise(q, k, v, log_i, log_f, *, chunk: int = 256, init_state=None, unroll: bool = False):
    """Chunkwise-parallel mLSTM: recurrent state across chunks, quadratic
    only within a chunk — activation memory O(B*C*C*H) instead of O(B*S*S*H).

    State (c, n, m) represents the true memory as ``c * exp(m)`` (and
    ``n * exp(m)``), keeping the exponential gating stabilized across chunks.
    """
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    def reshape_chunks(x_, extra):
        return x_.reshape((b, n_chunks, chunk) + extra).swapaxes(0, 1)

    qc = reshape_chunks(q, (h, dh))
    kc = reshape_chunks(k, (h, dh))
    vc = reshape_chunks(v, (h, dh))
    ic = reshape_chunks(log_i, (h,))
    fc = reshape_chunks(log_f, (h,))

    if init_state is not None:
        c0, n0, m0 = init_state["c"], init_state["n"], init_state["m"]
    else:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, inputs):
        c_mat, n_vec, m_prev = carry
        q_, k_, v_, li, lf = inputs  # [B,C,H,*]
        F = jnp.cumsum(lf, axis=1)  # [B,C,H] inclusive cumsum of log f
        # log weights of intra-chunk source u for target t: F_t - F_u + li_u
        log_w = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
        log_w = jnp.where(tri[None, :, :, None], log_w, -jnp.inf)
        inter_log = F + m_prev[:, None, :]  # [B,C,H]
        m_t = jnp.maximum(jnp.max(log_w, axis=2), inter_log)  # [B,C,H]
        d = jnp.exp(log_w - m_t[:, :, None, :])  # [B,C,U,H]
        inter_scale = jnp.exp(inter_log - m_t)  # [B,C,H]

        scores = jnp.einsum("bthd,buhd->btuh", q_, k_, preferred_element_type=jnp.float32) * scale
        intra = jnp.einsum("btuh,buhd->bthd", scores * d, v_.astype(jnp.float32))
        qf = q_.astype(jnp.float32) * scale
        inter = jnp.einsum("bthd,bhdv->bthv", qf, c_mat) * inter_scale[..., None]
        num = intra + inter
        # normalizer: |q . n_t| with n_t split into intra + inter parts
        den_inter = jnp.einsum("bthd,bhd->bth", qf, n_vec) * inter_scale
        den_intra = jnp.einsum("bthd,buhd,btuh->bth", qf, k_.astype(jnp.float32), d)
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        h_out = (num / den[..., None]).astype(q_.dtype)

        # ---- state update to end of chunk --------------------------------
        F_C = F[:, -1]  # [B,H]
        m_new = jnp.maximum(F_C + m_prev, jnp.max(F_C[:, None] - F + li, axis=1))
        w_u = jnp.exp(F_C[:, None] - F + li - m_new[:, None])  # [B,C,H]
        c_new = (
            jnp.exp(F_C + m_prev - m_new)[:, :, None, None] * c_mat
            + jnp.einsum("buh,buhk,buhv->bhkv", w_u, k_.astype(jnp.float32), v_.astype(jnp.float32))
        )
        n_new = jnp.exp(F_C + m_prev - m_new)[:, :, None] * n_vec + jnp.einsum(
            "buh,buhk->bhk", w_u, k_.astype(jnp.float32)
        )
        return (c_new, n_new, m_new), h_out

    (c_f, n_f, m_f), hs = jax.lax.scan(step, (c0, n0, m0), (qc, kc, vc, ic, fc), unroll=True if unroll else 1)
    hs = hs.swapaxes(0, 1).reshape(b, n_chunks * chunk, h, dh)
    return hs[:, :s], {"c": c_f, "n": n_f, "m": m_f}


def _mlstm_recurrent_step(state, q, k, v, log_i, log_f):
    """One decode step. state: dict(c [B,H,Dk,Dv], n [B,H,Dk], m [B,H])."""
    dh = q.shape[-1]
    scale = dh ** -0.5
    m_new = jnp.maximum(log_f + state["m"], log_i)  # [B,H]
    f_ = jnp.exp(log_f + state["m"] - m_new)
    i_ = jnp.exp(log_i - m_new)
    c = f_[..., None, None] * state["c"] + i_[..., None, None] * jnp.einsum(
        "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n = f_[..., None] * state["n"] + i_[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhkv,bhk->bhv", c, q.astype(jnp.float32) * scale)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q.astype(jnp.float32) * scale)), jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q.dtype)
    return {"c": c, "n": n, "m": m_new}, h


def mlstm_block(
    params: Dict[str, Any],
    x: jax.Array,  # [B,S,D]
    n_heads: int,
    *,
    state: Optional[Dict[str, jax.Array]] = None,
    return_state: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    b, s, d = x.shape
    xn = rms_norm(x, params["norm"])
    up = jnp.einsum("bsd,de->bse", xn, params["w_up"])
    inner, z = jnp.split(up, 2, axis=-1)
    d_in = inner.shape[-1]
    dh = d_in // n_heads
    qkv = jnp.einsum("bse,ef->bsf", inner, params["w_qkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, n_heads, dh)
    k = k.reshape(b, s, n_heads, dh)
    v = v.reshape(b, s, n_heads, dh)
    gates = jnp.einsum("bse,eg->bsg", inner, params["w_if"]).astype(jnp.float32) + params[
        "b_if"
    ].astype(jnp.float32)
    log_i, f_raw = jnp.split(gates, 2, axis=-1)  # [B,S,H]
    log_f = jax.nn.log_sigmoid(f_raw)

    new_state = None
    if state is not None and s == 1:
        new_state, h1 = _mlstm_recurrent_step(
            state, q[:, 0], k[:, 0], v[:, 0], log_i[:, 0], log_f[:, 0]
        )
        h = h1[:, None]
    elif state is None and not return_state and s <= 256:
        h = _mlstm_parallel(q, k, v, log_i, log_f)
    else:
        h, final_state = _mlstm_chunkwise(q, k, v, log_i, log_f, init_state=state, unroll=unroll)
        if return_state or state is not None:
            new_state = final_state
    h = h.reshape(b, s, d_in)
    h = rms_norm(h, params["out_norm"]) * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", h, params["w_down"])
    return x + y, new_state


def init_mlstm_state(batch: int, d_model: int, n_heads: int):
    d_in = PROJ_FACTOR * d_model
    dh = d_in // n_heads
    return {
        "c": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        # -inf-like stabilizer: an empty memory must not distort the
        # normalizer floor exp(-m) on the first real update.
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_step(params_r, carry, zifo):
    """carry: (c, n, m, h_prev) each [B, H, Dh] (m: [B,H,Dh]); one timestep."""
    c, n, m, h_prev = carry
    rec = jnp.einsum("bhd,hdg->bhg", h_prev, params_r)  # [B,H,4Dh]
    zz, ii, ff, oo = jnp.split(zifo + rec, 4, axis=-1)
    z = jnp.tanh(zz)
    o = jax.nn.sigmoid(oo)
    log_f = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(log_f + m, ii)
    i_ = jnp.exp(ii - m_new)
    f_ = jnp.exp(log_f + m - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h), h


def slstm_block(
    params: Dict[str, Any],
    x: jax.Array,  # [B,S,D]
    n_heads: int,
    *,
    state: Optional[Tuple[jax.Array, ...]] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, ...]]]:
    b, s, d = x.shape
    dh = d // n_heads
    xn = rms_norm(x, params["norm"])
    zifo = (
        jnp.einsum("bsd,dg->bsg", xn, params["w_gates"]).astype(jnp.float32)
        + params["b_gates"].astype(jnp.float32)
    ).reshape(b, s, n_heads, 4 * dh)
    if state is None:
        zeros = jnp.zeros((b, n_heads, dh), jnp.float32)
        carry = (zeros, zeros, zeros, zeros)
    else:
        carry = state
    r = params["r_gates"].astype(jnp.float32)

    def step(c, z_t):
        return _slstm_step(r, c, z_t)

    carry, hs = jax.lax.scan(step, carry, zifo.swapaxes(0, 1))  # scan over S
    h = hs.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    h = rms_norm(h, params["out_norm"])
    y = jnp.einsum("bsd,de->bse", h, params["w_out"])
    new_state = carry if (state is not None or return_state) else None
    return x + y, new_state


def init_slstm_state(batch: int, d_model: int, n_heads: int):
    dh = d_model // n_heads
    zeros = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return (zeros, zeros, zeros, zeros)
