"""Whisper-style encoder-decoder backbone (audio family).

The conv frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings [B, frames, d_model]. The backbone is faithful:
pre-LN transformer encoder (sinusoidal positions), decoder with causal
self-attention + cross-attention (learned positions), GELU MLPs, tied
unembedding. decode_32k treats the decoder as a backbone stress shape (far
beyond Whisper's 448-token window — noted in DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .layers import (
    ParamDef,
    causal_attention,
    gqa_attention_block,
    init_kv_cache,
    layer_norm,
)

MAX_DECODER_POS = 1 << 16


def _plain_mlp_defs(d_model: int, d_ff: int) -> Dict[str, ParamDef]:
    return {
        "w1": ParamDef((d_model, d_ff), ("embed", "ffn")),
        "b1": ParamDef((d_ff,), ("ffn",), init="zeros"),
        "w2": ParamDef((d_ff, d_model), ("ffn", "embed")),
        "b2": ParamDef((d_model,), ("embed",), init="zeros"),
    }


def _plain_mlp(p, x):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"], approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"]


def _ln_defs(d: int) -> Dict[str, ParamDef]:
    return {"w": ParamDef((d,), ("embed",), init="ones"), "b": ParamDef((d,), ("embed",), init="zeros")}


def _attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    from .layers import gqa_defs

    return gqa_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, qkv_bias=True)


def _stack(defs, n: int):
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (None,) + d.logical, d.init, d.scale, d.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def encdec_defs(cfg: ModelConfig) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.vocab_size
    enc_block = {
        "ln1": _ln_defs(D),
        "attn": _attn_defs(cfg),
        "ln2": _ln_defs(D),
        "mlp": _plain_mlp_defs(D, cfg.d_ff),
    }
    dec_block = {
        "ln1": _ln_defs(D),
        "self_attn": _attn_defs(cfg),
        "ln2": _ln_defs(D),
        "cross_attn": _attn_defs(cfg),
        "ln3": _ln_defs(D),
        "mlp": _plain_mlp_defs(D, cfg.d_ff),
    }
    return {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=D ** -0.5),
        "pos_embed": ParamDef((MAX_DECODER_POS, D), (None, "embed"), scale=0.02),
        "encoder": _stack(enc_block, cfg.encoder_layers),
        "enc_ln": _ln_defs(D),
        "decoder": _stack(dec_block, cfg.n_layers),
        "dec_ln": _ln_defs(D),
    }


def _sinusoids(length: int, d: int) -> np.ndarray:
    half = d // 2
    scale = np.log(10000.0) / max(1, half - 1)
    inv = np.exp(-scale * np.arange(half))
    pos = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(pos), np.cos(pos)], axis=1).astype(np.float32)


def encode(cfg: ModelConfig, params: Dict[str, Any], frames: jax.Array) -> jax.Array:
    """frames: [B, T, D] stub embeddings -> encoder states."""
    T = frames.shape[1]
    x = frames + jnp.asarray(_sinusoids(T, cfg.d_model)).astype(frames.dtype)

    def body(x_in, p):
        h = layer_norm(x_in, p["ln1"]["w"], p["ln1"]["b"])
        attn, _ = gqa_attention_block(p["attn"], h, jnp.zeros(h.shape[:2], jnp.int32), causal=False, use_rope=False)
        x_mid = x_in + attn
        h2 = layer_norm(x_mid, p["ln2"]["w"], p["ln2"]["b"])
        return x_mid + _plain_mlp(p["mlp"], h2), None

    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=True if cfg.scan_unroll else 1)
    return layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"])


def _cross(p, x, enc_k, enc_v):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]) + p["bq"]
    out = causal_attention(q, enc_k, enc_v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _enc_kv(p, enc):
    k = jnp.einsum("btd,dhk->bthk", enc, p["wk"]) + p["bk"]
    v = jnp.einsum("btd,dhk->bthk", enc, p["wv"]) + p["bv"]
    return k, v


def decode_stack(
    cfg: ModelConfig,
    params: Dict[str, Any],
    tokens: jax.Array,
    enc: Optional[jax.Array],
    *,
    mode: str = "train",
    caches: Optional[Dict[str, Any]] = None,
    cache_pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    B, S = tokens.shape
    if mode == "decode":
        positions = jnp.zeros((B, S), jnp.int32) + cache_pos
        pos_ids = jnp.zeros((S,), jnp.int32) + cache_pos
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        pos_ids = jnp.arange(S)
    x = jnp.take(params["embed"], tokens, axis=0) + params["pos_embed"][pos_ids][None]

    def body(carry, layer_in):
        x_in = carry
        p, cache = layer_in
        h = layer_norm(x_in, p["ln1"]["w"], p["ln1"]["b"])
        self_out, self_cache = gqa_attention_block(
            p["self_attn"], h, positions,
            mode=mode, cache=cache.get("attn") if cache else None,
            cache_pos=cache_pos, use_rope=False,
            q_chunk=cfg.attn_q_chunk if mode != "decode" else None,
        )
        x_mid = x_in + self_out
        h2 = layer_norm(x_mid, p["ln2"]["w"], p["ln2"]["b"])
        if mode == "decode":
            enc_k, enc_v = cache["cross_k"], cache["cross_v"]
        else:
            enc_k, enc_v = _enc_kv(p["cross_attn"], enc)
        x_mid = x_mid + _cross_with_kv(p["cross_attn"], h2, enc_k, enc_v)
        h3 = layer_norm(x_mid, p["ln3"]["w"], p["ln3"]["b"])
        x_out = x_mid + _plain_mlp(p["mlp"], h3)
        cache_out = None
        if mode == "prefill":
            cache_out = {"attn": self_cache, "cross_k": enc_k, "cross_v": enc_v}
        elif mode == "decode":
            cache_out = {"attn": self_cache, "cross_k": enc_k, "cross_v": enc_v}
        return x_out, cache_out

    x, caches_out = jax.lax.scan(body, x, (params["decoder"], caches), unroll=True if cfg.scan_unroll else 1)
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, caches_out


def _cross_with_kv(p, x, enc_k, enc_v):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]) + p["bq"]
    out = causal_attention(q, enc_k, enc_v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_decoder_caches(cfg: ModelConfig, batch: int, max_len: int, enc_frames: int):
    one = {
        "attn": init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.resolved_head_dim, cfg.dtype),
        "cross_k": jnp.zeros((batch, enc_frames, cfg.n_kv_heads, cfg.resolved_head_dim), cfg.dtype),
        "cross_v": jnp.zeros((batch, enc_frames, cfg.n_kv_heads, cfg.resolved_head_dim), cfg.dtype),
    }
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (cfg.n_layers,) + l.shape).copy(), one)
