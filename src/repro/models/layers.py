"""Foundational layers for the 10 assigned architectures.

Functional style: parameter trees are plain nested dicts of jax.Arrays built
from ``ParamDef`` declarations that carry logical sharding axes (resolved by
``distributed/sharding.py``). All compute is bf16 with fp32 softmax/norm
statistics; Megatron-style TP pairs (column then row) keep one psum per
block under GSPMD propagation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict of arrays


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones
    scale: Optional[float] = None
    dtype: Any = jnp.bfloat16

    def initialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(max(1, fan_in))
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(self.dtype)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_tree(defs, key) -> Params:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [d.initialize(k) for d, k in zip(leaves, keys)])


def abstract_tree(defs) -> Params:
    return jax.tree.map(lambda d: d.abstract(), defs, is_leaf=is_def)


def logical_tree(defs):
    return jax.tree.map(lambda d: d.logical, defs, is_leaf=is_def)


# ---------------------------------------------------------------------------
# norms / embeddings / rope
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (normed * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Half-rotation RoPE. x: [..., S, H, Dh]; positions: [..., S]."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / MLP
# ---------------------------------------------------------------------------

def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)


def gated_mlp_defs(d_model: int, d_ff: int, *, prefix_dims: Tuple[int, ...] = ()) -> Dict[str, ParamDef]:
    pl = (None,) * len(prefix_dims)
    return {
        "w_gate": ParamDef(prefix_dims + (d_model, d_ff), pl + ("embed", "ffn")),
        "w_up": ParamDef(prefix_dims + (d_model, d_ff), pl + ("embed", "ffn")),
        "w_down": ParamDef(prefix_dims + (d_ff, d_model), pl + ("ffn", "embed")),
    }


def gated_mlp(params: Params, x: jax.Array, activation: str = "silu") -> jax.Array:
    a = act_fn(activation)
    gate = jnp.einsum("...sd,df->...sf", x, params["w_gate"])
    up = jnp.einsum("...sd,df->...sf", x, params["w_up"])
    return jnp.einsum("...sf,fd->...sd", a(gate) * up, params["w_down"])


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def gqa_defs(
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    *,
    qkv_bias: bool = False,
) -> Dict[str, ParamDef]:
    defs: Dict[str, ParamDef] = {
        "wq": ParamDef((d_model, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((n_heads, head_dim, d_model), ("heads", "head_dim", "embed")),
    }
    if qkv_bias:
        defs["bq"] = ParamDef((n_heads, head_dim), ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((n_kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef((n_kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")
    return defs


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B,Sq,K,G,Dh], k: [B,Skv,K,Dh] -> [B,K,G,Sq,Skv] (fp32)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32)


def _grouped_values(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: [B,K,G,Sq,Skv], v: [B,Skv,K,Dh] -> [B,Sq,K,G,Dh]."""
    return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)


def causal_attention(
    q: jax.Array,  # [B, Sq, H, Dh]
    k: jax.Array,  # [B, Skv, Kv, Dh]
    v: jax.Array,  # [B, Skv, Kv, Dh]
    *,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,  # valid cache length per batch [B]
    sliding_window: Optional[int] = None,
    q_chunk: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    causal: bool = True,
) -> jax.Array:
    """Grouped-query attention with optional q-chunking (memory-bounded).

    Chunking unrolls over static q-blocks, each attending only to the kv
    prefix it can see — no flops on fully-masked blocks (the poor man's
    flash attention; the HLO stays compact because blocks share code).
    """
    b, sq, h, dh = q.shape
    kv_heads = k.shape[2]
    dv = v.shape[-1]  # may differ from dh (MLA: qk_dim != v_head_dim)
    assert h % kv_heads == 0, (h, kv_heads)
    g = h // kv_heads
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, kv_heads, g, dh)

    def block(q_blk, blk_offset, kv_hi):
        """q_blk: [B, C, K, G, Dh] attending to k[:, :kv_hi]."""
        kk = k[:, :kv_hi]
        vv = v[:, :kv_hi]
        scores = _grouped_scores(q_blk, kk) * scale  # [B,K,G,C,kv_hi]
        q_pos = blk_offset + jnp.arange(q_blk.shape[1])[:, None] + (
            q_offset if not isinstance(q_offset, int) else jnp.int32(q_offset)
        )
        kv_pos = jnp.arange(kv_hi)[None, :]
        mask = jnp.ones((q_blk.shape[1], kv_hi), dtype=bool)
        if causal:
            mask &= kv_pos <= q_pos
        if sliding_window is not None:
            mask &= kv_pos > q_pos - sliding_window
        if kv_len is not None:
            mask = mask[None] & (kv_pos[None] < kv_len[:, None, None])
            scores = jnp.where(mask[:, None, None], scores, -1e30)
        else:
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        return _grouped_values(probs, vv)

    if q_chunk is None or q_chunk >= sq or not causal or not isinstance(q_offset, int):
        out = block(qg, 0, k.shape[1])
        return out.reshape(b, sq, h, dv)

    n_blocks = -(-sq // q_chunk)
    outs = []
    for i in range(n_blocks):
        lo = i * q_chunk
        hi = min(sq, lo + q_chunk)
        kv_hi = min(k.shape[1], q_offset + hi)  # static: no masked-block flops
        outs.append(block(qg[:, lo:hi], lo, kv_hi))
    return jnp.concatenate(outs, axis=1).reshape(b, sq, h, dv)


def ring_attention_decode(
    q: jax.Array,  # [B, 1, H, Dh]
    cache: Dict[str, jax.Array],  # k/v [B, W, Kv, Dh] + pos [B?, W] int32 (-1 empty)
    k_new: jax.Array,
    v_new: jax.Array,
    position: jax.Array,  # scalar absolute position of the new token
    *,
    sliding_window: int,
    softmax_scale: Optional[float] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Sliding-window decode against a ring buffer of size W (bounded state).

    Slot ``p % W`` holds position ``p``; the per-slot position array masks
    empty and out-of-window entries — absolute RoPE stays correct because
    keys were rotated before insertion.
    """
    b, _, h, dh = q.shape
    W = cache["k"].shape[1]
    slot = position % W
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), position, jnp.int32), slot, axis=0
    )
    kv_heads = k_cache.shape[2]
    g = h // kv_heads
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dh)
    scores = _grouped_scores(q.reshape(b, 1, kv_heads, g, dh), k_cache) * scale
    valid = (pos >= 0) & (pos <= position) & (pos > position - sliding_window)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _grouped_values(probs, v_cache).reshape(b, 1, h, dh)
    return out, {"k": k_cache, "v": v_cache, "pos": pos}


def gqa_attention_block(
    params: Params,
    x: jax.Array,  # [B, S, D]
    positions: jax.Array,  # [B, S]
    *,
    rope_theta: float = 10000.0,
    mode: str = "train",  # train | prefill | decode
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
    sliding_window: Optional[int] = None,
    q_chunk: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    causal: bool = True,
    use_rope: bool = True,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """GQA attention with rope; returns (y, cache_out).

    * train:   cache_out is None.
    * prefill: cache_out = {"k","v"} post-rope full-sequence tensors (the
               serve layer lays them out into decode caches).
    * decode:  cache is required; S must be 1. Linear caches use
               dynamic-update + causal mask; sliding-window caches are ring
               buffers (bounded memory at 0.5M contexts).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    if mode == "decode":
        assert cache is not None and cache_pos is not None and x.shape[1] == 1
        if "pos" in cache:  # ring buffer (sliding window)
            out, new_cache = ring_attention_decode(
                q, cache, k, v, cache_pos,
                sliding_window=sliding_window or cache["k"].shape[1],
                softmax_scale=softmax_scale,
            )
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, axis=1)
            new_cache = {"k": k_cache, "v": v_cache}
            kv_len = jnp.full((x.shape[0],), cache_pos + 1, dtype=jnp.int32)
            out = causal_attention(
                q, k_cache, v_cache,
                q_offset=cache_pos, kv_len=kv_len,
                sliding_window=sliding_window,
                softmax_scale=softmax_scale, causal=causal,
            )
    else:
        out = causal_attention(
            q, k, v,
            sliding_window=sliding_window, q_chunk=q_chunk,
            softmax_scale=softmax_scale, causal=causal,
        )
        new_cache = {"k": k, "v": v} if mode == "prefill" else None

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def init_kv_cache(
    batch: int,
    max_len: int,
    n_kv_heads: int,
    head_dim: int,
    dtype=jnp.bfloat16,
    *,
    ring: bool = False,
):
    cache = {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
    }
    if ring:
        cache["pos"] = jnp.full((max_len,), -1, jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_attention_block(
    params: Params,
    x: jax.Array,  # decoder states [B, S, D]
    enc: jax.Array,  # encoder states [B, T, D]
) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", enc, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc, params["wv"])
    out = causal_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])
