"""AdamW with fp32 moments, global-norm clipping, and LR schedules.

Built from scratch (no optax dependency). ZeRO-1 emerges from shardings, not
from the math: moments are placed with ``zero1_spec`` (param sharding + an
extra 'data' factor), so under jit the gradient is reduce-scattered into the
moment update and the fresh params are all-gathered back — exactly the
ZeRO-1 communication pattern, chosen by GSPMD from the output shardings.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    end_lr_fraction: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to end_lr_fraction * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(1, cfg.warmup_steps)
    decay_steps = max(1, cfg.total_steps - cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.end_lr_fraction + (1 - cfg.end_lr_fraction) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: Dict[str, Any],
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"step": step, "m": new_m, "v": new_v}, metrics
