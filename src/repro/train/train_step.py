"""jit-compiled train step: loss + grad + AdamW, with microbatch gradient
accumulation, optional int8 gradient compression (error feedback), and
sharding-in/out declarations that realize DP/TP/EP/ZeRO-1.

``make_train_step`` returns (step_fn, state_shardings) so the launcher and
the dry-run share one code path.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.compression import init_error_state, quantize_with_feedback
from ..distributed.sharding import ShardingRules, fit_spec, zero1_spec
from ..models.model import Model
from ..models.transformer import ModelContext
from .optimizer import AdamWConfig, adamw_update, init_opt_state


def param_shardings(model: Model, mesh, rules: ShardingRules):
    """Logical-axis shardings, clipped to divisible dims (fit_spec)."""
    logical = model.logical()
    abstract = model.abstract()
    return jax.tree.map(
        lambda la, ab: NamedSharding(mesh, fit_spec(rules.spec(la), ab.shape, mesh)),
        logical,
        abstract,
        is_leaf=lambda x: isinstance(x, tuple) or x is None,
    )


def opt_state_shardings(model: Model, mesh, rules: ShardingRules, *, zero1: bool = True):
    """Moments: param sharding + extra 'data' factor (ZeRO-1)."""
    p_shard = param_shardings(model, mesh, rules)
    abstract = model.abstract()

    def moment(sh: NamedSharding, ab):
        spec = zero1_spec(sh.spec, ab.shape, mesh) if zero1 else sh.spec
        return NamedSharding(mesh, spec)

    m_shard = jax.tree.map(moment, p_shard, abstract)
    return {
        "step": NamedSharding(mesh, P()),
        "m": m_shard,
        "v": m_shard,
    }


def batch_shardings(mesh, rules: ShardingRules, batch_specs: Dict[str, Any]):
    return {
        k: rules.sharding(mesh, ("batch",) + (None,) * (len(v.shape) - 1))
        for k, v in batch_specs.items()
    }


def make_train_step(
    model: Model,
    mesh,
    rules: ShardingRules,
    opt_cfg: AdamWConfig,
    *,
    grad_accum: int = 1,
    compress_grads: bool = False,
    zero1: bool = True,
):
    """Returns (jit step_fn, shardings dict)."""
    ctx = ModelContext(mesh, rules)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, ctx)
        return loss, metrics

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        # Microbatch accumulation: scan over leading splits, fp32 accumulators.
        def micro(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_acc + loss), metrics

        micro_batches = jax.tree.map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]), batch
        )
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, loss_sum), metrics = jax.lax.scan(micro, (zero, 0.0), micro_batches)
        grads = jax.tree.map(lambda g: (g / grad_accum).astype(jnp.bfloat16), acc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / grad_accum, metrics, grads

    def step_fn(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        if zero1:
            # Constrain gradients to the optimizer-state (ZeRO-1) layout:
            # GSPMD then lowers the DP gradient sync as reduce-scatter
            # (wire (n-1)/n * size) instead of all-reduce (2x that) and the
            # moment update runs on the scattered shard (§Perf iteration 3).
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads,
                o_shard_m,
            )
        if compress_grads:
            grads, err = quantize_with_feedback(grads, opt_state["grad_error"])
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, {k: opt_state[k] for k in ("step", "m", "v")}
        )
        if compress_grads:
            new_opt["grad_error"] = err
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    p_shard = param_shardings(model, mesh, rules)
    o_shard = opt_state_shardings(model, mesh, rules, zero1=zero1)
    o_shard_m = o_shard["m"]
    if compress_grads:
        o_shard = dict(o_shard, grad_error=o_shard["m"])

    jit_step = jax.jit(
        step_fn,
        in_shardings=(p_shard, o_shard, None),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1),
    )
    return jit_step, {"params": p_shard, "opt": o_shard}


def init_train_state(model: Model, key, *, compress_grads: bool = False):
    params = model.init(key)
    opt = init_opt_state(params)
    if compress_grads:
        opt["grad_error"] = init_error_state(params)
    return params, opt
