from .optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from .train_step import init_train_state, make_train_step, param_shardings

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "init_train_state",
           "lr_schedule", "make_train_step", "param_shardings"]
