"""Fleet telemetry: aggregate per-reader stats into one service snapshot.

Every `ParallelGzipReader` already reports its own cache/fetcher counters
(`reader.stats()`: access/prefetch `CacheStats` plus `FetcherStats`). A
service runs dozens of readers — operators need the *fleet* view: total
speculative work, fleet hit rates, pool occupancy against budget, scheduler
fairness, per-tenant consumption. `collect()` produces that as one plain
dict (JSON-serializable, stable keys), using `CacheStats.merge` so cache
counters aggregate without racing the fetcher threads (each member cache is
snapshotted atomically; sums are computed from the snapshots).
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional

from ..core.cache import CacheStats
from ..core.chunk_fetcher import FetcherStats

#: FetcherStats fields summed across readers — derived from the dataclass so
#: a new core counter can never be silently dropped from fleet aggregation.
_FETCHER_COUNTERS = tuple(FetcherStats.__dataclass_fields__)

#: Frontier-lock counters from `ParallelGzipReader.stats()["frontier"]`:
#: every first-pass advance takes the lock once; `lock_contended` /
#: `lock_wait_s` quantify how often (and for how long) concurrent positional
#: reads actually serialized on it. Warm indexed traffic shows zero
#: acquisitions — the observable proof that pread is lock-free there.
_FRONTIER_COUNTERS = ("lock_acquires", "lock_contended", "lock_wait_s")


def aggregate_reader_reports(reports: Mapping[str, Mapping[str, Any]]) -> Dict[str, Any]:
    """Sum many ``reader.stats()`` dicts into fleet totals."""
    access = CacheStats()
    prefetch = CacheStats()
    fetcher = {k: 0 for k in _FETCHER_COUNTERS}
    frontier = {k: 0.0 if k == "lock_wait_s" else 0 for k in _FRONTIER_COUNTERS}
    for rep in reports.values():
        access = access.merge(rep.get("access", {}))
        prefetch = prefetch.merge(rep.get("prefetch", {}))
        f = rep.get("fetcher", {})
        for k in _FETCHER_COUNTERS:
            fetcher[k] += int(f.get(k, 0))
        fr = rep.get("frontier", {})
        for k in _FRONTIER_COUNTERS:
            frontier[k] += fr.get(k, 0)
    # The fetcher's combined-stats lookup records exactly one hit or miss
    # per *logical* lookup across the two tiers (access misses are
    # suppressed when the prefetch tier still gets probed), so the
    # meaningful fleet number is the combined rate; per-tier dicts keep the
    # raw counters.
    combined = access.merge(prefetch)
    return {
        "readers": len(reports),
        "access": access.as_dict(),
        "access_hit_rate": access.hit_rate,
        "prefetch": prefetch.as_dict(),
        "prefetch_hit_rate": prefetch.hit_rate,
        "hit_rate": combined.hit_rate,
        "lookups": combined.hits + combined.misses,
        "fetcher": fetcher,
        "frontier": frontier,
    }


def collect(
    *,
    reader_reports: Mapping[str, Mapping[str, Any]],
    per_file: Optional[Mapping[str, Mapping[str, Any]]] = None,
    pool=None,
    executor=None,
    index_store=None,
    service: Optional[Mapping[str, Any]] = None,
    engine=None,
    transcode=None,
    obs: Optional[Mapping[str, Any]] = None,
) -> Dict[str, Any]:
    """One service-wide snapshot. All sections are optional except readers.

    ``service`` carries the server's front-door gauges (in-flight read
    count, cumulative reads split by discipline) — the liveness complement
    to the per-reader frontier lock-wait counters in the fleet section.
    ``engine`` is the server's shared `DeviceDecodeEngine` (or anything with
    ``stats()``): batch counts, tile occupancy, queue depth, and CPU
    fallbacks land in an ``engine`` section.
    """
    out: Dict[str, Any] = {
        "fleet": aggregate_reader_reports(reader_reports),
        "per_file": {h: dict(v) for h, v in (per_file or {}).items()},
        "per_reader": {h: dict(v) for h, v in reader_reports.items()},
    }
    if pool is not None:
        out["cache_pool"] = pool.snapshot()
    if executor is not None:
        out["scheduler"] = executor.snapshot()
    if index_store is not None:
        out["index_store"] = index_store.stats.as_dict()
    if service is not None:
        out["service"] = dict(service)
    if engine is not None:
        out["engine"] = engine.stats()
    if transcode is not None:
        out["transcode"] = transcode.snapshot()
    if obs is not None:
        # Tracing/histogram/slow-read section (repro.obs): the server passes
        # the already-snapshotted dict so collect stays side-effect free.
        out["obs"] = dict(obs)
    return out


def format_summary(snapshot: Mapping[str, Any]) -> str:
    """Human-readable one-screen summary of a `collect()` snapshot."""
    lines = []
    if "ts" in snapshot or "uptime_s" in snapshot:
        lines.append(
            "snapshot #%d at ts=%.3f, uptime %.1fs"
            % (snapshot.get("snapshot_seq", 0), snapshot.get("ts", 0.0),
               snapshot.get("uptime_s", 0.0))
        )
    fleet = snapshot.get("fleet", {})
    f = fleet.get("fetcher", {})
    lines.append(
        "fleet: %d readers, %.1f MiB decompressed, tasks nominal=%d exact=%d indexed=%d"
        % (
            fleet.get("readers", 0),
            f.get("bytes_decompressed", 0) / (1 << 20),
            f.get("nominal_tasks", 0),
            f.get("exact_tasks", 0),
            f.get("indexed_tasks", 0),
        )
    )
    lines.append(
        "caches: hit-rate %.2f over %d logical lookups"
        " (access hits %d, prefetch hit-rate %.2f)"
        % (fleet.get("hit_rate", 0.0), fleet.get("lookups", 0),
           fleet.get("access", {}).get("hits", 0),
           fleet.get("prefetch_hit_rate", 0.0))
    )
    fr = fleet.get("frontier")
    svc = snapshot.get("service")
    if fr or svc:
        fr = fr or {}
        svc = svc or {}
        lines.append(
            "reads: %d in flight, %d started (%d serialized); frontier lock:"
            " %d acquires, %d contended, %.1f ms waited"
            % (svc.get("reads_in_flight", 0), svc.get("reads_started", 0),
               svc.get("reads_serialized", 0), fr.get("lock_acquires", 0),
               fr.get("lock_contended", 0), fr.get("lock_wait_s", 0.0) * 1e3)
        )
    pool = snapshot.get("cache_pool")
    if pool:
        for tier, t in sorted(pool.get("tiers", {}).items()):
            lines.append(
                "pool[%s]: %.1f/%.1f MiB, %d entries, %d evictions"
                " (%.1f MiB, recompute cost %.1f MiB)"
                % (tier, t["held"] / (1 << 20), t["budget"] / (1 << 20),
                   t["entries"], t["evictions"],
                   t.get("evicted_bytes", 0) / (1 << 20),
                   t.get("evicted_cost", 0) / (1 << 20))
            )
        for tenant, t in sorted(pool.get("tenants", {}).items()):
            lines.append(
                "tenant[%s]: %.1f MiB held, %d hits, %d misses, evictions"
                " -%d/+%d (cost -%.1f/+%.1f MiB)"
                % (tenant, t["bytes_held"] / (1 << 20), t["hits"], t["misses"],
                   t["evictions_suffered"], t["evictions_caused"],
                   t.get("eviction_cost_suffered", 0) / (1 << 20),
                   t.get("eviction_cost_caused", 0) / (1 << 20))
            )
    sched = snapshot.get("scheduler")
    if sched:
        lines.append(
            "scheduler[%s]: %d workers, %d/%d tasks done (%d cancelled),"
            " %d queued, %d priority dispatches, dispatch=%s"
            % (sched.get("fairness", "drr"), sched["max_workers"],
               sched["done"], sched["submitted"], sched.get("cancelled", 0),
               sched["queued"],
               sched.get("priority_dispatches", 0), sched["dispatch_per_tenant"])
        )
        db = sched.get("dispatched_bytes_per_tenant", {})
        if db:
            lines.append(
                "scheduler bytes: "
                + ", ".join(
                    "%s=%.1fMiB" % (t, b / (1 << 20)) for t, b in sorted(db.items())
                )
            )
    engine = snapshot.get("engine")
    if engine is not None:
        req = engine.get("requests", {})
        fb = engine.get("fallbacks", {})
        lines.append(
            "engine[%s]: %d batches over %d requests (replace=%d crc=%d),"
            " occupancy %.2f, %d queued (max %d), fallbacks replace=%d crc=%d"
            % ("device" if engine.get("available") else "cpu-only",
               engine.get("batches", 0), engine.get("batched_requests", 0),
               req.get("replace", 0), req.get("crc", 0),
               engine.get("occupancy", 0.0), engine.get("queue_depth", 0),
               engine.get("max_queue_depth", 0),
               fb.get("replace", 0), fb.get("crc", 0))
        )
    tr = snapshot.get("transcode")
    if tr is not None:
        c = tr.get("counters", {})
        lines.append(
            "transcode[%s]: %d considered, %d scheduled, %d installed,"
            " %d failed, %d skipped"
            % (tr.get("twin_codec", "?"), c.get("considered", 0),
               c.get("scheduled", 0), c.get("installed", 0),
               c.get("failed", 0), c.get("skipped_unresolvable", 0))
        )
    store = snapshot.get("index_store")
    if store is not None:
        line = "index store: %d hits, %d misses, %d puts" % (
            store["hits"], store["misses"], store["puts"]
        )
        if store.get("remote_hits") or store.get("remote_misses"):
            line += " (index exchange: %d fetched, %d failed)" % (
                store.get("remote_hits", 0), store.get("remote_misses", 0)
            )
        lines.append(line)
    gateway = snapshot.get("gateway")
    if gateway is not None:
        bridge = snapshot.get("bridge", {})
        lines.append(
            "gateway: %d requests (%d opened, %d reads, %d streams),"
            " %d x 429, %d x 304, %d disconnects,"
            " bridge %d/%d started (%d cancelled)"
            % (gateway.get("requests", 0), gateway.get("opened", 0),
               gateway.get("reads", 0), gateway.get("streams", 0),
               gateway.get("rejected_429", 0),
               gateway.get("not_modified_304", 0),
               gateway.get("disconnects_mid_stream", 0)
               + gateway.get("disconnects_mid_request", 0),
               bridge.get("started", 0), bridge.get("submitted", 0),
               bridge.get("cancelled", 0))
        )
        active = gateway.get("streams_in_progress") or {}
        for sid, st in sorted(active.items()):
            total = st.get("total", 0) or 1
            lines.append(
                "  stream[%s] %s/%s: %d/%d bytes (%.0f%%)"
                % (sid, st.get("tenant", "?"), st.get("handle", "?"),
                   st.get("sent", 0), st.get("total", 0),
                   100.0 * st.get("sent", 0) / total)
            )
    obs = snapshot.get("obs")
    if obs is not None:
        tracing = obs.get("tracing", {})
        hists = obs.get("histograms", {})
        rr = hists.get("server.read_range")
        line = "obs: tracing %s (%d spans recorded)" % (
            "on" if tracing.get("enabled") else "off",
            tracing.get("recorded", 0),
        )
        if rr and rr.get("count"):
            line += ", read_range p50=%.1fms p99=%.1fms over %d" % (
                rr["p50_s"] * 1e3, rr["p99_s"] * 1e3, rr["count"]
            )
        slow = obs.get("slow_requests") or []
        if slow:
            line += ", %d slow request(s) logged" % len(slow)
        lines.append(line)
    router = snapshot.get("router")
    if router is not None:
        membership = router.get("membership", {})
        counters = router.get("counters", {})
        lines.append(
            "fleet router: %d/%d peers alive, %d opens, %d failovers"
            " (%d streams resumed), %d revalidations"
            % (membership.get("alive", 0), membership.get("total", 0),
               counters.get("opens", 0), counters.get("failovers", 0),
               counters.get("resumed_streams", 0),
               counters.get("revalidations", 0))
        )
        for url, peer in sorted(membership.get("peers", {}).items()):
            lines.append(
                "  peer %s: %s, %d consecutive failures, %d probes,"
                " -%d/+%d eject/readmit, %d stuck streams"
                % (url, "alive" if peer.get("alive") else "EJECTED",
                   peer.get("consecutive_failures", 0),
                   peer.get("probes", 0), peer.get("ejections", 0),
                   peer.get("readmissions", 0), peer.get("stuck_streams", 0))
            )
    return "\n".join(lines)
