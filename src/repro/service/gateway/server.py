"""GatewayServer — stdlib asyncio HTTP/1.1 front-end over AsyncArchiveServer.

One event loop (in a dedicated thread, so the server embeds in synchronous
programs and tests), one coroutine per connection, zero dependencies. Every
request rides `AsyncArchiveServer`'s bridge: the loop never blocks on
decompression, however cold the archive.

Concurrency/cancellation contract per connection:

  * each parsed request is handled as its own task while a **disconnect
    watcher** (a 1-byte read on the connection) runs alongside it. EOF from
    the watcher means the client is gone: the handler task is cancelled,
    which (a) cancels the in-flight bridged await — a queued bridge call
    never starts (`AsyncArchiveServer` books it under
    ``bridge_stats()['cancelled']``) — and (b) sweeps the handle's queued
    FairExecutor prefetch backlog via `ArchiveServer.cancel_queued`, where
    the executor books them under ``cancelled``. At quiescence the books
    always balance: ``submitted == done + cancelled + queued``.
  * large spans stream chunked, one ``stream_span`` read per await; a write
    failure mid-stream (reset) triggers the same cleanup path.

Admission (`TenantAdmission`) gates every ``/v1/archives`` request before
it can touch a bridge thread; over-budget tenants receive 429 +
``Retry-After``. ``/v1/metrics`` is exempt (operators must be able to look
at an overloaded gateway).

Source opening policy: ``open_roots`` (when given) jails ``POST
/v1/archives`` paths to those directory trees, and
``allow_remote_sources`` gates http(s) URLs — a gateway that fronts other
gateways (chaining) keeps it True.
"""

from __future__ import annotations

import asyncio
import json
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple
from urllib.parse import parse_qs

from ...core.remote import is_remote_url
from ...obs import hist as _obs_hist
from ...obs import trace as _obs_trace
from ...obs.prom import render_prometheus
from ...obs.sanitize import sanitize_snapshot
from ..async_server import AsyncArchiveServer
from ..index_store import _is_key
from ..server import ArchiveServer
from .admission import AdmissionDenied, TenantAdmission, Unauthorized

_REASONS = {
    200: "OK", 201: "Created", 204: "No Content", 206: "Partial Content",
    400: "Bad Request", 401: "Unauthorized", 403: "Forbidden",
    404: "Not Found", 405: "Method Not Allowed", 408: "Request Timeout",
    413: "Payload Too Large", 416: "Range Not Satisfiable",
    429: "Too Many Requests", 431: "Request Header Fields Too Large",
    500: "Internal Server Error", 503: "Service Unavailable",
}

MAX_HEADER_BYTES = 32 << 10
MAX_BODY_BYTES = 1 << 20


@dataclass
class _Request:
    method: str
    path: str
    headers: Dict[str, str]  # keys lower-cased
    body: bytes
    query: str = ""  # raw query string, no leading '?'


class _BadRequest(Exception):
    """Malformed wire input; answered with ``status`` then the connection
    closes (the stream position is no longer trustworthy)."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status


class _GatewayStats:
    """Front-door counters (read from any thread, bumped on the loop)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}
        self._tenant_bytes: Dict[str, int] = {}
        self._streams: Dict[int, Dict[str, Any]] = {}
        self._stream_seq = 0

    def bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + n

    def served(self, tenant: str, n: int) -> None:
        with self._lock:
            self._tenant_bytes[tenant] = self._tenant_bytes.get(tenant, 0) + n

    # Per-stream progress: a fleet health probe polling /v1/metrics can tell
    # a stuck peer (sent frozen across probes while a stream is registered)
    # from a merely slow one (sent advancing) — liveness data that a single
    # cumulative byte counter cannot provide once several streams multiplex.

    def stream_begin(self, handle: str, tenant: str, total: int) -> int:
        with self._lock:
            self._stream_seq += 1
            sid = self._stream_seq
            self._streams[sid] = {
                "handle": handle, "tenant": tenant, "sent": 0, "total": total
            }
            return sid

    def stream_progress(self, sid: int, n: int) -> None:
        with self._lock:
            st = self._streams.get(sid)
            if st is not None:
                st["sent"] += n

    def stream_end(self, sid: int) -> None:
        with self._lock:
            self._streams.pop(sid, None)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            out: Dict[str, Any] = dict(self._counters)
            out["bytes_served_per_tenant"] = dict(self._tenant_bytes)
            out["streams_in_progress"] = {
                str(sid): dict(st) for sid, st in self._streams.items()
            }
            return out


def _etag_matches(header_value: str, etag: str) -> bool:
    """``If-None-Match`` comparison: ``*``, or any listed entity-tag equal to
    ours. Weak-comparison (RFC 9110 §8.8.3.2): a ``W/`` prefix on either side
    is ignored — correct for 304 revalidation, which this header serves."""
    header_value = header_value.strip()
    if header_value == "*":
        return True
    ours = etag[2:] if etag.startswith("W/") else etag
    for candidate in header_value.split(","):
        candidate = candidate.strip()
        if candidate.startswith("W/"):
            candidate = candidate[2:]
        if candidate == ours:
            return True
    return False


def _parse_range(value: Optional[str], size: int):
    """``Range`` header -> (start, stop_exclusive) | None | "invalid" | "unsat".

    Handles the three RFC 9110 single-range shapes: ``bytes=a-b``,
    open-ended ``bytes=a-``, and suffix ``bytes=-n``. Multi-range requests
    are answered as invalid (full 200 body) — one span per request is the
    dialect `RemoteFileReader` speaks.
    """
    if not value:
        return None
    if size <= 0:
        # No byte of a zero-length body is addressable; RFC 9110 says 416
        # (a 206 here would emit the malformed 'bytes 0--1/0').
        return "unsat"
    value = value.strip()
    if not value.startswith("bytes="):
        return "invalid"
    spec = value[len("bytes="):].strip()
    if "," in spec or "-" not in spec:
        return "invalid"
    a_s, _, b_s = spec.partition("-")
    a_s, b_s = a_s.strip(), b_s.strip()
    try:
        if not a_s:  # suffix: last n bytes
            n = int(b_s)
            if n <= 0:
                return "unsat"
            return max(0, size - n), size
        start = int(a_s)
        if start >= size:
            return "unsat"
        if not b_s:
            return start, size
        end_incl = int(b_s)
        if end_incl < start:
            return "invalid"
        return start, min(end_incl + 1, size)
    except ValueError:
        return "invalid"


class GatewayServer:
    """HTTP wire front-end over an `ArchiveServer` (owned or wrapped).

    ``GatewayServer(cache_budget_bytes=...)`` builds and owns its backing
    server; ``GatewayServer(existing_server)`` fronts one the caller keeps
    responsibility for. ``start()`` (or ``with``) binds the socket; ``url``
    is then routable.
    """

    def __init__(
        self,
        server: Optional[ArchiveServer] = None,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        admission: Optional[TenantAdmission] = None,
        stream_span: int = 1 << 20,
        front_end_threads: int = 8,
        open_roots: Optional[Sequence[str]] = None,
        allow_remote_sources: bool = True,
        idle_timeout: float = 60.0,
        **server_kwargs: Any,
    ):
        if server is not None and server_kwargs:
            raise ValueError("pass either a server or ArchiveServer kwargs, not both")
        self._sync = server if server is not None else ArchiveServer(**server_kwargs)
        self._owns_sync = server is None
        self.admission = admission if admission is not None else TenantAdmission()
        self.stream_span = max(1, stream_span)
        self.front_end_threads = front_end_threads
        self.open_roots = (
            [os.path.realpath(os.fspath(r)) for r in open_roots]
            if open_roots is not None else None
        )
        self.allow_remote_sources = allow_remote_sources
        self.idle_timeout = idle_timeout
        auth_required = bool(self.admission.tokens) and self.admission.default_tenant is None
        if (
            host not in ("127.0.0.1", "localhost", "::1")
            and not auth_required
            and self.open_roots is None
        ):
            # Binding a routable interface with an unjailed, anonymous-
            # reachable POST /v1/archives would serve any readable file on
            # the machine to any network peer. Require an explicit opt-in:
            # an open_roots jail, or bearer tokens with default_tenant=None
            # (tokens alone don't help while a default tenant still admits
            # requests with no Authorization header at all).
            raise ValueError(
                "refusing to bind %r without an open_roots jail or required "
                "bearer auth (TenantAdmission(tokens=..., "
                "default_tenant=None)); anonymous clients could open any "
                "local path" % (host,)
            )
        self._host = host
        self._port = port
        self.stats = _GatewayStats()

        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._asrv: Optional[AsyncArchiveServer] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._conn_tasks: set = set()
        self._conn_writers: set = set()
        self._tenant_of: Dict[str, str] = {}  # handle -> opener's tenant
        self._started = False
        self._closed = False

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def start(self) -> "GatewayServer":
        if self._started:
            return self
        if self._closed:
            raise RuntimeError("gateway is closed")
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._loop.run_forever, name="gateway-loop", daemon=True
        )
        self._thread.start()
        try:
            fut = asyncio.run_coroutine_threadsafe(self._startup(), self._loop)
            self._port = fut.result(timeout=15)
        except BaseException:
            self._stop_loop()
            raise
        self._started = True
        return self

    async def _startup(self) -> int:
        self._asrv = AsyncArchiveServer(
            self._sync, front_end_threads=self.front_end_threads
        )
        self._server = await asyncio.start_server(
            self._handle_conn, self._host, self._port
        )
        return self._server.sockets[0].getsockname()[1]

    @property
    def url(self) -> str:
        """Base URL (no trailing slash), e.g. ``http://127.0.0.1:40213``."""
        if not self._started:
            raise RuntimeError("gateway not started")
        return "http://%s:%d" % (self._host, self._port)

    @property
    def server(self) -> ArchiveServer:
        """The backing synchronous server (telemetry, in-process co-access)."""
        return self._sync

    def bytes_url(self, handle: str) -> str:
        return "%s/v1/archives/%s/bytes" % (self.url, handle)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._started:
            try:
                asyncio.run_coroutine_threadsafe(
                    self._teardown(), self._loop
                ).result(timeout=15)
            finally:
                self._stop_loop()
        if self._owns_sync:
            self._sync.shutdown()

    async def _teardown(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        for task in list(self._conn_tasks):
            task.cancel()
        # Abort every remaining transport, for two reasons. (1) A cancelled
        # handler still closes its writer gracefully, and that close flushes
        # buffered response bytes — unbounded when the client stopped
        # reading (paused stream, full socket buffers). (2) On Python <3.12
        # wait_for() can swallow a cancellation that races the inner future
        # completing (bpo-42130) — a handler parked in _drain can survive
        # its cancel and keep streaming. Either way the response was already
        # cut mid-body, so buffered bytes carry no value; a dead transport
        # makes the survivor's next drain raise ConnectionResetError and the
        # gather below terminate.
        for w in list(self._conn_writers):
            transport = w.transport
            if transport is not None:
                transport.abort()
        if self._conn_tasks:
            await asyncio.gather(*self._conn_tasks, return_exceptions=True)
        if self._asrv is not None:
            await self._asrv.shutdown()  # bridge only: we own the sync server

    def _stop_loop(self) -> None:
        if self._loop is not None:
            self._loop.call_soon_threadsafe(self._loop.stop)
        if self._thread is not None:
            self._thread.join(timeout=10)
        if self._loop is not None and not self._thread.is_alive():
            # Release the loop's selector + self-pipe fds now, not at GC.
            self._loop.close()

    def __enter__(self) -> "GatewayServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------

    def metrics(self) -> Dict[str, Any]:
        """Backing-server fleet metrics + gateway/bridge/admission sections."""
        snap = self._sync.metrics()
        snap["gateway"] = self.stats.snapshot()
        if self._asrv is not None:
            snap["bridge"] = self._asrv.bridge_stats()
        snap["admission"] = self.admission.snapshot()
        return snap

    async def _serve_metrics(self, req: _Request, writer) -> None:
        """``GET /v1/metrics`` (JSON by default) / ``GET /metrics``
        (Prometheus text by default — scrapers hitting the conventional
        path never send a query string). Both honor an explicit
        ``?format=json|prometheus``.

        The snapshot crosses the wire boundary through `sanitize_snapshot`
        so whatever instrumented layers stuffed into their stats dicts
        (tuple keys, sets, NaNs, numpy scalars) serializes deterministically.
        """
        snap = sanitize_snapshot(self.metrics())
        default = "prometheus" if req.path.rstrip("/") == "/metrics" else "json"
        fmt = parse_qs(req.query).get("format", [default])[-1].lower()
        if fmt == "prometheus":
            body = render_prometheus(snap).encode()
            await self._send(
                writer, 200,
                {"Content-Type": "text/plain; version=0.0.4; charset=utf-8"},
                body,
            )
            return
        if fmt != "json":
            await self._send_error(
                writer, 400, "unknown metrics format %r (json|prometheus)" % fmt
            )
            return
        await self._send_json(writer, 200, snap)

    # ------------------------------------------------------------------
    # connection handling
    # ------------------------------------------------------------------

    async def _handle_conn(self, reader: asyncio.StreamReader, writer) -> None:
        task = asyncio.current_task()
        self._conn_tasks.add(task)
        self._conn_writers.add(writer)
        pushback = b""
        try:
            while True:
                req = await self._read_request(reader, pushback)
                if req is None:
                    break
                pushback = b""
                self.stats.bump("requests")
                handler = asyncio.ensure_future(self._dispatch(req, writer))
                # Disconnect watcher: clients do not pipeline (one request,
                # then they read the full response), so bytes arriving while
                # we serve are either EOF (client gone — cancel everything
                # end to end) or an eager next request (push the byte back).
                watcher = asyncio.ensure_future(reader.read(1))
                try:
                    await asyncio.wait(
                        {handler, watcher}, return_when=asyncio.FIRST_COMPLETED
                    )
                except asyncio.CancelledError:
                    # Gateway teardown cancelled this connection task:
                    # asyncio.wait does NOT cancel its children — reap them
                    # here or the handler outlives the loop (admission slot
                    # leaked, 'Task was destroyed but it is pending!').
                    handler.cancel()
                    watcher.cancel()
                    await asyncio.gather(handler, watcher, return_exceptions=True)
                    raise
                if not handler.done():
                    disconnected = True
                    try:
                        disconnected = watcher.result() == b""
                    except (ConnectionError, OSError):
                        pass
                    if disconnected:
                        self.stats.bump("disconnects_mid_request")
                        handler.cancel()
                        await asyncio.gather(handler, return_exceptions=True)
                        break
                    pushback = watcher.result()
                # Reap the watcher *before* awaiting the handler: a handler
                # raising a socket error must not leave an unretrieved task.
                if not watcher.done():
                    watcher.cancel()
                extra = (await asyncio.gather(watcher, return_exceptions=True))[0]
                keep = await handler
                if isinstance(extra, bytes):
                    if extra == b"":
                        keep = False  # client already sent FIN
                    else:
                        pushback = extra
                if not keep:
                    break
        except _BadRequest as exc:
            self.stats.bump("bad_requests")
            try:
                await self._send_error(writer, exc.status, str(exc))
            except (ConnectionError, OSError):
                pass
        except (ConnectionError, asyncio.IncompleteReadError, asyncio.TimeoutError, OSError):
            pass
        finally:
            self._conn_tasks.discard(task)
            self._conn_writers.discard(writer)
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _readline(self, reader: asyncio.StreamReader) -> bytes:
        """readline under the idle timeout, with StreamReader's 64 KiB line
        limit surfaced as a 431 instead of an unhandled ValueError."""
        try:
            return await asyncio.wait_for(reader.readline(), self.idle_timeout)
        except ValueError:
            # LimitOverrunError (a ValueError): line exceeds the stream limit.
            raise _BadRequest(431, "request line too long")

    async def _read_request(
        self, reader: asyncio.StreamReader, pushback: bytes
    ) -> Optional[_Request]:
        try:
            line = pushback + await self._readline(reader)
        except asyncio.TimeoutError:
            return None
        if not line.strip():
            if not line:
                return None  # clean EOF between requests
            try:
                # Tolerate a stray CRLF — but under the same idle timeout as
                # every other read, or a silent client pins this task forever.
                line = await self._readline(reader)
            except asyncio.TimeoutError:
                return None
            if not line.strip():
                return None
        try:
            method, path, _version = line.decode("latin-1").split(None, 2)
        except ValueError:
            raise _BadRequest(400, "malformed request line")
        headers: Dict[str, str] = {}
        total = len(line)
        while True:
            hline = await self._readline(reader)
            total += len(hline)
            if total > MAX_HEADER_BYTES:
                raise _BadRequest(431, "request headers too large")
            if hline in (b"\r\n", b"\n", b""):
                break
            name, _, value = hline.decode("latin-1").partition(":")
            headers[name.strip().lower()] = value.strip()
        body = b""
        try:
            length = int(headers.get("content-length", "0") or "0")
        except ValueError:
            raise _BadRequest(400, "malformed Content-Length")
        if length < 0:
            raise _BadRequest(400, "malformed Content-Length")
        if length:
            if length > MAX_BODY_BYTES:
                raise _BadRequest(413, "request body too large")
            body = await asyncio.wait_for(reader.readexactly(length), self.idle_timeout)
        path, _, query = path.partition("?")
        return _Request(method.upper(), path, headers, body, query)

    # ------------------------------------------------------------------
    # response plumbing
    # ------------------------------------------------------------------

    async def _drain(self, writer) -> None:
        """drain() under the idle timeout: a connected client that stopped
        *reading* (full TCP send buffer, slow-loris style) must count as
        gone — otherwise it pins its handler task and admission slot
        forever, since the EOF watcher never fires for a merely-stalled
        socket."""
        try:
            await asyncio.wait_for(writer.drain(), self.idle_timeout)
        except asyncio.TimeoutError:
            raise ConnectionResetError("client stopped reading the response")

    async def _send(
        self,
        writer,
        status: int,
        headers: Optional[Dict[str, str]] = None,
        body: bytes = b"",
        *,
        head_only: bool = False,
        content_length: Optional[int] = None,
    ) -> None:
        hdrs = {"Server": "rapidgzip-gateway"}
        hdrs.update(headers or {})
        if "Transfer-Encoding" not in hdrs:
            hdrs.setdefault(
                "Content-Length",
                str(len(body) if content_length is None else content_length),
            )
        out = ["HTTP/1.1 %d %s" % (status, _REASONS.get(status, "Unknown"))]
        out.extend("%s: %s" % kv for kv in hdrs.items())
        writer.write(("\r\n".join(out) + "\r\n\r\n").encode("latin-1"))
        if body and not head_only:
            writer.write(body)
        await self._drain(writer)

    async def _send_json(
        self, writer, status: int, payload: Any, headers: Optional[Dict[str, str]] = None
    ) -> None:
        body = json.dumps(payload, default=str).encode()
        hdrs = {"Content-Type": "application/json"}
        hdrs.update(headers or {})
        await self._send(writer, status, hdrs, body)

    async def _send_error(
        self, writer, status: int, message: str, headers: Optional[Dict[str, str]] = None
    ) -> None:
        await self._send_json(writer, status, {"error": message}, headers)

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------

    async def _dispatch(self, req: _Request, writer) -> bool:
        """Route one request; returns False when the connection must close.

        An incoming ``traceparent`` header adopts the caller's trace: the
        per-request span (and everything it fans out to — admission wait,
        bridge hop, executor run, remote range-GETs) parents under the
        remote caller's span, so a FleetClient read that fails over across
        two gateways stitches into one trace. The contextvar set here is
        task-local (one asyncio task per request), so concurrent requests
        never cross-contaminate.
        """
        parent = _obs_trace.parse_traceparent(
            req.headers.get(_obs_trace.TRACEPARENT_HEADER)
        )
        with _obs_trace.attach(parent), _obs_trace.timed(
            "gateway.request", {"method": req.method, "path": req.path}, parent=parent
        ):
            return await self._dispatch_routed(req, writer)

    async def _dispatch_routed(self, req: _Request, writer) -> bool:
        keep = req.headers.get("connection", "").lower() != "close"
        parts = [p for p in req.path.split("/") if p]
        try:
            # /metrics is the conventional Prometheus scrape path; /v1/metrics
            # the API-shaped one. Both are admission-exempt (operators must be
            # able to look at an overloaded gateway).
            if req.method == "GET" and parts in (["v1", "metrics"], ["metrics"]):
                await self._serve_metrics(req, writer)
                return keep
            if parts[:2] != ["v1", "archives"]:
                await self._send_error(writer, 404, "no such route: %s" % req.path)
                return keep
            tenant = self.admission.resolve(req.headers.get("authorization"))
            # Post-hoc span (not a live one): the admission wait is over by
            # the time anything could parent under it, and on the warm path
            # a completed-span record is about half the price of a Span.
            # `record_span` observes the histogram itself, so the disabled
            # branch keeps the always-on boundary timer without double
            # counting.
            t0_adm = time.perf_counter()
            await self.admission.acquire(tenant)
            wait_adm = time.perf_counter() - t0_adm
            if _obs_trace.tracing_enabled():
                _obs_trace.record_span(
                    "gateway.admission_wait", t0_adm, wait_adm, {"tenant": tenant}
                )
            else:
                _obs_hist.observe("gateway.admission_wait", wait_adm)
            try:
                return await self._dispatch_archives(req, writer, parts, tenant, keep)
            finally:
                self.admission.release(tenant)
        except Unauthorized as exc:
            self.stats.bump("unauthorized")
            await self._send_error(
                writer, 401, str(exc), {"WWW-Authenticate": "Bearer"}
            )
            return keep
        except AdmissionDenied as exc:
            self.stats.bump("rejected_429")
            # RFC 9110 delta-seconds is a non-negative *integer* — round the
            # configured delay up so strict clients honor it.
            await self._send_error(
                writer, 429, str(exc),
                {"Retry-After": "%d" % max(1, -(-exc.retry_after // 1))},
            )
            return keep
        except KeyError as exc:
            await self._send_error(writer, 404, str(exc))
            return keep
        except (json.JSONDecodeError, ValueError) as exc:
            await self._send_error(writer, 400, str(exc))
            return keep
        except FileNotFoundError as exc:
            await self._send_error(writer, 404, str(exc))
            return keep
        except PermissionError as exc:
            await self._send_error(writer, 403, str(exc))
            return keep
        except RuntimeError as exc:
            await self._send_error(writer, 503, str(exc))
            return False
        except ConnectionError:
            # Socket-level (this connection's writes): the loop owns cleanup.
            # Deliberately NOT OSError — backend I/O failures are OSError
            # subclasses and must become error *responses*, not silent drops.
            raise
        except asyncio.CancelledError:
            raise
        except Exception as exc:  # noqa: BLE001 - wire boundary
            self.stats.bump("errors")
            try:
                await self._send_error(writer, 500, "%s: %s" % (type(exc).__name__, exc))
            except (ConnectionError, OSError):
                pass
            return False

    async def _dispatch_archives(
        self, req: _Request, writer, parts, tenant: str, keep: bool
    ) -> bool:
        if len(parts) == 2 and req.method == "POST":
            return await self._open_archive(req, writer, tenant, keep)
        if len(parts) < 3:
            await self._send_error(writer, 404, "no such route: %s" % req.path)
            return keep
        handle = parts[2]
        owner = self._tenant_of.get(handle)
        if self.admission.tokens and owner is not None and owner != tenant:
            # Handles are tenant-scoped capabilities: another tenant's
            # handle id is indistinguishable from an unknown one. Only
            # enforced on authenticated gateways — without tokens every
            # request resolves to default_tenant, so an open-time tenant
            # override (benchmark accounting) must not lock the opener out
            # of its own handle.
            raise KeyError("unknown or closed handle %r" % handle)
        if len(parts) == 3 and req.method == "DELETE":
            await self._asrv.close(handle)
            self._tenant_of.pop(handle, None)
            await self._send(writer, 204)
            return keep
        if len(parts) == 4 and parts[3] == "bytes" and req.method in ("GET", "HEAD"):
            return await self._serve_bytes(req, writer, handle, tenant, keep)
        if len(parts) == 4 and parts[3] == "stat" and req.method == "GET":
            stat = await self._asrv.stat(handle)
            etag = '"%s"' % (stat.identity or handle)[:32]
            inm = req.headers.get("if-none-match")
            if inm is not None and _etag_matches(inm, etag):
                self.stats.bump("not_modified_304")
                await self._send(writer, 304, {"ETag": etag})
                return keep
            await self._send_json(writer, 200, stat.as_dict(), {"ETag": etag})
            return keep
        if len(parts) == 4 and parts[3] == "index" and req.method in ("GET", "HEAD"):
            return await self._serve_index(req, writer, handle, keep)
        await self._send_error(writer, 405, "%s not supported on %s" % (req.method, req.path))
        return keep

    async def _open_archive(self, req: _Request, writer, tenant: str, keep: bool) -> bool:
        spec = json.loads(req.body.decode() or "{}")
        source = spec.get("source")
        if not isinstance(source, str) or not source:
            raise ValueError("POST /v1/archives requires a JSON body with 'source'")
        if self.admission.tokens:
            if "tenant" in spec:
                raise ValueError("tenant is derived from the bearer token")
        elif isinstance(spec.get("tenant"), str):
            tenant = spec["tenant"]  # unauthenticated deployments (benchmarks)
        self._check_source(source)
        handle = await self._asrv.open(
            source, tenant=tenant, quantum=self.admission.quantum_for(tenant)
        )
        self._tenant_of[handle] = tenant
        self.stats.bump("opened")
        await self._send_json(
            writer, 201,
            {"handle": handle, "tenant": tenant,
             "bytes_url": "/v1/archives/%s/bytes" % handle},
        )
        return keep

    def _check_source(self, source: str) -> None:
        if is_remote_url(source):
            if not self.allow_remote_sources:
                raise PermissionError("remote sources are disabled on this gateway")
            return
        if self.open_roots is None:
            return
        real = os.path.realpath(source)
        for root in self.open_roots:
            if real == root or real.startswith(root.rstrip(os.sep) + os.sep):
                return
        raise PermissionError("source outside the gateway's open_roots jail")

    # ------------------------------------------------------------------
    # the index-exchange endpoint
    # ------------------------------------------------------------------

    async def _serve_index(self, req: _Request, writer, handle: str, keep: bool) -> bool:
        """``GET /v1/archives/{h}/index`` — the finalized seek-index blob.

        ``{h}`` is either a live handle or a 64-hex ``file_identity`` store
        key: peers fetching across nodes only know the content-addressed key
        (they never saw this node's handle ids). The response ETag is the
        bare key, which is how the fetching side validates it got the index
        of the exact file version it asked about. 404 until the index is
        finalized — a speculative (unconfirmed) index must not propagate.
        """
        if _is_key(handle):
            blob = self._sync.index_store.get_blob(handle)
            pair = (handle, blob) if blob is not None else None
        else:
            pair = self._sync.index_blob(handle)  # KeyError -> 404 upstream
        if pair is None:
            await self._send_error(
                writer, 404, "no finalized index for %r" % handle
            )
            return keep
        key, blob = pair
        etag = '"%s"' % key
        headers = {"ETag": etag, "Content-Type": "application/octet-stream"}
        inm = req.headers.get("if-none-match")
        if inm is not None and _etag_matches(inm, etag):
            self.stats.bump("not_modified_304")
            await self._send(writer, 304, {"ETag": etag})
            return keep
        self.stats.bump("index_served")
        await self._send(
            writer, 200, headers, blob,
            head_only=req.method == "HEAD", content_length=len(blob),
        )
        return keep

    # ------------------------------------------------------------------
    # the bytes endpoint
    # ------------------------------------------------------------------

    async def _serve_bytes(
        self, req: _Request, writer, handle: str, tenant: str, keep: bool
    ) -> bool:
        try:
            # Warm handles answer from the lock-free stat (no bridge
            # round-trip); only a cold/unfinalized handle pays the bridged
            # size() that drives the speculative first pass.
            stat = await self._asrv.stat(handle)
            size = stat.decompressed_size
            if size is None:
                size = await self._asrv.size(handle)
                stat = await self._asrv.stat(handle)  # identity known now
            etag = '"%s"' % (stat.identity or handle)[:32]
            base_headers = {"ETag": etag, "Accept-Ranges": "bytes"}

            inm = req.headers.get("if-none-match")
            if inm is not None and _etag_matches(inm, etag):
                # Conditional revalidation (e.g. FleetClient confirming a
                # failover target serves the same object version): no body,
                # no backend read.
                self.stats.bump("not_modified_304")
                await self._send(writer, 304, base_headers)
                return keep

            rng = _parse_range(req.headers.get("range"), size)
            if_range = req.headers.get("if-range")
            if if_range is not None and if_range != etag:
                rng = None  # validator moved on: serve the full current body
            if rng == "unsat":
                await self._send(
                    writer, 416,
                    {**base_headers, "Content-Range": "bytes */%d" % size},
                )
                return keep
            if rng is None or rng == "invalid":
                start, stop, status = 0, size, 200
            else:
                start, stop = rng
                status = 206
                base_headers["Content-Range"] = "bytes %d-%d/%d" % (
                    start, stop - 1, size
                )
            span = stop - start
            self.stats.bump("reads")
            if req.method == "HEAD":
                await self._send(
                    writer, status, base_headers, head_only=True,
                    content_length=span,
                )
                return keep
            # Byte-rate admission: charge the whole span up front, before
            # any header goes out — a refusal propagates to _dispatch's 429
            # + Retry-After path on a still-clean connection. HEAD and 304
            # answered above stream nothing and are never charged.
            self.admission.charge_bytes(tenant, span)
            if span <= self.stream_span:
                data = await self._asrv.read_range(handle, start, span)
                await self._send(writer, status, base_headers, data)
                self.stats.served(tenant, len(data))
                return keep
            # Large span: chunked streaming, one bounded read per await so a
            # disconnect cancels at most one stream_span of in-flight work.
            self.stats.bump("streams")
            base_headers["Transfer-Encoding"] = "chunked"
            await self._send(writer, status, base_headers)
            sid = self.stats.stream_begin(handle, tenant, span)
            try:
                off = start
                while off < stop:
                    data = await self._asrv.read_range(
                        handle, off, min(self.stream_span, stop - off)
                    )
                    if not data:
                        break  # stale size claim: end the stream short but valid
                    writer.write(b"%x\r\n" % len(data) + data + b"\r\n")
                    await self._drain(writer)
                    self.stats.served(tenant, len(data))
                    self.stats.stream_progress(sid, len(data))
                    off += len(data)
                writer.write(b"0\r\n\r\n")
                await self._drain(writer)
                return keep
            except (asyncio.CancelledError, ConnectionError):
                raise  # the function-level handlers below own these
            except Exception:  # noqa: BLE001 - wire framing boundary
                # Mid-stream failure *after* the response headers went out —
                # the handle DELETEd by another connection, or a backend I/O
                # error (OSError lands here too, on purpose): writing an
                # error response now would inject a status line into the
                # open chunked body and desync the framing. Abort: drop the
                # connection, never write.
                self.stats.bump("stream_aborts")
                return False
            finally:
                self.stats.stream_end(sid)
        except asyncio.CancelledError:
            # Client gone mid-request: the bridged await was already
            # cancelled by our own cancellation; also drop the speculation
            # the stream motivated (queued prefetches) if the handle is now
            # idle. Brief scheduler sweep — safe on the loop.
            self.stats.bump("cancelled_reads")
            try:
                self._sync.cancel_queued(handle)
            except Exception:  # noqa: BLE001 - handle may be gone already
                pass
            raise
        except ConnectionError:
            # A write on THIS socket failed (reset / stalled past the drain
            # timeout): same cleanup, then drop the connection — the
            # response is unfinishable. Backend I/O errors are NOT caught
            # here (plain OSError propagates to _dispatch's 404/403/500
            # mapping): a registered-but-missing file must answer 404, not
            # masquerade as a client disconnect.
            self.stats.bump("disconnects_mid_stream")
            try:
                self._sync.cancel_queued(handle)
            except Exception:  # noqa: BLE001
                pass
            return False
