"""Per-tenant admission control for the gateway front-end.

The bridge pool behind `AsyncArchiveServer` is a finite resource: without a
front-door limiter, one tenant issuing cold first-pass reads (each occupying
a bridge thread for the whole speculative pass) monopolizes it and every
other tenant's first byte waits behind the scan. `TenantAdmission` bounds
each tenant *before* any bridge thread is touched:

  * up to ``max_in_flight`` requests per tenant proceed concurrently;
  * up to ``max_queued`` more wait (FIFO within the tenant, asyncio-native
    — a waiting request costs a coroutine, never a thread);
  * anything beyond is refused immediately with `AdmissionDenied`, which
    the gateway answers ``429 Too Many Requests`` + ``Retry-After`` — the
    client-visible backpressure signal (`core.remote.RemoteFileReader`
    already treats 429 as retryable with exponential backoff, so chained
    gateways degrade gracefully).

Identity is bearer-token based: ``Authorization: Bearer <token>`` maps to a
tenant via the ``tokens`` table. Unknown tokens are rejected; requests with
no token land on ``default_tenant`` (set it to None to require auth). The
tenant id flows through to every backing budget — FairExecutor DRR queues,
CachePool shares, and the optional per-tenant ``quanta`` factors the
gateway applies at open time (paying tenants get a larger quantum).

Concurrency slots bound *threads*; they do not bound *bytes* — a tenant
streaming one enormous body per slot saturates the egress path while
staying under every count. ``charge_bytes`` closes that hole with a
per-tenant token bucket over bytes streamed (``byte_rate`` bytes/second,
``byte_burst`` bucket depth): the gateway charges the whole response span
up front, before any header goes out. The bucket allows overdraft — a
tenant with a positive balance may start a response larger than the
remaining tokens (otherwise no span above the burst could ever be served)
— and then answers 429 until the deficit refills, so the long-run average
never exceeds the configured rate.

Thread-model: ``resolve`` is pure; ``acquire``/``release``/``charge_bytes``
run only on the gateway's event loop (single thread, so counters need no
lock — release is deliberately synchronous and hands its slot directly to
the eldest live waiter, which makes it safe to call from a ``finally``
while the handler task is being cancelled); ``snapshot`` may be called from
any thread (int reads are telemetry snapshots, not barriers).
"""

from __future__ import annotations

import asyncio
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, Optional, Tuple

from ...obs import hist as _obs_hist


class AdmissionDenied(Exception):
    """Tenant over in-flight + queue-depth budget; retry after a delay."""

    def __init__(self, tenant: str, retry_after: float, reason: str):
        super().__init__("tenant %r %s" % (tenant, reason))
        self.tenant = tenant
        self.retry_after = retry_after


class Unauthorized(Exception):
    """Missing or unknown bearer token (gateway answers 401)."""


@dataclass
class TenantLimit:
    max_in_flight: int = 2
    max_queued: int = 4
    #: bytes/second this tenant may stream (None inherits the admission
    #: default; the default's None means unlimited).
    byte_rate: Optional[float] = None
    #: token-bucket depth in bytes (None: 2 seconds of byte_rate).
    byte_burst: Optional[int] = None


class _Gate:
    __slots__ = (
        "in_flight", "waiting", "waiters", "admitted", "rejected", "waited",
        "byte_tokens", "byte_refilled_at", "bytes_charged", "bytes_rejected",
    )

    def __init__(self) -> None:
        self.in_flight = 0
        self.waiting = 0  # live waiters (maintained by acquire's finally)
        self.waiters: Deque[asyncio.Future] = deque()
        self.admitted = 0
        self.rejected = 0
        self.waited = 0  # admissions that had to queue first
        # Byte token bucket: lazily primed to the full burst on first
        # charge (byte_refilled_at None = never charged).
        self.byte_tokens = 0.0
        self.byte_refilled_at: Optional[float] = None
        self.bytes_charged = 0
        self.bytes_rejected = 0


class TenantAdmission:
    def __init__(
        self,
        *,
        tokens: Optional[Dict[str, str]] = None,
        default_tenant: Optional[str] = "public",
        max_in_flight: int = 2,
        max_queued: int = 4,
        retry_after: float = 0.5,
        byte_rate: Optional[float] = None,
        byte_burst: Optional[int] = None,
        limits: Optional[Dict[str, TenantLimit]] = None,
        quanta: Optional[Dict[str, float]] = None,
    ):
        if max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        if max_queued < 0:
            raise ValueError("max_queued must be >= 0")
        if byte_rate is not None and byte_rate <= 0:
            raise ValueError("byte_rate must be positive (None = unlimited)")
        self.tokens = dict(tokens or {})
        self.default_tenant = default_tenant
        self.default_limit = TenantLimit(max_in_flight, max_queued)
        self.retry_after = retry_after
        self.byte_rate = byte_rate
        self.byte_burst = byte_burst
        self.limits = dict(limits or {})
        #: per-tenant weighted-DRR quantum factors, applied by the gateway
        #: via ``ArchiveServer.open(..., quantum=...)`` at open time.
        self.quanta = dict(quanta or {})
        # Guards _gates insertion and snapshot(): gates are created on the
        # loop but snapshots are read from arbitrary telemetry threads.
        self._gates_lock = threading.Lock()
        self._gates: Dict[str, _Gate] = {}

    # -- identity -----------------------------------------------------------

    def resolve(self, authorization: Optional[str]) -> str:
        """Tenant id for an ``Authorization`` header value (or None)."""
        if authorization:
            scheme, _, token = authorization.strip().partition(" ")
            if scheme.lower() != "bearer" or not token.strip():
                raise Unauthorized("unsupported Authorization scheme")
            tenant = self.tokens.get(token.strip())
            if tenant is None:
                raise Unauthorized("unknown bearer token")
            return tenant
        if self.default_tenant is None:
            raise Unauthorized("missing bearer token")
        return self.default_tenant

    def quantum_for(self, tenant: str) -> Optional[float]:
        return self.quanta.get(tenant)

    # -- gating -------------------------------------------------------------

    def _gate(self, tenant: str) -> _Gate:
        gate = self._gates.get(tenant)
        if gate is None:
            with self._gates_lock:
                gate = self._gates.setdefault(tenant, _Gate())
        return gate

    def _limit(self, tenant: str) -> Tuple[int, int]:
        lim = self.limits.get(tenant, self.default_limit)
        return lim.max_in_flight, lim.max_queued

    def _byte_limit(self, tenant: str) -> Tuple[Optional[float], float]:
        lim = self.limits.get(tenant)
        rate = lim.byte_rate if lim is not None and lim.byte_rate is not None else self.byte_rate
        if rate is None:
            return None, 0.0
        burst = lim.byte_burst if lim is not None and lim.byte_burst is not None else self.byte_burst
        if burst is None:
            burst = 2.0 * rate  # two seconds of line rate
        return rate, max(float(burst), 1.0)

    async def acquire(self, tenant: str) -> None:
        """Admit one request for ``tenant``: immediate when under the
        in-flight budget, bounded FIFO wait when under the queue budget,
        `AdmissionDenied` beyond that."""
        gate = self._gate(tenant)
        max_in_flight, max_queued = self._limit(tenant)
        if gate.in_flight < max_in_flight and gate.waiting == 0:
            gate.in_flight += 1
            gate.admitted += 1
            return
        if gate.waiting >= max_queued:
            gate.rejected += 1
            raise AdmissionDenied(
                tenant,
                self.retry_after,
                "over budget (%d in flight, %d queued)"
                % (gate.in_flight, gate.waiting),
            )
        fut: asyncio.Future = asyncio.get_running_loop().create_future()
        gate.waiters.append(fut)
        gate.waiting += 1
        t_wait = time.perf_counter()
        try:
            # release() resolves the future *with the slot already
            # transferred* (in_flight stays constant across the handoff), so
            # a resolved wait needs no re-check and a cancelled wait never
            # holds a slot.
            await fut
        except asyncio.CancelledError:
            if fut.done() and not fut.cancelled():
                # Lost race: release() handed us the slot, then our task was
                # cancelled before resuming — the handler's finally will
                # never run for us, so give the slot back here or the
                # tenant's capacity shrinks permanently.
                self.release(tenant)
            raise
        finally:
            gate.waiting -= 1
            # Only queued admissions land here; the fast path is untimed
            # (the gateway's gateway.admission_wait timer covers both).
            _obs_hist.observe("admission.queue_wait", time.perf_counter() - t_wait)

    def release(self, tenant: str) -> None:
        """Return one slot: hand it to the eldest live waiter, else free it.

        Synchronous on purpose — handler ``finally`` blocks call this while
        their task is being cancelled, where a fresh ``await`` could be
        interrupted and leak the slot forever.
        """
        gate = self._gate(tenant)
        while gate.waiters:
            fut = gate.waiters.popleft()
            if not fut.done():
                gate.admitted += 1
                gate.waited += 1
                fut.set_result(None)  # slot transferred, in_flight unchanged
                return
        gate.in_flight = max(0, gate.in_flight - 1)

    def charge_bytes(self, tenant: str, nbytes: int, *, now: Optional[float] = None) -> None:
        """Debit ``nbytes`` from the tenant's byte bucket or refuse the
        response.

        Called by the gateway with the full response span *before* any
        header is written (so a refusal can still become a clean 429).
        Overdraft semantics: a tenant whose balance is positive is always
        admitted — even for a span larger than the balance or the burst —
        and the balance goes negative; further charges are refused with
        `AdmissionDenied` carrying the exact refill delay until the balance
        is positive again. Loop-thread-only, like ``acquire``. ``now`` is a
        monotonic-clock override for deterministic tests.
        """
        rate, burst = self._byte_limit(tenant)
        gate = self._gate(tenant)
        if rate is None:
            gate.bytes_charged += max(0, nbytes)
            return
        if now is None:
            now = time.monotonic()
        if gate.byte_refilled_at is None:
            gate.byte_tokens = burst  # first charge: full bucket
        else:
            elapsed = max(0.0, now - gate.byte_refilled_at)
            gate.byte_tokens = min(burst, gate.byte_tokens + elapsed * rate)
        gate.byte_refilled_at = now
        if gate.byte_tokens <= 0.0:
            gate.bytes_rejected += max(0, nbytes)
            retry = max(-gate.byte_tokens / rate, 0.001)
            raise AdmissionDenied(
                tenant,
                retry,
                "over byte rate (%.0f B/s, %.0f B in deficit)"
                % (rate, -gate.byte_tokens),
            )
        gate.byte_tokens -= max(0, nbytes)
        gate.bytes_charged += max(0, nbytes)

    # -- telemetry ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        # Copy the registry under the lock, then read plain ints only —
        # never iterate a gate's waiters deque, which the loop thread
        # mutates concurrently.
        with self._gates_lock:
            gates = dict(self._gates)
        return {
            tenant: {
                "in_flight": g.in_flight,
                "waiting": g.waiting,
                "admitted": g.admitted,
                "rejected": g.rejected,
                "waited": g.waited,
                "bytes_charged": g.bytes_charged,
                "bytes_rejected": g.bytes_rejected,
                "byte_tokens": round(g.byte_tokens, 1),
            }
            for tenant, g in gates.items()
        }
