"""Network gateway: the archive service behind an HTTP/1.1 wire protocol.

PRs 1-4 built a multi-tenant archive service that was only reachable
in-process. This package is the wire front-end the ROADMAP's north star
(heavy traffic from millions of users) requires: a dependency-free
HTTP/1.1 server (stdlib ``asyncio`` sockets — hermetic, loopback-testable
like ``tests/_range_server.py``) over `AsyncArchiveServer`, plus a
`FileReader`-shaped client, so every byte of the paper's architecture
(speculative parallel decompression, shared cache budget, fair scheduling,
persistent seek indexes) is now one ``Range:`` header away from any HTTP
client.

Wire protocol (all request/response bodies JSON unless noted):

    ==========  =================================  =============================
    verb        path                               semantics
    ==========  =================================  =============================
    POST        /v1/archives                       open ``{"source": path|url}``
                                                   -> 201 ``{"handle", "tenant"}``
    HEAD        /v1/archives/{h}/bytes             decompressed stat: 200 with
                                                   ``Content-Length`` (size),
                                                   ``ETag`` (from
                                                   IndexStore.file_identity),
                                                   ``Accept-Ranges: bytes``
    GET         /v1/archives/{h}/bytes             decompressed bytes.
                                                   ``Range: bytes=a-b`` (also
                                                   ``a-`` and suffix ``-n``)
                                                   -> 206 + ``Content-Range``;
                                                   no Range -> 200 full stream;
                                                   start past EOF -> 416 with
                                                   ``Content-Range: bytes */N``.
                                                   Spans larger than the
                                                   gateway's ``stream_span``
                                                   stream chunked
                                                   (``Transfer-Encoding``).
    GET         /v1/archives/{h}/stat              JSON `ArchiveStat` (+ETag;
                                                   ``If-None-Match`` -> 304)
    GET         /v1/archives/{h}/index             finalized seek-index blob
                                                   (binary GzipIndex). ``{h}``
                                                   is a handle **or** a 64-hex
                                                   ``file_identity`` key;
                                                   ``ETag`` is the bare key.
                                                   404 until finalized.
    DELETE      /v1/archives/{h}                   close -> 204
    GET         /v1/metrics                        fleet metrics + gateway/
                                                   bridge/admission sections
                                                   (incl. per-handle stream
                                                   progress)
    ==========  =================================  =============================

    ``GET``/``HEAD`` on ``/bytes`` and ``/stat`` honor ``If-None-Match``
    (ETag revalidation -> ``304 Not Modified``), which lets a fleet client
    re-validate a failover target for the cost of headers, not a body.

The ``/bytes`` endpoint deliberately speaks the exact single-range dialect
`core.remote.RemoteFileReader` consumes (206/416, ``Content-Range``,
``ETag`` + ``If-Range``), so gateways *chain*: a second-tier gateway can
``open()`` a first-tier gateway's bytes URL like any other remote object —
tiered deployments for free, and one contract suite covers both hops.

Three front-end properties the in-process API could not offer:

  * **Cancellation propagation** — a client disconnecting mid-stream
    cancels the handler's in-flight `AsyncArchiveServer` awaits (bridged
    futures are cancelled before they can occupy a bridge thread) and
    sweeps the handle's queued FairExecutor prefetch backlog
    (`ArchiveServer.cancel_queued`); the executor books them under
    ``cancelled`` so ``submitted == done + cancelled + queued`` always
    balances — no orphaned decompression work.
  * **Per-tenant admission control** — `TenantAdmission` maps bearer
    tokens to tenants and bounds each tenant's in-flight requests and
    wait-queue depth; overflow is answered ``429 Too Many Requests`` with
    ``Retry-After``, so one cold-scanning tenant can no longer occupy
    every bridge thread.
  * **Service classes** — admission carries per-tenant weighted-DRR
    quantum factors (`FairExecutor.set_tenant_quantum`) and cache-share
    weights into the backing server.

Quickstart (see ``examples/serve_gateway.py`` for the full tour)::

    from repro.service.gateway import GatewayServer, GatewayClient

    with GatewayServer(cache_budget_bytes=64 << 20, max_workers=4) as gw:
        client = GatewayClient(gw.url, source="/data/corpus-00.json.gz")
        page = client.pread(10 << 20, 4096)     # FileReader contract
        for chunk in client.stream():            # chunked full read
            consume(chunk)
        client.close()
"""

from .admission import AdmissionDenied, TenantAdmission
from .client import GatewayClient, GatewayError
from .server import GatewayServer

__all__ = [
    "AdmissionDenied",
    "GatewayClient",
    "GatewayError",
    "GatewayServer",
    "TenantAdmission",
]
