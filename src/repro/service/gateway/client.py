"""GatewayClient — the FileReader contract spoken over the gateway wire.

Management verbs (open/stat/close/metrics) are thin one-shot JSON calls;
the data path *is* `core.remote.RemoteFileReader` pointed at the handle's
``/bytes`` endpoint. That is deliberate, not lazy: the bytes endpoint
speaks exactly the single-range dialect the remote backend already
implements (206/416, ``Content-Range``, ``ETag`` + ``If-Range``, bounded
retry with backoff on 429/5xx), so the gateway inherits a battle-tested
client and — the chaining dividend — anything that can read a remote
object can read a gateway: ``ArchiveServer.open(gw.bytes_url(h))`` makes a
second-tier archive service front a first-tier gateway with zero new code.

What the wrapper adds on top of the inner remote reader:

  * ``open`` semantics: constructing with ``source=`` POSTs the archive
    open and owns the handle (``close()`` DELETEs it); constructing with
    ``handle=`` attaches to an existing handle and leaves its lifetime to
    the owner.
  * ``stream()``: the chunked full-body read (one ``GET`` without
    ``Range``), yielded incrementally — the acceptance path for "bytes
    identical over a chunked stream", and the easiest way to *abandon* a
    stream mid-flight (closing the generator drops the connection, which
    is precisely the cancellation signal the gateway tests exercise).
    ``stream(offset=n)`` resumes mid-body via ``Range: bytes=n-`` with
    ETag continuity checking — fleet failover's exact-resume primitive.
  * an admission-aware retry budget: management-verb 429s are retried,
    paced by the server's ``Retry-After``, within ``retry_budget`` seconds
    of total wait instead of failing immediately.
  * ``revalidate(etag)``: conditional GET (``If-None-Match`` + 1-byte
    Range) — object-version equality for the price of headers.
  * bearer-token auth on every request (``token=``).

`GatewayClient` is a `FileReader`: ``pread``/``size``/``identity``/``view``
satisfy the same contract suite as the bytes/mmap/python/remote backends
(tests/test_filereader_contract.py) — over a live socket.
"""

from __future__ import annotations

import http.client
import json
import time
import urllib.parse
from typing import Any, Dict, Iterator, Optional

from ...core.errors import RemoteFileChangedError, RemoteIOError
from ...core.filereader import FileReader, check_pread_args
from ...core.remote import RemoteFileReader, parse_retry_after
from ...obs import trace as _obs_trace


class GatewayError(RemoteIOError):
    """A gateway management verb failed (non-2xx status)."""

    def __init__(self, status: int, message: str, retry_after: Optional[float] = None):
        super().__init__("HTTP %d: %s" % (status, message))
        self.status = status
        #: Parsed ``Retry-After`` seconds on a 429/503, else None.
        self.retry_after = retry_after


class GatewayClient(FileReader):
    """Positioned reads of an archive's *decompressed* bytes via a gateway.

    ``GatewayClient(url, source="/data/x.gz")`` opens (and owns) a handle;
    ``GatewayClient(url, handle="f3")`` attaches to one opened elsewhere.
    Extra keyword arguments tune the inner `RemoteFileReader` (block_size,
    cache_blocks, retry/backoff, timeout).
    """

    def __init__(
        self,
        base_url: str,
        source: Optional[str] = None,
        *,
        handle: Optional[str] = None,
        token: Optional[str] = None,
        tenant: Optional[str] = None,
        timeout: float = 30.0,
        retry_budget: float = 8.0,
        **remote_options: Any,
    ):
        if (source is None) == (handle is None):
            raise ValueError("pass exactly one of source= or handle=")
        if retry_budget < 0:
            raise ValueError("retry_budget must be >= 0")
        split = urllib.parse.urlsplit(base_url)
        if split.scheme not in ("http", "https") or not split.netloc:
            raise ValueError("not a gateway base URL: %r" % (base_url,))
        self._base = base_url.rstrip("/")
        self._scheme = split.scheme
        self._netloc = split.netloc
        self._timeout = timeout
        self._retry_budget = retry_budget
        #: Management-verb 429s absorbed by the retry budget (telemetry).
        self.retries_429 = 0
        self._headers: Dict[str, str] = {}
        if token is not None:
            self._headers["Authorization"] = "Bearer %s" % token
        self._closed = False
        self._remote: Optional[RemoteFileReader] = None

        if source is not None:
            spec: Dict[str, Any] = {"source": source}
            if tenant is not None:
                spec["tenant"] = tenant
            status, payload = self._request("POST", "/v1/archives", spec)
            handle = payload["handle"]
            self.tenant = payload.get("tenant")
            self._owns_handle = True
        else:
            self.tenant = tenant
            self._owns_handle = False
        self.handle = handle
        self._bytes_path = "/v1/archives/%s/bytes" % handle
        try:
            # The inner reader's open-time HEAD captures decompressed size +
            # ETag; on a cold archive that HEAD drives the speculative first
            # pass server-side (the price of knowing Content-Length).
            self._remote = RemoteFileReader(
                self._base + self._bytes_path,
                headers=dict(self._headers),
                timeout=timeout,
                **remote_options,
            )
        except BaseException:
            self.close()
            raise

    # -- FileReader contract -------------------------------------------------

    def size(self) -> int:
        return self._remote.size()

    def pread(self, offset: int, size: int) -> bytes:
        check_pread_args(offset, size)
        if self._closed:
            raise ValueError("pread on closed GatewayClient")
        return self._remote.pread(offset, size)

    def identity(self) -> Optional[str]:
        return self._remote.identity()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            if self._remote is not None:
                self._remote.close()
        finally:
            if self._owns_handle:
                # A 429 here means our tenant is momentarily at its
                # admission limit — _request's retry budget absorbs it
                # rather than silently leaking the server-side handle
                # (reader + pool-charged cache bytes stay alive until
                # gateway shutdown otherwise).
                try:
                    self._request("DELETE", "/v1/archives/%s" % self.handle)
                except GatewayError:
                    pass  # already closed / gone / budget spent
                except (OSError, http.client.HTTPException):
                    pass  # gateway already gone

    # -- gateway extras ------------------------------------------------------

    @property
    def etag(self) -> Optional[str]:
        return self._remote.etag

    @property
    def remote_stats(self):
        """Inner RemoteFileReader network counters (requests/retries/bytes)."""
        return self._remote.stats

    def revalidate(self, etag: str) -> bool:
        """True iff the gateway's current entity for this handle matches
        ``etag``.

        Conditional GET (``If-None-Match`` + a 1-byte ``Range``): a match
        answers 304 with no body, a mismatch at most one body byte — never
        a full-body refetch. Fleet failover uses this to confirm a new peer
        serves the same object version before resuming mid-stream.
        """
        headers = dict(self._headers)
        headers["If-None-Match"] = etag
        headers["Range"] = "bytes=0-0"
        conn = self._connect()
        try:
            conn.request("GET", self._bytes_path, headers=headers)
            resp = conn.getresponse()
            resp.read()
            if resp.status == 304:
                return True
            if resp.status in (200, 206):
                current = resp.getheader("ETag")
                return current is not None and current == etag
            raise GatewayError(
                resp.status, "revalidation failed",
                parse_retry_after(resp.getheader("Retry-After")),
            )
        finally:
            conn.close()

    def fetch_index(self) -> Optional[bytes]:
        """The handle's finalized seek-index blob, or None (404: not yet
        finalized). The exchange counterpart to ``GET .../index``."""
        conn = self._connect()
        try:
            conn.request(
                "GET", "/v1/archives/%s/index" % self.handle,
                headers=dict(self._headers),
            )
            resp = conn.getresponse()
            raw = resp.read()
            if resp.status == 200:
                return raw
            if resp.status == 404:
                return None
            raise GatewayError(
                resp.status, raw.decode(errors="replace"),
                parse_retry_after(resp.getheader("Retry-After")),
            )
        finally:
            conn.close()

    def stream(self, *, read_size: int = 64 << 10, offset: int = 0) -> Iterator[bytes]:
        """Yield the decompressed body incrementally from ``offset`` on.

        ``offset=0`` is the chunked full-body 200; ``offset>0`` resumes via
        ``Range: bytes=offset-`` (206) — the exact-resume primitive fleet
        failover relies on. A resumed response whose ETag no longer matches
        the open-time one raises `RemoteFileChangedError` instead of
        splicing bytes of two object versions into one stream.

        Uses a dedicated connection so an abandoned generator (``close()``
        or ``break``) drops the socket — which the gateway observes as a
        mid-stream disconnect and turns into end-to-end cancellation.
        """
        if self._closed:
            raise ValueError("stream on closed GatewayClient")
        if offset < 0:
            raise ValueError("offset must be >= 0")
        headers = dict(self._headers)
        expect = 200
        if offset:
            headers["Range"] = "bytes=%d-" % offset
            expect = 206
        conn = self._connect()
        try:
            conn.request("GET", self._bytes_path, headers=headers)
            resp = conn.getresponse()
            if offset and resp.status == 416:
                resp.read()
                return  # resuming exactly at EOF: nothing left to yield
            if resp.status != expect:
                raise GatewayError(
                    resp.status, resp.read().decode(errors="replace"),
                    parse_retry_after(resp.getheader("Retry-After")),
                )
            if offset:
                ours = self._remote.etag if self._remote is not None else None
                theirs = resp.getheader("ETag")
                if ours is not None and theirs is not None and ours != theirs:
                    raise RemoteFileChangedError(
                        "%s: ETag changed from %s to %s while resuming at %d"
                        % (self._bytes_path, ours, theirs, offset)
                    )
            while True:
                data = resp.read(read_size)
                if not data:
                    return
                yield data
        finally:
            conn.close()

    def stat(self) -> Dict[str, Any]:
        status, payload = self._request(
            "GET", "/v1/archives/%s/stat" % self.handle
        )
        return payload

    def metrics(self) -> Dict[str, Any]:
        status, payload = self._request("GET", "/v1/metrics")
        return payload

    # -- plumbing ------------------------------------------------------------

    def _connect(self) -> http.client.HTTPConnection:
        cls = (
            http.client.HTTPSConnection
            if self._scheme == "https"
            else http.client.HTTPConnection
        )
        return cls(self._netloc, timeout=self._timeout)

    def _request(self, method: str, path: str, payload: Optional[Dict] = None):
        """Management call; returns (status, decoded JSON body).

        Admission-aware: a 429 is retried within ``retry_budget`` seconds of
        total wait, paced by the server's ``Retry-After`` when present (the
        admission controller knows its own queue better than our backoff
        guess). The budget bounds *wall-clock spent waiting*, not attempt
        count — under fleet failover every surviving peer absorbs the dead
        peer's clients at once, so immediate-fail on the resulting 429 burst
        would turn one node loss into a client-visible error storm.
        """
        budget = self._retry_budget
        attempt = 0
        while True:
            try:
                return self._request_once(method, path, payload)
            except GatewayError as exc:
                if exc.status != 429:
                    raise
                delay = exc.retry_after
                if delay is None or delay <= 0:
                    delay = min(1.0, 0.1 * (2 ** attempt))
                if delay > budget:
                    raise  # budget exhausted: surface the 429 to the caller
                budget -= delay
                attempt += 1
                self.retries_429 += 1
                time.sleep(delay)

    def _request_once(self, method: str, path: str, payload: Optional[Dict] = None):
        body = json.dumps(payload).encode() if payload is not None else None
        headers = dict(self._headers)
        if body is not None:
            headers["Content-Type"] = "application/json"
        # Management verbs join the caller's trace the same way the data path
        # does (RemoteFileReader injects this inside core.remote).
        tp = _obs_trace.current_traceparent()
        if tp is not None:
            headers.setdefault(_obs_trace.TRACEPARENT_HEADER, tp)
        conn = self._connect()
        try:
            conn.request(method, path, body=body, headers=headers)
            resp = conn.getresponse()
            raw = resp.read()
            if resp.status >= 400:
                try:
                    message = json.loads(raw.decode() or "{}").get("error", "")
                except (ValueError, UnicodeDecodeError):
                    message = raw.decode(errors="replace")
                raise GatewayError(
                    resp.status, message,
                    parse_retry_after(resp.getheader("Retry-After")),
                )
            decoded = json.loads(raw.decode()) if raw else None
            return resp.status, decoded
        finally:
            conn.close()
