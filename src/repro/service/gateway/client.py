"""GatewayClient — the FileReader contract spoken over the gateway wire.

Management verbs (open/stat/close/metrics) are thin one-shot JSON calls;
the data path *is* `core.remote.RemoteFileReader` pointed at the handle's
``/bytes`` endpoint. That is deliberate, not lazy: the bytes endpoint
speaks exactly the single-range dialect the remote backend already
implements (206/416, ``Content-Range``, ``ETag`` + ``If-Range``, bounded
retry with backoff on 429/5xx), so the gateway inherits a battle-tested
client and — the chaining dividend — anything that can read a remote
object can read a gateway: ``ArchiveServer.open(gw.bytes_url(h))`` makes a
second-tier archive service front a first-tier gateway with zero new code.

What the wrapper adds on top of the inner remote reader:

  * ``open`` semantics: constructing with ``source=`` POSTs the archive
    open and owns the handle (``close()`` DELETEs it); constructing with
    ``handle=`` attaches to an existing handle and leaves its lifetime to
    the owner.
  * ``stream()``: the chunked full-body read (one ``GET`` without
    ``Range``), yielded incrementally — the acceptance path for "bytes
    identical over a chunked stream", and the easiest way to *abandon* a
    stream mid-flight (closing the generator drops the connection, which
    is precisely the cancellation signal the gateway tests exercise).
  * bearer-token auth on every request (``token=``).

`GatewayClient` is a `FileReader`: ``pread``/``size``/``identity``/``view``
satisfy the same contract suite as the bytes/mmap/python/remote backends
(tests/test_filereader_contract.py) — over a live socket.
"""

from __future__ import annotations

import http.client
import json
import time
import urllib.parse
from typing import Any, Dict, Iterator, Optional

from ...core.errors import RemoteIOError
from ...core.filereader import FileReader, check_pread_args
from ...core.remote import RemoteFileReader


class GatewayError(RemoteIOError):
    """A gateway management verb failed (non-2xx status)."""

    def __init__(self, status: int, message: str):
        super().__init__("HTTP %d: %s" % (status, message))
        self.status = status


class GatewayClient(FileReader):
    """Positioned reads of an archive's *decompressed* bytes via a gateway.

    ``GatewayClient(url, source="/data/x.gz")`` opens (and owns) a handle;
    ``GatewayClient(url, handle="f3")`` attaches to one opened elsewhere.
    Extra keyword arguments tune the inner `RemoteFileReader` (block_size,
    cache_blocks, retry/backoff, timeout).
    """

    def __init__(
        self,
        base_url: str,
        source: Optional[str] = None,
        *,
        handle: Optional[str] = None,
        token: Optional[str] = None,
        tenant: Optional[str] = None,
        timeout: float = 30.0,
        **remote_options: Any,
    ):
        if (source is None) == (handle is None):
            raise ValueError("pass exactly one of source= or handle=")
        split = urllib.parse.urlsplit(base_url)
        if split.scheme not in ("http", "https") or not split.netloc:
            raise ValueError("not a gateway base URL: %r" % (base_url,))
        self._base = base_url.rstrip("/")
        self._scheme = split.scheme
        self._netloc = split.netloc
        self._timeout = timeout
        self._headers: Dict[str, str] = {}
        if token is not None:
            self._headers["Authorization"] = "Bearer %s" % token
        self._closed = False
        self._remote: Optional[RemoteFileReader] = None

        if source is not None:
            spec: Dict[str, Any] = {"source": source}
            if tenant is not None:
                spec["tenant"] = tenant
            status, payload = self._request("POST", "/v1/archives", spec)
            handle = payload["handle"]
            self.tenant = payload.get("tenant")
            self._owns_handle = True
        else:
            self.tenant = tenant
            self._owns_handle = False
        self.handle = handle
        self._bytes_path = "/v1/archives/%s/bytes" % handle
        try:
            # The inner reader's open-time HEAD captures decompressed size +
            # ETag; on a cold archive that HEAD drives the speculative first
            # pass server-side (the price of knowing Content-Length).
            self._remote = RemoteFileReader(
                self._base + self._bytes_path,
                headers=dict(self._headers),
                timeout=timeout,
                **remote_options,
            )
        except BaseException:
            self.close()
            raise

    # -- FileReader contract -------------------------------------------------

    def size(self) -> int:
        return self._remote.size()

    def pread(self, offset: int, size: int) -> bytes:
        check_pread_args(offset, size)
        if self._closed:
            raise ValueError("pread on closed GatewayClient")
        return self._remote.pread(offset, size)

    def identity(self) -> Optional[str]:
        return self._remote.identity()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            if self._remote is not None:
                self._remote.close()
        finally:
            if self._owns_handle:
                # A 429 here means our tenant is momentarily at its
                # admission limit — retry briefly rather than silently
                # leaking the server-side handle (reader + pool-charged
                # cache bytes stay alive until gateway shutdown otherwise).
                for attempt in range(4):
                    try:
                        self._request("DELETE", "/v1/archives/%s" % self.handle)
                        break
                    except GatewayError as exc:
                        if exc.status != 429 or attempt == 3:
                            break  # already closed / gone / retries spent
                        time.sleep(0.25 * (attempt + 1))
                    except (OSError, http.client.HTTPException):
                        break  # gateway already gone

    # -- gateway extras ------------------------------------------------------

    @property
    def etag(self) -> Optional[str]:
        return self._remote.etag

    @property
    def remote_stats(self):
        """Inner RemoteFileReader network counters (requests/retries/bytes)."""
        return self._remote.stats

    def stream(self, *, read_size: int = 64 << 10) -> Iterator[bytes]:
        """Yield the whole decompressed body incrementally (chunked 200).

        Uses a dedicated connection so an abandoned generator (``close()``
        or ``break``) drops the socket — which the gateway observes as a
        mid-stream disconnect and turns into end-to-end cancellation.
        """
        if self._closed:
            raise ValueError("stream on closed GatewayClient")
        conn = self._connect()
        try:
            conn.request("GET", self._bytes_path, headers=dict(self._headers))
            resp = conn.getresponse()
            if resp.status != 200:
                raise GatewayError(resp.status, resp.read().decode(errors="replace"))
            while True:
                data = resp.read(read_size)
                if not data:
                    return
                yield data
        finally:
            conn.close()

    def stat(self) -> Dict[str, Any]:
        status, payload = self._request(
            "GET", "/v1/archives/%s/stat" % self.handle
        )
        return payload

    def metrics(self) -> Dict[str, Any]:
        status, payload = self._request("GET", "/v1/metrics")
        return payload

    # -- plumbing ------------------------------------------------------------

    def _connect(self) -> http.client.HTTPConnection:
        cls = (
            http.client.HTTPSConnection
            if self._scheme == "https"
            else http.client.HTTPConnection
        )
        return cls(self._netloc, timeout=self._timeout)

    def _request(self, method: str, path: str, payload: Optional[Dict] = None):
        """One-shot management call; returns (status, decoded JSON body)."""
        body = json.dumps(payload).encode() if payload is not None else None
        headers = dict(self._headers)
        if body is not None:
            headers["Content-Type"] = "application/json"
        conn = self._connect()
        try:
            conn.request(method, path, body=body, headers=headers)
            resp = conn.getresponse()
            raw = resp.read()
            if resp.status >= 400:
                try:
                    message = json.loads(raw.decode() or "{}").get("error", "")
                except (ValueError, UnicodeDecodeError):
                    message = raw.decode(errors="replace")
                raise GatewayError(resp.status, message)
            decoded = json.loads(raw.decode()) if raw else None
            return resp.status, decoded
        finally:
            conn.close()
