"""FairExecutor — one decompression thread-pool budget, many tenants.

`GzipChunkFetcher` assumes it owns a private ThreadPoolExecutor; a service
hosting dozens of readers cannot hand each one `parallelization` threads
(worst case: tenants x parallelization threads), nor share one plain FIFO
pool — a hot tenant streaming prefetches would queue ahead of everyone
else's first byte.

FairExecutor keeps one fixed worker pool and a run-queue *per tenant*,
serviced by **deficit round-robin over byte-weighted quanta** (DRR, Shreedhar
& Varghese): every task carries an estimated byte cost (how much
decompression work it represents), every tenant queue carries a deficit
counter replenished in quanta, and a task dispatches only when its tenant
has banked enough deficit to pay for it. Task-count round-robin is *not*
fair here — the paper's own measurements (§1.3) put a marker-mode trial
decode at >2x the work of a zlib-delegated indexed chunk of the same size,
and chunks themselves differ by orders of magnitude; a tenant submitting
4 MiB speculative decodes would receive orders of magnitude more CPU than
one submitting 32 KiB indexed reads while "fairly" alternating with it.

On top of DRR, each tenant has a **priority lane**: interactive tasks
(`read_range`'s blocking fetch, finalization on the read path) dispatch
before that tenant's queued batch prefetches. Cross-tenant arbitration is
unchanged — priority cuts the line only within its own tenant, so a tenant
cannot buy extra bandwidth by marking everything interactive (its deficit
still pays full byte cost).

Tenants can carry **weighted quanta** (``set_tenant_quantum(tenant,
factor)``): each replenishment pass credits that tenant ``factor x
quantum_bytes`` instead of one flat quantum, so a paying tenant with factor
2.0 receives ~2x the decompression bandwidth of a factor-1.0 tenant under
contention — classic weighted DRR, threaded through
``ArchiveServer.open(..., quantum=...)`` and the gateway's tenant config.

Accounting invariant (enforced by tests and the gateway's disconnect
handling): ``submitted == done + cancelled + queued`` at quiescence —
``done`` counts tasks that actually ran, ``cancelled`` counts tasks whose
future was cancelled while queued (they never execute), ``queued`` what
still waits. A client abandoning a request can therefore never orphan a
task: it either runs, or it is accounted cancelled.

``fairness="task_rr"`` restores the legacy task-count round-robin (costs and
lanes ignored) so the two disciplines can be A/B-measured — see
benchmarks/bench_service.py's skewed-tenant scenario.

Readers receive a `TenantExecutor` view: submit-compatible with
ThreadPoolExecutor (the fetcher calls only ``submit``/``shutdown``), tagging
every task with its tenant. Cost/priority hints travel via ``submit_hinted``
— callers that don't know about hints keep calling ``submit`` and get
neutral defaults (one quantum, batch lane). ``shutdown`` on a view cancels
that tenant's queued tasks but never touches the shared workers — the
server owns those.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, Optional, Tuple

from ..obs import hist as _obs_hist
from ..obs import trace as _obs_trace

#: Default deficit replenishment per round-robin visit. One quantum ~ one
#: small indexed-chunk task, so light tenants dispatch every visit while a
#: 4 MiB speculative decode must bank ~16 visits worth of credit.
DEFAULT_QUANTUM_BYTES = 256 << 10


@dataclass
class _Task:
    seq: int  # global submission order (task_rr FIFO + stable ties)
    future: Future
    fn: Callable
    args: tuple
    kwargs: dict
    view: object
    cost: int
    priority: bool
    tenant: str = ""  # owning queue (runtime observation needs it post-dispatch)
    #: Trace context captured at submit (None while tracing is disabled) —
    #: the worker reinstates it so a task's spans join the submitter's trace
    #: across the thread hop.
    ctx: Optional[Tuple[str, str]] = None
    t_submit: float = field(default=0.0)  # perf_counter at enqueue


class _TenantQueue:
    __slots__ = ("pri", "batch", "deficit")

    def __init__(self) -> None:
        self.pri: Deque[_Task] = deque()
        self.batch: Deque[_Task] = deque()
        self.deficit: int = 0

    def __len__(self) -> int:
        return len(self.pri) + len(self.batch)

    def head(self, fairness: str) -> _Task:
        """Next task: priority lane first under DRR, submission order under
        the legacy task_rr discipline (which predates lanes)."""
        if fairness == "task_rr":
            if self.pri and self.batch:
                return self.pri[0] if self.pri[0].seq < self.batch[0].seq else self.batch[0]
        if self.pri:
            return self.pri[0]
        return self.batch[0]

    def pop(self, task: _Task) -> None:
        if self.pri and self.pri[0] is task:
            self.pri.popleft()
        else:
            self.batch.popleft()

    def drain(self) -> list:
        tasks = list(self.pri) + list(self.batch)
        self.pri.clear()
        self.batch.clear()
        return tasks


class FairExecutor:
    def __init__(
        self,
        max_workers: int,
        *,
        thread_name_prefix: str = "archive",
        quantum_bytes: int = DEFAULT_QUANTUM_BYTES,
        fairness: str = "drr",
        cost_correction: bool = False,
        correction_alpha: float = 0.2,
    ):
        if max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        if quantum_bytes < 1:
            raise ValueError("quantum_bytes must be >= 1")
        if fairness not in ("drr", "task_rr"):
            raise ValueError("fairness must be 'drr' or 'task_rr'")
        if not 0.0 < correction_alpha <= 1.0:
            raise ValueError("correction_alpha must be in (0, 1]")
        self.max_workers = max_workers
        self.quantum_bytes = quantum_bytes
        self.fairness = fairness
        #: EWMA observed-runtime correction of byte-cost hints. Cost hints
        #: are estimates (a marker-mode decode claims 2x, a transcode span
        #: claims span_bytes); observed runtimes calibrate them: a global
        #: EWMA of claimed-bytes/second sets the fleet's exchange rate, and
        #: each tenant's factor tracks EWMA(runtime x rate / claimed_cost) —
        #: >1 means the tenant's tasks run slower than their hints claim, so
        #: DRR charges them proportionally more. Off by default: the raw
        #: hints stay exactly the documented DRR behavior.
        self.cost_correction = bool(cost_correction)
        self._corr_alpha = float(correction_alpha)
        self._throughput_ewma: Optional[float] = None  # claimed bytes / s
        self._correction: Dict[str, float] = {}
        self._cond = threading.Condition()
        # OrderedDict gives a stable round-robin order with O(1) membership.
        self._queues: "OrderedDict[str, _TenantQueue]" = OrderedDict()
        self._rr_last: Optional[str] = None
        self._shutdown = False
        self._seq = 0
        self._tasks_done = 0
        self._tasks_cancelled = 0  # cancelled while queued: never ran
        self._tasks_submitted = 0
        self._priority_dispatches = 0
        self._dispatch_per_tenant: Dict[str, int] = {}
        self._dispatched_bytes_per_tenant: Dict[str, int] = {}
        self._tenant_quanta: Dict[str, float] = {}
        self._threads = [
            threading.Thread(
                target=self._worker, name=f"{thread_name_prefix}-{i}", daemon=True
            )
            for i in range(max_workers)
        ]
        for t in self._threads:
            t.start()

    # -- submission ---------------------------------------------------------

    def submit(
        self,
        tenant: str,
        fn: Callable,
        *args: Any,
        _view: object = None,
        _cost: Optional[int] = None,
        _priority: bool = False,
        **kwargs: Any,
    ) -> Future:
        fut: Future = Future()
        # A cost-less task is charged one quantum: neutral under DRR (one
        # dispatch per visit, exactly the legacy task-count behavior).
        cost = self.quantum_bytes if _cost is None else max(1, int(_cost))
        with self._cond:
            if self._shutdown:
                raise RuntimeError("cannot submit after shutdown")
            self._seq += 1
            task = _Task(
                self._seq, fut, fn, args, kwargs, _view, cost, _priority, tenant,
                ctx=_obs_trace.capture(), t_submit=time.perf_counter(),
            )
            q = self._queues.setdefault(tenant, _TenantQueue())
            (q.pri if _priority else q.batch).append(task)
            self._tasks_submitted += 1
            self._cond.notify()
        return fut

    def view(self, tenant: str) -> "TenantExecutor":
        return TenantExecutor(self, tenant)

    def set_tenant_quantum(self, tenant: str, factor: float) -> None:
        """Weighted DRR: scale ``tenant``'s per-pass deficit replenishment to
        ``factor * quantum_bytes`` (default 1.0). Under contention a tenant's
        long-run share of dispatched decompression bytes is proportional to
        its factor — the "paying tenants get a larger quantum" knob."""
        if factor <= 0:
            raise ValueError("quantum factor must be > 0")
        with self._cond:
            self._tenant_quanta[tenant] = float(factor)

    def _quantum_of(self, tenant: str) -> int:
        # Called under self._cond.
        return max(1, int(self.quantum_bytes * self._tenant_quanta.get(tenant, 1.0)))

    def _effective_cost(self, tenant: str, cost: int) -> int:
        """The cost DRR charges: the hint, scaled by the tenant's observed
        correction factor when enabled. Called under self._cond."""
        if not self.cost_correction:
            return cost
        return max(1, int(cost * self._correction.get(tenant, 1.0)))

    def _observe_runtime_locked(self, tenant: str, cost: int, runtime_s: float) -> None:
        """Fold one finished task's (claimed cost, observed runtime) into the
        EWMA correction state. Called under self._cond."""
        runtime_s = max(runtime_s, 1e-6)
        alpha = self._corr_alpha
        throughput = cost / runtime_s
        if self._throughput_ewma is None:
            self._throughput_ewma = throughput
        else:
            self._throughput_ewma = (
                alpha * throughput + (1.0 - alpha) * self._throughput_ewma
            )
        implied = runtime_s * self._throughput_ewma  # fleet-rate byte cost
        ratio = min(16.0, max(1.0 / 16.0, implied / max(1, cost)))
        prev = self._correction.get(tenant, 1.0)
        self._correction[tenant] = min(
            16.0, max(1.0 / 16.0, alpha * ratio + (1.0 - alpha) * prev)
        )

    def boost(self, fut: Future, tenant: Optional[str] = None) -> bool:
        """Move a still-queued task into its tenant's priority lane.

        Dedup makes this necessary: when a blocking read joins an already-
        queued batch prefetch for the same chunk, the caller gets the old
        future back — without the upgrade it would wait behind the whole
        batch backlog despite being interactive (priority inversion).
        ``tenant`` narrows the scan to one queue (a view always boosts its
        own tenant's work; a fruitless full scan of a deep batch backlog
        would stall dispatch, since this holds the scheduler lock). The
        remaining per-tenant scan is linear, bounded in practice by the
        fetcher's in-flight dedup (distinct chunks, not request volume).
        Returns True if the task was found queued and promoted.
        """
        with self._cond:
            if tenant is not None:
                q = self._queues.get(tenant)
                queues = [q] if q is not None else []
            else:
                queues = list(self._queues.values())
            for q in queues:
                for i, task in enumerate(q.batch):
                    if task.future is fut:
                        del q.batch[i]
                        task.priority = True
                        q.pri.append(task)
                        return True
        return False

    # -- worker loop --------------------------------------------------------

    def _next_task_locked(self):
        """DRR pick over per-tenant queues (legacy task-count RR in task_rr).

        Equivalent to the textbook multi-pass DRR — each pass credits every
        non-empty queue one quantum until some head task is affordable — but
        computed in one O(tenants) scan: the winner is the queue needing the
        fewest replenishment passes for its head (ties broken in round-robin
        order after ``_rr_last``), and every scanned queue is credited that
        many passes' worth of quanta.
        """
        if not self._queues:
            return None
        tenants = list(self._queues.keys())
        start = 0
        if self._rr_last in self._queues:
            start = tenants.index(self._rr_last) + 1
        n = len(tenants)
        best: Optional[Tuple[int, str]] = None  # (passes_needed, tenant)
        nonempty = []
        for i in range(n):
            tenant = tenants[(start + i) % n]
            q = self._queues[tenant]
            if not len(q):
                # Drop empty queues so dead tenants don't slow the scan.
                del self._queues[tenant]
                continue
            nonempty.append(tenant)
            if self.fairness == "task_rr":
                best = (0, tenant)
                break
            head = q.head(self.fairness)
            head_cost = self._effective_cost(tenant, head.cost)
            passes = max(0, -(-(head_cost - q.deficit) // self._quantum_of(tenant)))
            if passes == 0:
                best = (0, tenant)
                break  # affordable now, and first in RR order
            if best is None or passes < best[0]:
                best = (passes, tenant)
        if best is None:
            return None
        passes, tenant = best
        if passes:
            for t in nonempty:
                # Weighted DRR: each pass credits a tenant its own quantum,
                # so dispatched-byte shares track the configured factors.
                self._queues[t].deficit += passes * self._quantum_of(t)
        q = self._queues[tenant]
        task = q.head(self.fairness)
        q.pop(task)
        # A task cancelled while queued never runs: don't debit the tenant's
        # deficit or book its bytes, or cancelled prefetches would eat real
        # bandwidth credit (the worker still receives it to close the done
        # count).
        cancelled = task.future.cancelled()
        if self.fairness != "task_rr" and not cancelled:
            q.deficit = max(0, q.deficit - self._effective_cost(tenant, task.cost))
        if not len(q):
            # Classic DRR: an emptied queue forfeits banked credit, so an
            # idle tenant cannot hoard a burst allowance.
            q.deficit = 0
        self._rr_last = tenant
        if not cancelled:
            self._dispatch_per_tenant[tenant] = (
                self._dispatch_per_tenant.get(tenant, 0) + 1
            )
            self._dispatched_bytes_per_tenant[tenant] = (
                self._dispatched_bytes_per_tenant.get(tenant, 0) + task.cost
            )
            if task.priority:
                self._priority_dispatches += 1
        return task

    def _worker(self) -> None:
        while True:
            with self._cond:
                task = self._next_task_locked()
                while task is None:
                    if self._shutdown:
                        return
                    self._cond.wait()
                    task = self._next_task_locked()
            fut = task.future
            if not fut.set_running_or_notify_cancel():
                # Cancelled while queued: still a terminal outcome — book it
                # under `cancelled` or snapshot()'s submitted == done +
                # cancelled + queued invariant drifts.
                with self._cond:
                    self._tasks_cancelled += 1
                continue
            t0 = time.perf_counter()
            # Queue wait (enqueue -> dispatch) is the scheduler's own
            # contribution to read latency — always histogrammed; the run
            # span below only exists while tracing is on.
            _obs_hist.observe("executor.queue_wait", t0 - task.t_submit)
            if _obs_trace.tracing_enabled():
                run_cm = _obs_trace.span(
                    "executor.run",
                    {
                        "tenant": task.tenant,
                        "cost": task.cost,
                        "priority": task.priority,
                        "queue_wait_s": round(t0 - task.t_submit, 6),
                    },
                    parent=task.ctx,
                )
            else:
                run_cm = None
            try:
                if run_cm is not None:
                    with _obs_trace.attach(task.ctx), run_cm:
                        result = task.fn(*task.args, **task.kwargs)
                else:
                    result = task.fn(*task.args, **task.kwargs)
            except BaseException as exc:  # noqa: BLE001 - mirror Executor semantics
                fut.set_exception(exc)
            else:
                fut.set_result(result)
            runtime_s = time.perf_counter() - t0
            if run_cm is None:
                _obs_hist.observe("executor.run", runtime_s)
            with self._cond:
                self._tasks_done += 1
                if self.cost_correction:
                    self._observe_runtime_locked(task.tenant, task.cost, runtime_s)

    # -- teardown & introspection ------------------------------------------

    def cancel_tenant(self, tenant: str) -> int:
        """Cancel all *queued* (not yet running) tasks of one tenant."""
        cancelled = 0
        with self._cond:
            q = self._queues.get(tenant)
            if q:
                for task in q.drain():
                    # Dequeued without running: terminal either way. A future
                    # the owner already cancelled directly still books here
                    # (it can no longer reach a worker).
                    task.future.cancel()
                    cancelled += 1
                    self._tasks_cancelled += 1
        return cancelled

    def cancel_view(self, view: object, *, batch_only: bool = False) -> int:
        """Cancel queued tasks submitted through one TenantExecutor view.

        Scoped narrower than cancel_tenant: a tenant may have several
        readers open; closing one must not cancel the others' work.
        ``batch_only=True`` restricts the sweep to the batch lane — queued
        *prefetches* — leaving priority-lane tasks (someone is blocking on
        those right now) untouched; this is what the gateway uses when a
        client disconnects mid-stream.
        """
        cancelled = 0
        with self._cond:
            for q in self._queues.values():
                for lane in ((q.batch,) if batch_only else (q.pri, q.batch)):
                    if not any(task.view is view for task in lane):
                        continue
                    keep = []
                    for task in lane:
                        if task.view is view:
                            task.future.cancel()
                            cancelled += 1
                            self._tasks_cancelled += 1  # dequeued: terminal
                        else:
                            keep.append(task)
                    lane.clear()
                    lane.extend(keep)
        return cancelled

    def shutdown(self, wait: bool = True, cancel_futures: bool = False) -> None:
        with self._cond:
            self._shutdown = True
            if cancel_futures:
                for q in self._queues.values():
                    for task in q.drain():
                        task.future.cancel()
                        self._tasks_cancelled += 1
            self._cond.notify_all()
        if wait:
            for t in self._threads:
                t.join()

    def snapshot(self) -> Dict[str, Any]:
        with self._cond:
            return {
                "max_workers": self.max_workers,
                "fairness": self.fairness,
                "quantum_bytes": self.quantum_bytes,
                "submitted": self._tasks_submitted,
                "done": self._tasks_done,
                "cancelled": self._tasks_cancelled,
                "queued": sum(len(q) for q in self._queues.values()),
                "priority_dispatches": self._priority_dispatches,
                "dispatch_per_tenant": dict(self._dispatch_per_tenant),
                "dispatched_bytes_per_tenant": dict(self._dispatched_bytes_per_tenant),
                "tenant_quanta": dict(self._tenant_quanta),
                "deficit_per_tenant": {
                    t: q.deficit for t, q in self._queues.items() if len(q)
                },
                "cost_correction": {
                    "enabled": self.cost_correction,
                    "throughput_bps": (
                        round(self._throughput_ewma, 1)
                        if self._throughput_ewma is not None
                        else None
                    ),
                    "per_tenant": {
                        t: round(f, 4) for t, f in self._correction.items()
                    },
                },
            }

    def __enter__(self) -> "FairExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=False, cancel_futures=True)


class TenantExecutor:
    """ThreadPoolExecutor-shaped view binding one tenant id.

    This is what gets injected into `GzipChunkFetcher`: the fetcher keeps
    calling ``pool.submit(fn, *args)`` exactly as before, unaware that its
    tasks now compete fairly with every other reader's. Hint-aware callers
    use ``submit_hinted`` to declare byte cost and interactivity; its
    presence is feature-detected (``getattr``), so the same fetcher code
    also runs against a plain ThreadPoolExecutor.
    """

    def __init__(self, parent: FairExecutor, tenant: str):
        self._parent = parent
        self.tenant = tenant

    def submit(self, fn: Callable, *args: Any, **kwargs: Any) -> Future:
        return self._parent.submit(self.tenant, fn, *args, _view=self, **kwargs)

    def submit_hinted(
        self,
        fn: Callable,
        *args: Any,
        cost: Optional[int] = None,
        priority: bool = False,
        **kwargs: Any,
    ) -> Future:
        """submit() plus scheduling hints: estimated byte ``cost`` (DRR
        deficit charge) and ``priority`` (interactive lane, jumps this
        tenant's batch backlog only)."""
        return self._parent.submit(
            self.tenant, fn, *args, _view=self, _cost=cost, _priority=priority, **kwargs
        )

    def boost(self, fut: Future) -> bool:
        """Promote a queued task of this tenant to the priority lane (see
        FairExecutor.boost)."""
        return self._parent.boost(fut, tenant=self.tenant)

    def shutdown(self, wait: bool = True, cancel_futures: bool = False) -> None:
        # The shared pool is server-owned; a reader closing only drains its
        # own queued work.
        if cancel_futures:
            self._parent.cancel_view(self)

    def cancel_pending(self, *, batch_only: bool = False) -> int:
        """Cancel this view's queued tasks (fetcher shutdown hook); with
        ``batch_only`` only the prefetch backlog (gateway disconnects)."""
        return self._parent.cancel_view(self, batch_only=batch_only)
