"""FairExecutor — one decompression thread-pool budget, many tenants.

`GzipChunkFetcher` assumes it owns a private ThreadPoolExecutor; a service
hosting dozens of readers cannot hand each one `parallelization` threads
(worst case: tenants x parallelization threads), nor share one plain FIFO
pool — a hot tenant streaming prefetches would queue ahead of everyone
else's first byte.

FairExecutor keeps one fixed worker pool and a run-queue *per tenant*,
serviced round-robin: each free worker takes the next task from the next
non-empty tenant queue after the last one served. A tenant with 1000 queued
prefetch tasks and a tenant with 1 queued read each get a worker on the next
two dispatches. That is the paper's dynamic work distribution (§4.2) with a
fairness layer on top.

Readers receive a `TenantExecutor` view: submit-compatible with
ThreadPoolExecutor (the fetcher calls only ``submit``/``shutdown``), tagging
every task with its tenant. ``shutdown`` on a view cancels that tenant's
queued tasks but never touches the shared workers — the server owns those.
"""

from __future__ import annotations

import threading
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Any, Callable, Deque, Dict, Optional, Tuple


class FairExecutor:
    def __init__(self, max_workers: int, *, thread_name_prefix: str = "archive"):
        if max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        self.max_workers = max_workers
        self._cond = threading.Condition()
        # tenant -> queue of (Future, fn, args, kwargs, view); OrderedDict
        # gives a stable round-robin order with O(1) membership.
        self._queues: "OrderedDict[str, Deque[Tuple[Future, Callable, tuple, dict, object]]]" = OrderedDict()
        self._rr_last: Optional[str] = None
        self._shutdown = False
        self._tasks_done = 0
        self._tasks_submitted = 0
        self._dispatch_per_tenant: Dict[str, int] = {}
        self._threads = [
            threading.Thread(
                target=self._worker, name=f"{thread_name_prefix}-{i}", daemon=True
            )
            for i in range(max_workers)
        ]
        for t in self._threads:
            t.start()

    # -- submission ---------------------------------------------------------

    def submit(
        self, tenant: str, fn: Callable, *args: Any, _view: object = None, **kwargs: Any
    ) -> Future:
        fut: Future = Future()
        with self._cond:
            if self._shutdown:
                raise RuntimeError("cannot submit after shutdown")
            self._queues.setdefault(tenant, deque()).append((fut, fn, args, kwargs, _view))
            self._tasks_submitted += 1
            self._cond.notify()
        return fut

    def view(self, tenant: str) -> "TenantExecutor":
        return TenantExecutor(self, tenant)

    # -- worker loop --------------------------------------------------------

    def _next_task_locked(self):
        """Round-robin pick: first non-empty tenant queue after _rr_last."""
        if not self._queues:
            return None
        tenants = list(self._queues.keys())
        start = 0
        if self._rr_last in self._queues:
            start = tenants.index(self._rr_last) + 1
        n = len(tenants)
        for i in range(n):
            tenant = tenants[(start + i) % n]
            q = self._queues[tenant]
            if q:
                self._rr_last = tenant
                self._dispatch_per_tenant[tenant] = (
                    self._dispatch_per_tenant.get(tenant, 0) + 1
                )
                return q.popleft()
            # Drop empty queues so dead tenants don't slow the scan.
            del self._queues[tenant]
        return None

    def _worker(self) -> None:
        while True:
            with self._cond:
                task = self._next_task_locked()
                while task is None:
                    if self._shutdown:
                        return
                    self._cond.wait()
                    task = self._next_task_locked()
            fut, fn, args, kwargs, _view = task
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                result = fn(*args, **kwargs)
            except BaseException as exc:  # noqa: BLE001 - mirror Executor semantics
                fut.set_exception(exc)
            else:
                fut.set_result(result)
            with self._cond:
                self._tasks_done += 1

    # -- teardown & introspection ------------------------------------------

    def cancel_tenant(self, tenant: str) -> int:
        """Cancel all *queued* (not yet running) tasks of one tenant."""
        cancelled = 0
        with self._cond:
            q = self._queues.get(tenant)
            if q:
                for item in q:
                    if item[0].cancel():
                        cancelled += 1
                q.clear()
        return cancelled

    def cancel_view(self, view: object) -> int:
        """Cancel queued tasks submitted through one TenantExecutor view.

        Scoped narrower than cancel_tenant: a tenant may have several
        readers open; closing one must not cancel the others' work.
        """
        cancelled = 0
        with self._cond:
            for q in self._queues.values():
                keep = [item for item in q if item[4] is not view]
                if len(keep) != len(q):
                    for item in q:
                        if item[4] is view and item[0].cancel():
                            cancelled += 1
                    q.clear()
                    q.extend(keep)
        return cancelled

    def shutdown(self, wait: bool = True, cancel_futures: bool = False) -> None:
        with self._cond:
            self._shutdown = True
            if cancel_futures:
                for q in self._queues.values():
                    for item in q:
                        item[0].cancel()
                    q.clear()
            self._cond.notify_all()
        if wait:
            for t in self._threads:
                t.join()

    def snapshot(self) -> Dict[str, Any]:
        with self._cond:
            return {
                "max_workers": self.max_workers,
                "submitted": self._tasks_submitted,
                "done": self._tasks_done,
                "queued": sum(len(q) for q in self._queues.values()),
                "dispatch_per_tenant": dict(self._dispatch_per_tenant),
            }

    def __enter__(self) -> "FairExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=False, cancel_futures=True)


class TenantExecutor:
    """ThreadPoolExecutor-shaped view binding one tenant id.

    This is what gets injected into `GzipChunkFetcher`: the fetcher keeps
    calling ``pool.submit(fn, *args)`` exactly as before, unaware that its
    tasks now compete fairly with every other reader's.
    """

    def __init__(self, parent: FairExecutor, tenant: str):
        self._parent = parent
        self.tenant = tenant

    def submit(self, fn: Callable, *args: Any, **kwargs: Any) -> Future:
        return self._parent.submit(self.tenant, fn, *args, _view=self, **kwargs)

    def shutdown(self, wait: bool = True, cancel_futures: bool = False) -> None:
        # The shared pool is server-owned; a reader closing only drains its
        # own queued work.
        if cancel_futures:
            self._parent.cancel_view(self)

    def cancel_pending(self) -> int:
        """Cancel this view's queued tasks (fetcher shutdown hook)."""
        return self._parent.cancel_view(self)
