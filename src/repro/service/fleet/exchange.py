"""Cross-node index exchange: fetch finalized seek indexes from fleet peers.

The fetching side of the gateway's ``GET /v1/archives/{key}/index``
endpoint, packaged as an `IndexStore` ``remote_fallback`` hook. Peers are
asked in HRW order for the key — the owner is the peer most likely to have
paid for (and persisted) the index — and the response is validator-checked
against the very key requested: the endpoint's ETag is the bare
content-addressed ``file_identity`` key, so a match proves the peer is
talking about the same file version, not merely the same path. (The store
then re-validates that the blob parses as a *finalized* GzipIndex before
installing it.)

Single-flight de-duplication lives in `IndexStore` itself; this module is a
pure fetch function so it composes with any membership source: a static
URL list, a `FleetMembership`, or a `FleetRouter`.
"""

from __future__ import annotations

import http.client
import urllib.parse
from typing import Callable, Iterable, Optional, Sequence, Union

from .router import rendezvous_rank


def fetch_index_from_peers(
    peers: Sequence[str],
    key: str,
    *,
    token: Optional[str] = None,
    timeout: float = 5.0,
) -> Optional[bytes]:
    """Ask ``peers`` (in HRW order for ``key``) for the finalized index blob.

    Returns the first validator-matching blob, or None when no peer has one
    (every peer answered 404, errored, or served a mismatched ETag). Peer
    faults are swallowed: a missing index degrades to a cold first pass,
    it must never fail the open.
    """
    headers = {"Authorization": "Bearer %s" % token} if token else {}
    for peer in rendezvous_rank(key, [p.rstrip("/") for p in peers]):
        split = urllib.parse.urlsplit(peer)
        cls = (
            http.client.HTTPSConnection
            if split.scheme == "https"
            else http.client.HTTPConnection
        )
        conn = cls(split.netloc, timeout=timeout)
        try:
            conn.request(
                "GET", "/v1/archives/%s/index" % key, headers=dict(headers)
            )
            resp = conn.getresponse()
            blob = resp.read()
            if resp.status != 200:
                continue
            etag = (resp.getheader("ETag") or "").strip('"')
            if etag != key:
                # The peer is serving *an* index but not provably the one
                # for this exact file version — importing it could seed
                # corrupt seek points. Skip.
                continue
            return blob
        except (OSError, http.client.HTTPException):
            continue
        finally:
            conn.close()
    return None


def make_index_fallback(
    peers: Union[Sequence[str], "object"],
    *,
    exclude: Iterable[str] = (),
    token: Optional[str] = None,
    timeout: float = 5.0,
) -> Callable[[str], Optional[bytes]]:
    """Build an ``IndexStore(remote_fallback=...)`` hook over ``peers``.

    ``peers`` is a static URL sequence or anything with ``alive()`` (a
    `FleetMembership`/`FleetRouter.membership`) — the live view is consulted
    per fetch, so ejected peers are skipped. ``exclude`` is typically the
    node's *own* URL: a gateway must not ask itself for the index it is in
    the middle of missing.
    """
    excluded = {u.rstrip("/") for u in exclude}

    def fallback(key: str) -> Optional[bytes]:
        alive = getattr(peers, "alive", None)
        candidates = alive() if callable(alive) else list(peers)
        candidates = [
            u.rstrip("/") for u in candidates if u.rstrip("/") not in excluded
        ]
        if not candidates:
            return None
        return fetch_index_from_peers(
            candidates, key, token=token, timeout=timeout
        )

    return fallback
