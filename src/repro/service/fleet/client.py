"""FleetClient — the FileReader contract over a fleet of gateway peers.

A thin failover shell around `GatewayClient`: resolve the archive's owner
via the router, open there, delegate reads; on a peer fault, re-resolve,
re-open on the next-highest live peer, re-validate object identity, and
retry/resume. Positional reads make failover trivial (a pread re-issues
verbatim); streams resume at the exact byte offset already yielded via
``Range`` (see `GatewayClient.stream(offset=...)`).

Fault classification is deliberate: connection-level faults and gateway
5xx/timeout/throttle-exhaustion fail over (the peer, not the archive, is
the problem); 4xx management errors (404 unknown path, 403 jail) and
`RemoteFileChangedError` (the *file* changed — a different peer would only
confirm it) propagate immediately. Each logical operation tries each live
peer at most once; when every candidate is exhausted `FleetUnavailable`
(a `RemoteIOError`) surfaces, so existing remote-error handling upstream
needs no new except clauses.
"""

from __future__ import annotations

import http.client
import threading
from typing import Any, Dict, Iterator, Optional, Set

from ...core.errors import RemoteFileChangedError, RemoteIOError
from ...core.filereader import FileReader, check_pread_args
from ...obs import trace as _obs_trace
from ..gateway.client import GatewayClient, GatewayError


class FleetUnavailable(RemoteIOError):
    """No live peer can serve the archive (all candidates failed/ejected)."""


def _is_peer_failure(exc: BaseException) -> bool:
    """Faults that indict the *peer* (fail over) vs the *request* (raise)."""
    if isinstance(exc, RemoteFileChangedError):
        return False
    if isinstance(exc, GatewayError):
        # 429 only lands here after the client's retry budget is spent —
        # at that point the peer is effectively unavailable to us.
        return exc.status in (408, 429, 500, 502, 503, 504)
    return isinstance(exc, (RemoteIOError, OSError, http.client.HTTPException))


class FleetClient(FileReader):
    """Positioned reads of an archive's decompressed bytes via its fleet
    owner, with transparent failover.

    Built by `FleetRouter.open`; extra keyword arguments tune the inner
    `GatewayClient` / `RemoteFileReader` (block_size, cache_blocks,
    retry/backoff, timeout, retry_budget).
    """

    def __init__(
        self,
        router,
        source: str,
        *,
        token: Optional[str] = None,
        tenant: Optional[str] = None,
        **gateway_options: Any,
    ):
        self._router = router
        self._source = source
        self._token = token
        self._tenant = tenant
        self._gateway_options = gateway_options
        self._key = router.key_for(source)
        self._lock = threading.Lock()  # guards the (_peer, _gw) swap
        self._gw: Optional[GatewayClient] = None
        self._peer: Optional[str] = None
        self._etag: Optional[str] = None
        self._closed = False
        self.stats: Dict[str, int] = {
            "opens": 0, "failovers": 0, "revalidations": 0,
            "resumed_streams": 0,
        }
        self._connect(set())

    # -- placement / failover ------------------------------------------------

    @property
    def peer(self) -> Optional[str]:
        """URL of the peer currently serving this archive."""
        with self._lock:
            return self._peer

    @property
    def key(self) -> str:
        """Placement key (content-addressed `file_identity`) for this archive."""
        return self._key

    def _bump(self, counter: str) -> None:
        self.stats[counter] += 1
        self._router.note(counter)

    def _connect(self, exclude: Set[str]) -> GatewayClient:
        """Open the archive on the best live peer not in ``exclude``.

        On success the (peer, client) pair is installed under the lock; on a
        per-peer fault the peer is reported to membership and the next
        candidate tried. Raises `FleetUnavailable` when no candidate works.
        """
        last_exc: Optional[BaseException] = None
        for peer in self._router.owners(self._key):
            if peer in exclude:
                continue
            gw = None
            try:
                gw = GatewayClient(
                    peer,
                    source=self._source,
                    token=self._token,
                    tenant=self._tenant,
                    **self._gateway_options,
                )
                if self._etag is not None and gw.etag != self._etag:
                    # Re-validation after failover: the 304 path (a
                    # conditional GET inside revalidate) confirms version
                    # identity without refetching any body bytes.
                    self._bump("revalidations")
                    if not gw.revalidate(self._etag):
                        raise RemoteFileChangedError(
                            "%s: peer %s serves ETag %s, expected %s"
                            % (self._source, peer, gw.etag, self._etag)
                        )
            except BaseException as exc:
                if gw is not None:
                    try:
                        gw.close()
                    except Exception:  # noqa: BLE001 - already failing
                        pass
                if not _is_peer_failure(exc):
                    raise
                last_exc = exc
                exclude.add(peer)
                self._router.membership.report_failure(peer, exc)
                continue
            with self._lock:
                self._peer = peer
                self._gw = gw
                if self._etag is None:
                    self._etag = gw.etag
            self._bump("opens")
            return gw
        raise FleetUnavailable(
            "no live peer can serve %r (key %s): last error: %r"
            % (self._source, self._key[:12], last_exc)
        ) from last_exc

    def _current(self) -> GatewayClient:
        with self._lock:
            if self._closed:
                raise ValueError("operation on closed FleetClient")
            assert self._gw is not None
            return self._gw

    def _failover(self, failed: GatewayClient, exclude: Set[str]) -> None:
        """Replace ``failed`` with a client on the next-best peer.

        Concurrent preads may fail on the same dead peer at once: only the
        first caller performs the re-resolve; the rest observe the swap and
        simply retry on the new client.
        """
        with self._lock:
            if self._closed:
                raise ValueError("operation on closed FleetClient")
            if self._gw is not failed:
                return  # another thread already failed over
            peer = self._peer
        if peer is not None:
            exclude.add(peer)
            self._router.membership.report_failure(peer)
        try:
            failed.close()
        except Exception:  # noqa: BLE001 - the peer is gone; best effort
            pass
        self._bump("failovers")
        self._connect(exclude)

    # -- FileReader contract -------------------------------------------------

    def pread(self, offset: int, size: int) -> bytes:
        check_pread_args(offset, size)
        exclude: Set[str] = set()
        with _obs_trace.span("fleet.pread", {"offset": offset, "size": size}) as sp:
            while True:
                gw = self._current()
                try:
                    return gw.pread(offset, size)
                except BaseException as exc:
                    if not _is_peer_failure(exc):
                        raise
                    with _obs_trace.span(
                        "fleet.failover", {"from_peer": self.peer, "error": type(exc).__name__}
                    ):
                        self._failover(gw, exclude)  # raises FleetUnavailable at end
                    sp.set_attr("failovers", len(exclude))

    def size(self) -> int:
        exclude: Set[str] = set()
        while True:
            gw = self._current()
            try:
                return gw.size()
            except BaseException as exc:
                if not _is_peer_failure(exc):
                    raise
                self._failover(gw, exclude)

    def identity(self) -> Optional[str]:
        return self._current().identity()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            gw, self._gw = self._gw, None
        if gw is not None:
            gw.close()

    # -- fleet extras --------------------------------------------------------

    @property
    def etag(self) -> Optional[str]:
        return self._etag

    def stream(self, *, read_size: int = 64 << 10) -> Iterator[bytes]:
        """Yield the whole decompressed body; survives owner death.

        Bytes already yielded are never re-yielded: on a mid-stream peer
        fault the stream resumes on the failover peer at the exact next
        offset (``Range: bytes=offset-``), with ETag continuity enforced by
        `GatewayClient.stream` — the concatenation is bit-identical to an
        uninterrupted read.
        """
        offset = 0
        exclude: Set[str] = set()
        while True:
            gw = self._current()
            try:
                if offset and offset >= gw.size():
                    return  # failed over exactly at EOF
                for chunk in gw.stream(read_size=read_size, offset=offset):
                    offset += len(chunk)
                    yield chunk
                return
            except BaseException as exc:
                if not _is_peer_failure(exc):
                    raise
                self._failover(gw, exclude)
                if offset:
                    self._bump("resumed_streams")

    def stat(self) -> Dict[str, Any]:
        return self._current().stat()
