"""Sharded archive fleet: routing, failover, and cross-node index exchange.

One gateway process is the ceiling of PRs 1-5 — a single event loop, one
machine's cores, one cache budget. This package is the horizontal axis the
ROADMAP's north star requires: N independent gateway peers behind a thin
client-side routing tier. Nothing here adds a coordinator process or a
consensus protocol; placement is a pure function of content identity, and
every recovery path reduces to wire primitives the gateway already
guarantees (exact Range semantics, ETag validators, admission Retry-After).

Placement contract
==================

Archives are placed by **rendezvous (HRW) hashing** of their
`IndexStore.file_identity` key: for each (key, peer) pair a deterministic
score ``sha256(peer || key)`` is computed, and the key's *owner* is the
live peer with the highest score (`rendezvous_rank` returns the full
preference order). Properties the fleet leans on:

  * **Coordinator-free agreement** — every client computes the same owner
    from the same membership view; no lookup table, no rebalancing state.
  * **Minimal disruption** — when a peer dies, only the keys it owned move
    (each to its next-highest peer); all other placements are untouched.
    When it recovers, exactly those keys move back.
  * **Content-addressed** — the key is `file_identity` (path+size+mtime,
    content digest, or url+validator), the same key the `IndexStore` uses,
    so "where does this archive live" and "where is its seek index" have
    the same answer by construction.

Failover contract
=================

`FleetClient` speaks the `FileReader` contract (pread/size/identity/view/
close) against the archive's owner. On a peer fault mid-operation it:

  1. reports the failure to `FleetMembership` (probes will eject the peer
     after ``eject_after`` consecutive failures; data-path reports count),
  2. re-resolves to the next-highest live peer, excluding peers already
     tried for this operation,
  3. re-opens the archive there and **re-validates object identity** with a
     conditional GET (``If-None-Match``: a 304 proves the new peer serves
     the same object version for the price of headers — satisfying the
     "no full-body refetch on failover" budget),
  4. resumes: a pread simply re-issues (positional reads are stateless); a
     ``stream()`` resumes at the exact byte offset already yielded via
     ``Range: bytes=offset-``, with ETag continuity enforced — killing the
     owner mid-stream yields bit-identical bytes to an uninterrupted read.

Membership and health come from polling each peer's existing
``/v1/metrics`` endpoint (admission-exempt, so an overloaded peer still
answers): configurable probe interval, consecutive-failure ejection,
re-admission on the first successful probe after recovery. Per-handle
stream progress in the metrics lets probes distinguish a *stuck* peer
(bytes frozen across probes) from a merely slow stream.

Index exchange
==============

The expensive artifact worth sharing across nodes is the finalized seek
index — rebuilding it re-runs the speculative first pass over the whole
file (O(file)), while shipping it costs O(index). The gateway's
``GET /v1/archives/{key}/index`` endpoint serves finalized index blobs by
content-addressed key; `make_index_fallback` builds the `IndexStore`
remote-fallback hook that asks fleet peers (in HRW order — the owner most
likely has it) on a local miss. Fetches are single-flighted per key and
validator-checked twice: the response ETag must equal the requested key,
and the blob must parse as a *finalized* `GzipIndex`. A cold open on node
B of an archive node A already indexed therefore does **zero** speculative
first-pass work — fleet-wide warm-open cost drops from O(file) to
O(index).

Quickstart (see ``examples/serve_fleet.py`` for the full tour)::

    from repro.service.fleet import FleetRouter

    with FleetRouter([gw1.url, gw2.url, gw3.url]) as router:
        client = router.open("/data/corpus-00.json.gz")
        page = client.pread(10 << 20, 4096)   # served by the HRW owner
        for chunk in client.stream():          # survives owner death
            consume(chunk)
        client.close()
"""

from .client import FleetClient, FleetUnavailable
from .exchange import fetch_index_from_peers, make_index_fallback
from .membership import FleetMembership, PeerState
from .router import FleetRouter, rendezvous_rank, rendezvous_score

__all__ = [
    "FleetClient",
    "FleetMembership",
    "FleetRouter",
    "FleetUnavailable",
    "PeerState",
    "fetch_index_from_peers",
    "make_index_fallback",
    "rendezvous_rank",
    "rendezvous_score",
]
