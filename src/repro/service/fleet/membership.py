"""FleetMembership — peer liveness from the metrics endpoint every peer
already serves.

No gossip, no heartbeat protocol: ``GET /v1/metrics`` is admission-exempt
(an overloaded gateway still answers it) and already carries per-handle
stream progress, so one poll yields both liveness and stuck-stream
detection. A peer is ejected after ``eject_after`` *consecutive* failures
(one dropped packet must not reshuffle placements) and re-admitted on its
first successful probe — rendezvous hashing then moves exactly its keys
back, nothing else.

Data-path failures count too: `FleetClient.report_failure` feeds the same
consecutive-failure counter, so a dead peer discovered by a read is ejected
without waiting for the next probe cycle to notice.
"""

from __future__ import annotations

import http.client
import json
import threading
import time
import urllib.parse
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence


class PeerState:
    """Mutable health record for one peer (guarded by the membership lock)."""

    __slots__ = (
        "url", "alive", "consecutive_failures", "probes", "ejections",
        "readmissions", "last_ok", "last_error", "stuck_streams",
        "_last_stream_progress",
    )

    def __init__(self, url: str):
        self.url = url
        self.alive = True  # optimistic: a fresh fleet serves immediately
        self.consecutive_failures = 0
        self.probes = 0
        self.ejections = 0
        self.readmissions = 0
        self.last_ok: Optional[float] = None
        self.last_error: Optional[str] = None
        self.stuck_streams = 0
        # stream-id -> bytes sent at the previous probe (stuck detection)
        self._last_stream_progress: Dict[str, int] = {}

    def as_dict(self) -> Dict[str, Any]:
        return {
            "url": self.url,
            "alive": self.alive,
            "consecutive_failures": self.consecutive_failures,
            "probes": self.probes,
            "ejections": self.ejections,
            "readmissions": self.readmissions,
            "last_ok": self.last_ok,
            "last_error": self.last_error,
            "stuck_streams": self.stuck_streams,
        }


def _default_probe(timeout: float, headers: Mapping[str, str]):
    def probe(url: str) -> Mapping[str, Any]:
        split = urllib.parse.urlsplit(url)
        cls = (
            http.client.HTTPSConnection
            if split.scheme == "https"
            else http.client.HTTPConnection
        )
        conn = cls(split.netloc, timeout=timeout)
        try:
            conn.request("GET", "/v1/metrics", headers=dict(headers))
            resp = conn.getresponse()
            body = resp.read()
            if resp.status != 200:
                raise OSError("HTTP %d from %s/v1/metrics" % (resp.status, url))
            return json.loads(body.decode())
        finally:
            conn.close()

    return probe


class FleetMembership:
    """Liveness view over a static peer set, probed at ``probe_interval``.

    ``probe`` is injectable (a callable ``url -> metrics dict``, raising on
    failure) so tests drive state transitions deterministically; the default
    probe speaks HTTP to ``/v1/metrics``. ``start()`` launches the daemon
    probe thread; `probe_once` is public for deterministic single steps.
    """

    def __init__(
        self,
        peers: Sequence[str],
        *,
        probe_interval: float = 1.0,
        eject_after: int = 2,
        timeout: float = 2.0,
        token: Optional[str] = None,
        probe: Optional[Callable[[str], Mapping[str, Any]]] = None,
    ):
        urls = [u.rstrip("/") for u in peers]
        if not urls:
            raise ValueError("a fleet needs at least one peer")
        if len(set(urls)) != len(urls):
            raise ValueError("duplicate peer URLs: %r" % (urls,))
        if eject_after < 1:
            raise ValueError("eject_after must be >= 1")
        self.probe_interval = probe_interval
        self.eject_after = eject_after
        headers = {"Authorization": "Bearer %s" % token} if token else {}
        self._probe = probe if probe is not None else _default_probe(timeout, headers)
        self._lock = threading.Lock()
        self._peers: Dict[str, PeerState] = {u: PeerState(u) for u in urls}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- views ---------------------------------------------------------------

    def peers(self) -> List[str]:
        with self._lock:
            return list(self._peers)

    def alive(self) -> List[str]:
        with self._lock:
            return [u for u, st in self._peers.items() if st.alive]

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            states = {u: st.as_dict() for u, st in self._peers.items()}
        return {
            "peers": states,
            "alive": sum(1 for st in states.values() if st["alive"]),
            "total": len(states),
            "eject_after": self.eject_after,
            "probe_interval": self.probe_interval,
        }

    # -- state transitions ---------------------------------------------------

    def report_failure(self, url: str, error: Optional[BaseException] = None) -> None:
        """Data-path failure signal (connection refused/reset on a read):
        same consecutive-failure bookkeeping as a failed probe."""
        self._mark_failure(url.rstrip("/"), repr(error) if error else "reported")

    def _mark_failure(self, url: str, error: str) -> None:
        with self._lock:
            st = self._peers.get(url)
            if st is None:
                return
            st.consecutive_failures += 1
            st.last_error = error
            if st.alive and st.consecutive_failures >= self.eject_after:
                st.alive = False
                st.ejections += 1

    def _mark_success(self, url: str, metrics: Mapping[str, Any]) -> None:
        streams = {}
        gateway = metrics.get("gateway")
        if isinstance(gateway, Mapping):
            streams = gateway.get("streams_in_progress") or {}
        with self._lock:
            st = self._peers.get(url)
            if st is None:
                return
            st.consecutive_failures = 0
            st.last_ok = time.monotonic()
            st.last_error = None
            if not st.alive:
                st.alive = True
                st.readmissions += 1
            # A stream whose byte count did not advance since the previous
            # probe is *stuck* (slow streams advance, stalled ones do not) —
            # the liveness signal a cumulative byte counter cannot give.
            stuck = 0
            progress: Dict[str, int] = {}
            for sid, info in streams.items():
                sent = int(info.get("sent", 0))
                progress[sid] = sent
                if sid in st._last_stream_progress and st._last_stream_progress[sid] == sent:
                    stuck += 1
            st.stuck_streams = stuck
            st._last_stream_progress = progress

    def probe_once(self) -> None:
        """One probe sweep over all peers (serial; each bounded by the probe
        timeout). Public so tests and callers can step deterministically."""
        for url in self.peers():
            with self._lock:
                st = self._peers.get(url)
                if st is not None:
                    st.probes += 1
            try:
                metrics = self._probe(url)
            except Exception as exc:  # noqa: BLE001 - any fault is a failure
                self._mark_failure(url, repr(exc))
            else:
                self._mark_success(url, metrics)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "FleetMembership":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name="fleet-membership", daemon=True
            )
            self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.probe_interval):
            self.probe_once()

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def __enter__(self) -> "FleetMembership":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
