"""FleetRouter — rendezvous-hash placement over live gateway peers.

Rendezvous (highest-random-weight) hashing beats a hash ring here because
the peer count is small and churn is the common case being optimized:
scoring is O(peers) per key with no virtual-node tuning, every client
agrees on the full preference order (not just the owner — the *failover
order* is part of the placement), and a peer's death moves exactly the
keys it owned to their next-highest peers.

The score is ``sha256(peer || key)`` truncated to 64 bits — stable across
processes and Python versions (never ``hash()``, which is salted per
process and would give every client its own placement).
"""

from __future__ import annotations

import hashlib
import threading
from typing import Any, Dict, List, Optional, Sequence

from ..index_store import file_identity
from .membership import FleetMembership


def rendezvous_score(key: str, peer: str) -> int:
    """Deterministic 64-bit HRW score for (key, peer)."""
    h = hashlib.sha256()
    h.update(peer.encode())
    h.update(b"\0")
    h.update(key.encode())
    return int.from_bytes(h.digest()[:8], "big")


def rendezvous_rank(key: str, peers: Sequence[str]) -> List[str]:
    """Peers ordered by descending HRW score: [owner, first failover, ...].

    The peer URL is the tiebreak (scores are 64-bit, collisions are
    astronomically unlikely, but determinism must not rest on luck).
    """
    return sorted(
        peers, key=lambda p: (rendezvous_score(key, p), p), reverse=True
    )


class FleetRouter:
    """Client-side routing tier over N gateway peers.

    Owns a `FleetMembership` (or wraps one the caller provides) and places
    archives on live peers by HRW hash of their `file_identity` key.
    ``open()`` returns a `FleetClient` bound to this router; the router is
    shared state (membership view + fleet counters), clients are cheap.
    """

    def __init__(
        self,
        peers: Optional[Sequence[str]] = None,
        *,
        membership: Optional[FleetMembership] = None,
        probe_interval: float = 1.0,
        eject_after: int = 2,
        probe_timeout: float = 2.0,
        token: Optional[str] = None,
    ):
        if (peers is None) == (membership is None):
            raise ValueError("pass exactly one of peers= or membership=")
        self.membership = (
            membership
            if membership is not None
            else FleetMembership(
                peers,
                probe_interval=probe_interval,
                eject_after=eject_after,
                timeout=probe_timeout,
                token=token,
            )
        )
        self._owns_membership = membership is None
        self.token = token
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}

    # -- placement -----------------------------------------------------------

    def key_for(self, source) -> str:
        """The placement key: `IndexStore.file_identity` of the source (a
        64-hex string passes through unchanged)."""
        if isinstance(source, str) and len(source) == 64 and all(
            c in "0123456789abcdef" for c in source
        ):
            return source
        return file_identity(source)

    def owners(self, key: str) -> List[str]:
        """Live peers in placement-preference order for ``key``."""
        return rendezvous_rank(key, self.membership.alive())

    def owner(self, key: str) -> str:
        ranked = self.owners(key)
        if not ranked:
            from .client import FleetUnavailable

            raise FleetUnavailable(
                "no live peer for key %s (fleet of %d, all ejected)"
                % (key[:12], len(self.membership.peers()))
            )
        return ranked[0]

    def open(self, source, **client_options: Any):
        """Open ``source`` on its owner; returns a `FleetClient`."""
        from .client import FleetClient

        client_options.setdefault("token", self.token)
        return FleetClient(self, source, **client_options)

    # -- telemetry -----------------------------------------------------------

    def note(self, counter: str, n: int = 1) -> None:
        with self._lock:
            self._counters[counter] = self._counters.get(counter, 0) + n

    def snapshot(self) -> Dict[str, Any]:
        """Fleet-side metrics: membership states + routing counters. Shaped
        for `repro.service.metrics.format_summary` under the ``router`` key."""
        with self._lock:
            counters = dict(self._counters)
        return {"membership": self.membership.snapshot(), "counters": counters}

    def metrics(self) -> Dict[str, Any]:
        return {"router": self.snapshot()}

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "FleetRouter":
        self.membership.start()
        return self

    def close(self) -> None:
        if self._owns_membership:
            self.membership.close()

    def __enter__(self) -> "FleetRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
