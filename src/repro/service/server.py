"""ArchiveServer — many gzip files, many clients, one resource budget.

The paper's architecture (cache + prefetcher + thread pool, §3.2) serves one
reader over one file. This server multiplexes a registry of
`ParallelGzipReader`s behind a single shared budget:

  * **memory** — every reader's access/prefetch caches are `PooledCache`s
    drawn from one `CachePool`, so fleet memory is bounded by the pool
    budget, not by (readers x per-reader maxima);
  * **CPU** — every reader's fetcher submits into one `FairExecutor`
    (byte-weighted deficit round-robin + per-tenant priority lanes), so a
    hot tenant's prefetch stream cannot starve another tenant's first read,
    measured in bytes of decompression work rather than task counts;
  * **index reuse** — opens consult an `IndexStore`; a warm hit skips the
    speculative first pass entirely (zero nominal tasks), closes persist
    finalized indexes back.

API: ``open(source) -> handle``, ``read_range(handle, offset, size)``,
``stat(handle)``, ``close(handle)``. Readers are opened lazily on first use.

Concurrency contract (who locks what):

  * ``read_range`` is **stateless and concurrent**: it rides
    `ParallelGzipReader.pread`, which has no shared cursor. N threads
    hammering one handle serialize only where the physics demands it —
    advancing the speculative first pass past uncovered offsets (the
    reader's narrow frontier lock, one chunk per acquisition). With a warm
    (finalized) index no server- or reader-level lock is taken at all;
    aggregate throughput scales with the executor, not with handle count.
    ``read_range(..., serialized=True)`` keeps the legacy one-cursor-
    per-handle discipline (entry lock around seek+read) for A/B
    measurement — see bench_service's concurrent-scaling scenario.
  * the **entry lock** is a lifecycle lock only: lazy open (exactly one
    thread builds the reader) and close (nobody closes a reader out from
    under an opener). Reads never hold it.
  * reads and ``close`` shake hands through a per-entry **condition**
    (``_Entry.cond``): each read registers in ``in_flight`` (refusing
    closed entries with KeyError), and ``close`` flips ``closed`` then
    drains ``in_flight`` to zero before the reader's file handle goes away
    — a racing read either completes on a live fd or fails cleanly, never
    preads a closed (or fd-recycled) descriptor. The per-entry read/byte
    counters ride the same condition's lock; hot concurrent reads contend
    on nothing coarser.
  * ``stat`` is **lock-free**: it reads a snapshot of the entry and the
    index's own internally-consistent counters, so telemetry stays
    responsive while long first-pass reads are in flight on the same
    handle.

For asyncio front-ends use `service.async_server.AsyncArchiveServer`, which
bridges these calls off the event loop and adds a concurrent ``read_many``.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.reader import ParallelGzipReader
from ..core.remote import RemoteFileReader, is_remote_url
from ..obs import hist as _obs_hist
from ..obs import trace as _obs_trace
from . import metrics as _metrics
from .cache_pool import PREFETCH, CachePool
from .index_store import IndexStore
from .scheduler import FairExecutor
from .transcode import TranscodeManager, resolve_source


@dataclass
class ArchiveStat:
    handle: str
    tenant: str
    opened: bool
    compressed_size: Optional[int]
    decompressed_size: Optional[int]  # None until the index is finalized
    index_points: int
    index_finalized: bool
    index_was_warm: bool  # True when the open hit the IndexStore
    reads: int
    bytes_served: int
    #: IndexStore.file_identity hex key (None until the reader opened) —
    #: the gateway derives the wire ETag from this, so a replaced source
    #: revalidates exactly like the index store re-keys.
    identity: Optional[str] = None
    #: Resolved codec tag ("deflate"/"bgzf"/"zstd") once the reader opened;
    #: before that, the tag requested at open() (None = auto-detect).
    codec: Optional[str] = None
    #: Twin codec tag when the open resolved to a transcoded twin (the
    #: handle serves bit-identical bytes from the re-encoded copy while
    #: `identity` still keys — and the ETag still names — the origin).
    twin: Optional[str] = None

    def as_dict(self) -> Dict[str, Any]:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


class _Entry:
    def __init__(self, handle: str, source, tenant: str, codec: Optional[str] = None):
        self.handle = handle
        self.source = source
        self.tenant = tenant
        #: Codec tag requested at open() (None = auto-detect); replaced by
        #: the reader's resolved tag once the lazy open runs.
        self.codec = codec
        # Lifecycle lock: lazy open / close / persist. Positional reads never
        # take it (pread is stateless); serialized=True legacy reads do.
        self.lock = threading.RLock()
        # Condition guarding the per-entry counters AND the read/close
        # handshake: reads register in `in_flight` under it (refusing closed
        # entries), close() flips `closed` and drains `in_flight` to zero
        # before the reader's file handle goes away — without this, a
        # lock-free read racing close() could pread a closed (or, after fd
        # reuse, a *different*) file descriptor. Cheap enough to take per
        # request without re-serializing the reads themselves.
        self.cond = threading.Condition()
        self.in_flight = 0
        self.reader: Optional[ParallelGzipReader] = None
        self.identity: Optional[str] = None
        self.index_was_warm = False
        #: Twin codec tag when resolution bound this handle to a transcoded
        #: twin; None while serving the origin bytes directly.
        self.twin: Optional[str] = None
        #: One hostility probe per handle: set the first time a finalized
        #: first pass is offered to the TranscodeManager (which dedups by
        #: identity anyway — this flag just keeps the hot path cheap).
        self.transcode_probed = False
        self.reads = 0
        self.bytes_served = 0
        self.closed = False


class ArchiveServer:
    def __init__(
        self,
        *,
        max_workers: int = 8,
        cache_budget_bytes: int = 64 << 20,
        access_fraction: float = 0.25,
        max_tenant_fraction: float = 0.5,
        index_store: Optional[IndexStore] = None,
        chunk_size: int = 1 << 20,
        reader_parallelization: int = 4,
        access_cache_entries: int = 4,
        verify: bool = True,
        fairness: str = "drr",
        quantum_bytes: Optional[int] = None,
        tenant_weights: Optional[Dict[str, float]] = None,
        tenant_quanta: Optional[Dict[str, float]] = None,
        remote_options: Optional[Dict[str, Any]] = None,
        device_engine: Any = "auto",
        engine_options: Optional[Dict[str, Any]] = None,
        transcode: Any = "auto",
        transcode_options: Optional[Dict[str, Any]] = None,
        cost_correction: bool = True,
        slow_request_s: Optional[float] = 1.0,
        slow_log_entries: int = 32,
    ):
        #: kwargs forwarded to every RemoteFileReader the server opens for
        #: http(s):// sources: auth headers, block_size/cache_blocks,
        #: timeout, retry tuning. The remote block caches themselves are
        #: pool-backed (prefetch tier, cache_blocks entries), so their
        #: resident bytes count against the owning tenant's shared budget.
        self.remote_options = dict(remote_options or {})
        self.cache_pool = CachePool(
            cache_budget_bytes,
            access_fraction=access_fraction,
            max_tenant_fraction=max_tenant_fraction,
        )
        for tenant, weight in (tenant_weights or {}).items():
            self.cache_pool.set_tenant_weight(tenant, weight)
        # Quantum defaults to a quarter chunk: a zlib-delegated indexed task
        # dispatches nearly every round-robin visit while a marker-mode
        # speculative decode (2x chunk) banks ~8 visits of deficit first.
        # cost_correction: byte-cost hints are claims; the executor's EWMA of
        # observed runtime re-prices them so a tenant whose "1 MiB" tasks run
        # like 4 MiB (marker-mode two-stage decodes, cold page cache) drains
        # deficit at the observed rate. On by default here — server-submitted
        # work has runtimes roughly proportional to bytes, so honest tenants
        # converge to factor 1.0.
        self.executor = FairExecutor(
            max_workers,
            fairness=fairness,
            quantum_bytes=quantum_bytes if quantum_bytes is not None else max(1, chunk_size // 4),
            cost_correction=cost_correction,
        )
        # Weighted DRR: a tenant's per-pass deficit replenishment scales
        # with its factor (paying tenants get a larger quantum). Also
        # settable per-open via ``open(..., quantum=...)``.
        for tenant, factor in (tenant_quanta or {}).items():
            self.executor.set_tenant_quantum(tenant, factor)
        self.index_store = index_store if index_store is not None else IndexStore()
        # One batched stage-2 device engine per server, shared by every
        # reader/tenant like the executor and cache pool — cross-reader
        # batching is the whole point (kernels/engine.py). "auto" builds one
        # when the kernel stack imports (falling back to None — pure CPU —
        # on hosts without jax); "off"/None/False disables; an object with a
        # ``replace_markers`` attribute is used as an externally owned
        # engine and is NOT shut down with the server.
        self.device_engine = None
        self._owns_engine = False
        if hasattr(device_engine, "replace_markers"):
            self.device_engine = device_engine
        elif device_engine == "auto":
            try:
                from ..kernels.engine import DeviceDecodeEngine

                self.device_engine = DeviceDecodeEngine(**(engine_options or {}))
                self._owns_engine = True
            except Exception:  # noqa: BLE001 - no jax/kernels: serve on CPU
                self.device_engine = None
        elif device_engine not in (None, False, "off"):
            raise ValueError(
                "device_engine must be 'auto', 'off'/None/False, or an engine"
            )
        # Background transcoder: archives whose first pass probes
        # seek-hostile (Codec.seek_hostility above threshold) get re-encoded
        # as a seekable twin on the executor's batch lane; later opens
        # resolve to the twin transparently (service/transcode.py). Same
        # ownership contract as the engine: "auto" builds one over this
        # server's store+executor, "off"/None/False disables, an object with
        # a ``consider`` attribute is externally owned.
        self.transcoder: Optional[TranscodeManager] = None
        self._owns_transcode = False
        if hasattr(transcode, "consider"):
            self.transcoder = transcode
        elif transcode == "auto":
            self.transcoder = TranscodeManager(
                self.index_store, self.executor, **(transcode_options or {})
            )
            self._owns_transcode = True
        elif transcode not in (None, False, "off"):
            raise ValueError(
                "transcode must be 'auto', 'off'/None/False, or a manager"
            )
        self.chunk_size = chunk_size
        self.reader_parallelization = reader_parallelization
        self.access_cache_entries = access_cache_entries
        self.verify = verify

        self._lock = threading.Lock()
        self._entries: Dict[str, _Entry] = {}
        self._handle_seq = 0
        self._closed = False
        # Front-door gauges (metrics "service" section): how many read_range
        # calls are inside the server right now, and cumulative counts split
        # by discipline. Guarded by a micro-lock of their own so the hot
        # path never touches the registry lock.
        self._gauge_lock = threading.Lock()
        self._reads_in_flight = 0
        self._reads_started = 0
        self._reads_serialized = 0
        # Snapshot provenance (metrics satellite): wall/monotonic anchors so
        # scrapers can compute rates and detect restarts, plus a sequence
        # number that makes snapshot ordering explicit.
        self._started_wall = time.time()
        self._started_mono = time.monotonic()
        self._snapshot_seq = 0
        # Threshold-gated slow-request log: reads slower than
        # ``slow_request_s`` (None disables) land here with their span tree
        # attached when tracing is on. Bounded; newest wins.
        self._slow_request_s = slow_request_s
        self._slow_lock = threading.Lock()
        self._slow_log: deque = deque(maxlen=max(1, slow_log_entries))

    # ------------------------------------------------------------------
    # registry
    # ------------------------------------------------------------------

    def open(
        self,
        source,
        *,
        tenant: str = "default",
        quantum: Optional[float] = None,
        codec: Optional[str] = None,
    ) -> str:
        """Register an archive source; the reader is created lazily on first
        use.

        ``source`` is anything `ParallelGzipReader` accepts: a path, bytes,
        an ``http(s)://`` URL (served via range-GET preads, never fully
        downloaded), or a FileReader. ``codec`` pins the format tag
        ("deflate"/"bgzf"/"zstd"); None auto-detects from the head bytes at
        lazy-open time (BGZF by its BC subfield, zstd by frame magic, with
        a deflate fallback that never errors on valid gzip). ``quantum``
        optionally (re)sets the tenant's weighted-DRR quantum factor (see
        `FairExecutor.set_tenant_quantum`) — a per-open convenience for
        callers that learn the tenant's service class at open time (the
        gateway's admission control does).
        """
        if quantum is not None:
            self.executor.set_tenant_quantum(tenant, quantum)
        with self._lock:
            if self._closed:
                raise RuntimeError("server is closed")
            self._handle_seq += 1
            handle = "f%d" % self._handle_seq
            self._entries[handle] = _Entry(handle, source, tenant, codec)
        return handle

    def _entry(self, handle: str) -> _Entry:
        with self._lock:
            entry = self._entries.get(handle)
        if entry is None or entry.closed:
            raise KeyError("unknown or closed handle %r" % handle)
        return entry

    def _ensure_reader(self, entry: _Entry) -> ParallelGzipReader:
        with entry.lock:
            # Re-check under the entry lock: a concurrent close() may have
            # won the race after our registry lookup. Without this, a lazy
            # open here would build a reader (and register pooled caches)
            # that nothing ever closes.
            if entry.closed:
                raise KeyError("unknown or closed handle %r" % entry.handle)
            if entry.reader is not None:
                return entry.reader
            source = entry.source
            access_cache = prefetch_cache = block_cache = None
            try:
                if is_remote_url(source):
                    # Open the remote backend once: the identity probe and
                    # the reader then share one set of open-time validators
                    # (and one HEAD), and `ParallelGzipReader.close` owns its
                    # lifetime. Its block cache is pool-backed, so the
                    # cache_blocks x block_size of readahead bytes are
                    # charged to this tenant's shared budget (prefetch tier)
                    # instead of sitting beside it.
                    opts = dict(self.remote_options)
                    block_cache = self.cache_pool.cache(
                        tier=PREFETCH,
                        tenant=entry.tenant,
                        capacity=int(opts.pop("cache_blocks", 16)),
                    )
                    source = RemoteFileReader(source, block_cache=block_cache, **opts)
                # Source resolution: identity and the reader must agree on
                # the codec (an explicit tag pins both; auto-detection probes
                # the same head bytes in both places), and the store may know
                # a transcoded twin for this identity — in which case the
                # handle binds to the twin's bytes/index while `identity`
                # (and thus the ETag and fleet placement) stays the origin's.
                origin = source
                resolved = resolve_source(
                    self.index_store, origin, codec=entry.codec
                )
                entry.identity = resolved.identity
                entry.index_was_warm = resolved.index_was_warm
                entry.twin = resolved.twin
                source = resolved.source
                if resolved.twin is not None and origin is not entry.source:
                    # Twin-bound: the read path never touches the origin
                    # again, so the remote backend (and its pool-backed
                    # block cache) opened for the identity probe goes back.
                    origin.close()
                    if block_cache is not None:
                        block_cache.release()
                        block_cache = None
                access_cache, prefetch_cache = self.cache_pool.reader_caches(
                    entry.tenant, access_capacity=self.access_cache_entries
                )
                entry.reader = ParallelGzipReader(
                    source,
                    parallelization=self.reader_parallelization,
                    chunk_size=self.chunk_size,
                    index=resolved.index,
                    verify=self.verify,
                    codec=resolved.codec,
                    executor=self.executor.view(entry.tenant),
                    access_cache=access_cache,
                    prefetch_cache=prefetch_cache,
                    resolver=self.device_engine,
                )
                entry.codec = entry.reader.codec.tag
            except BaseException:
                # Corrupt/non-gzip source, torn index blob, or a pool fault:
                # return the caches to the pool and close the remote reader
                # we opened, or client retries would grow connections and
                # registrations without bound. ParallelGzipReader's own
                # constructor already tears down what it reached (fetcher,
                # caches, file handle); this backstop covers failures before
                # the reader constructor ran (identity probe, index store)
                # and is harmless after it — PooledCache.release and
                # FileReader.close are idempotent.
                if access_cache is not None:
                    access_cache.release()
                    prefetch_cache.release()
                if block_cache is not None:
                    block_cache.release()  # idempotent if close() already did
                if source is not entry.source and hasattr(source, "close"):
                    source.close()  # twin paths are plain strings: no-op
                raise
            return entry.reader

    def _maybe_transcode(self, entry: _Entry, reader: ParallelGzipReader) -> None:
        """Offer a freshly finalized first pass to the transcoder, once.

        Called from the read paths after the reader worked: only a
        *finalized* index carries the first-pass observations the hostility
        score needs, and only an origin-bound handle should probe (a twin is
        the transcode's output, never its input). Remote origins are skipped
        — re-encoding somebody else's URL into a local twin would pin the
        fleet's placement to this node. The probed flag is a benign race:
        the manager dedups by identity.
        """
        mgr = self.transcoder
        if (
            mgr is None
            or entry.twin is not None
            or entry.transcode_probed
            or not reader.index.finalized
            or is_remote_url(entry.source)
        ):
            return
        entry.transcode_probed = True
        try:
            mgr.consider(entry.identity, entry.source, reader)
        except Exception:  # noqa: BLE001 - background QoS must not fail reads
            pass

    # ------------------------------------------------------------------
    # request API
    # ------------------------------------------------------------------

    def read_range(
        self, handle: str, offset: int, size: int, *, serialized: bool = False
    ) -> bytes:
        """Decompressed bytes [offset, offset+size) — short at EOF.

        Concurrent and stateless: no per-handle cursor, no entry lock. The
        entry lock is taken only inside ``_ensure_reader`` when this is the
        first touch of a lazily-opened handle; after that, N threads on one
        handle proceed in parallel (index-covered ranges entirely lock-free,
        frontier advancement serialized inside the reader one chunk at a
        time). ``serialized=True`` restores the legacy discipline — entry
        lock around a shared-cursor seek+read — kept for A/B benchmarking.
        """
        if offset < 0 or size < 0:
            raise ValueError("offset and size must be non-negative")
        entry = self._entry(handle)
        # Always-on latency boundary: the duration histogram records even
        # while tracing is off; with tracing on this is the read's span (the
        # root, unless a gateway request is already the current context).
        read_span = _obs_trace.timed(
            "server.read_range",
            {
                "handle": handle,
                "tenant": entry.tenant,
                "offset": offset,
                "size": size,
                "serialized": serialized,
            },
        )
        t0 = time.perf_counter()
        with read_span:
            reader = entry.reader
            if reader is None:
                reader = self._ensure_reader(entry)
            with entry.cond:
                # Register under the close handshake: after this, close()
                # waits for us before tearing the reader (and its fd) down.
                if entry.closed:
                    raise KeyError("unknown or closed handle %r" % handle)
                entry.in_flight += 1
            with self._gauge_lock:
                self._reads_in_flight += 1
                self._reads_started += 1
                if serialized:
                    self._reads_serialized += 1
            try:
                if serialized:
                    with entry.lock:
                        reader.seek(offset)
                        data = reader.read(size)
                else:
                    data = reader.pread(offset, size)
            finally:
                with self._gauge_lock:
                    self._reads_in_flight -= 1
                with entry.cond:
                    entry.in_flight -= 1
                    if entry.in_flight == 0:
                        entry.cond.notify_all()
        duration = time.perf_counter() - t0
        if self._slow_request_s is not None and duration >= self._slow_request_s:
            self._log_slow_read(entry, offset, size, duration, read_span)
        with entry.cond:
            entry.reads += 1
            entry.bytes_served += len(data)
        self._maybe_transcode(entry, reader)
        return data

    def _log_slow_read(
        self, entry: _Entry, offset: int, size: int, duration: float, read_span
    ) -> None:
        """Record one over-threshold read; attach its span tree if traced."""
        record: Dict[str, Any] = {
            "ts": time.time(),
            "handle": entry.handle,
            "tenant": entry.tenant,
            "offset": offset,
            "size": size,
            "duration_s": round(duration, 6),
            "trace_id": getattr(read_span, "trace_id", None),
        }
        if record["trace_id"] is not None:
            tree = _obs_trace.span_tree(record["trace_id"])
            t_first = tree[0]["ts"] if tree else 0.0
            record["spans"] = [
                {
                    "name": s["name"],
                    "start_offset_s": round(s["ts"] - t_first, 6),
                    "dur_s": round(s["dur_s"], 6),
                    "span_id": s["span_id"],
                    "parent_id": s["parent_id"],
                    "thread": s["thread_name"],
                }
                for s in tree
            ]
        with self._slow_lock:
            self._slow_log.append(record)

    def read_many(
        self, requests: Sequence[Tuple[str, int, int]]
    ) -> List[bytes]:
        """Serve many ``(handle, offset, size)`` ranges, in order.

        Runs sequentially in the calling thread — the parallelism callers
        want lives either in their own threads (each calling read_range) or
        in `AsyncArchiveServer.read_many`, which fans these out across the
        front-end bridge concurrently.
        """
        return [self.read_range(h, off, size) for h, off, size in requests]

    def stat(self, handle: str) -> ArchiveStat:
        """Lock-free snapshot of one handle.

        Deliberately does NOT take the entry lock: a long first-pass read (or
        a slow lazy open) on the same handle must not make telemetry hang.
        The index reports through its own internal lock; the counters come
        from the stats micro-lock; `opened` reflects the reader reference at
        the instant of the call.
        """
        entry = self._entry(handle)
        reader = entry.reader
        index = reader.index if reader is not None else None
        with entry.cond:
            reads, bytes_served = entry.reads, entry.bytes_served
        return ArchiveStat(
            handle=handle,
            tenant=entry.tenant,
            opened=reader is not None,
            compressed_size=(
                index.compressed_size if index is not None else None
            ),
            decompressed_size=(
                index.decompressed_size if index is not None else None
            ),
            index_points=len(index) if index is not None else 0,
            index_finalized=bool(index.finalized) if index is not None else False,
            index_was_warm=entry.index_was_warm,
            reads=reads,
            bytes_served=bytes_served,
            identity=entry.identity,
            codec=entry.codec,
            twin=entry.twin,
        )

    def size(self, handle: str) -> int:
        """Decompressed size (drives the first pass to completion).

        No entry lock: the reader's own frontier lock serializes the first
        pass, and concurrent read_range calls on the same handle keep
        flowing while it completes.
        """
        entry = self._entry(handle)
        reader = entry.reader
        if reader is None:
            reader = self._ensure_reader(entry)
        with entry.cond:
            if entry.closed:
                raise KeyError("unknown or closed handle %r" % handle)
            entry.in_flight += 1
        try:
            return reader.size()
        finally:
            with entry.cond:
                entry.in_flight -= 1
                if entry.in_flight == 0:
                    entry.cond.notify_all()
            self._maybe_transcode(entry, reader)

    def cancel_queued(self, handle: str) -> int:
        """Cancel the handle's queued batch-lane prefetch tasks, if idle.

        The gateway calls this when a client disconnects mid-stream: the
        speculation that client motivated should stop consuming executor
        bandwidth. Scoped to the handle's reader view and to the *batch*
        lane only, and skipped entirely while other reads are in flight on
        the handle (their latency-hiding prefetches stay). Cancelled tasks
        are booked under the executor's ``cancelled`` counter, so
        ``submitted == done + cancelled + queued`` always balances.
        """
        entry = self._entry(handle)
        reader = entry.reader
        if reader is None:
            return 0
        with entry.cond:
            if entry.closed or entry.in_flight:
                return 0
        return reader.cancel_prefetches()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def persist_index(self, handle: str) -> Optional[str]:
        """Store the handle's index if finalized; returns the store key.

        Twin-bound handles never persist: their live index describes the
        *twin's* byte layout, and `entry.identity` keys the *origin* — a put
        here would poison the origin's index slot for every non-twin open.
        The origin's own finalized index was persisted by the transcoder at
        schedule time.
        """
        entry = self._entry(handle)
        with entry.lock:
            if (
                entry.reader is None
                or entry.twin is not None
                or not entry.reader.index.finalized
            ):
                return None
            return self.index_store.put(entry.identity, entry.reader.index)

    def index_blob(self, handle: str) -> Optional[tuple]:
        """(identity key, finalized index blob) for a handle, else None.

        The serving side of the fleet index exchange: a live finalized
        reader serializes its in-memory index; a lazy (never-read) handle
        can still be served from the local store if a previous session
        persisted it. Non-finalized indexes are never exported — an importer
        would trust seek points that the speculative pass has not confirmed.
        Twin-bound handles fall through to the store: a peer asking for this
        identity wants the *origin's* index (it holds the origin's bytes),
        not the local twin's layout.
        """
        entry = self._entry(handle)
        with entry.lock:
            if (
                entry.reader is not None
                and entry.twin is None
                and entry.reader.index.finalized
            ):
                return entry.identity, entry.reader.index.to_bytes()
            if entry.identity is not None:
                blob = self.index_store.get_blob(entry.identity)
                if blob is not None:
                    return entry.identity, blob
        return None

    def close(self, handle: str, *, persist_index: bool = True) -> None:
        entry = self._entry(handle)
        with entry.cond:
            if entry.closed:
                return
            # Refuse new reads first, then drain the in-flight ones: the
            # reader's file handle must not close under a lock-free pread
            # (EBADF at best; with fd-number reuse, bytes from a different
            # file at worst). Like the old entry-lock discipline, close
            # waits for reads already admitted — but no longer blocks
            # telemetry or other handles while it does.
            entry.closed = True
            while entry.in_flight:
                entry.cond.wait()
        with entry.lock:
            if entry.reader is not None:
                # Twin-bound handles skip the persist: entry.identity keys
                # the origin, but the live index maps the twin's bytes.
                if (
                    persist_index
                    and entry.twin is None
                    and entry.reader.index.finalized
                ):
                    self.index_store.put(entry.identity, entry.reader.index)
                # Reader close cancels its own queued tasks (view-scoped —
                # the tenant may have other files open), releases its pooled
                # caches back to the budget, and leaves the server-owned
                # executor running.
                entry.reader.close()
        with self._lock:
            self._entries.pop(handle, None)

    def close_all(self, *, persist_indexes: bool = True) -> None:
        with self._lock:
            handles = list(self._entries)
        for h in handles:
            try:
                self.close(h, persist_index=persist_indexes)
            except KeyError:
                pass

    def shutdown(self) -> None:
        # Refuse new opens *before* draining the registry: an open() racing
        # into the gap would register an entry nothing ever closes, and its
        # reads would hit the shut-down executor.
        with self._lock:
            self._closed = True
        # Stop the transcoder before the executor: closed managers fail
        # their in-flight jobs cleanly (tmp twins unlinked) instead of
        # racing cancelled futures through half a span chain.
        if self._owns_transcode and self.transcoder is not None:
            self.transcoder.close()
        self.close_all()
        self.executor.shutdown(wait=False, cancel_futures=True)
        # After the executor: no pool worker can submit to the engine once
        # the pool is down, so queued engine futures error instead of hang.
        if self._owns_engine and self.device_engine is not None:
            self.device_engine.shutdown()

    def __enter__(self) -> "ArchiveServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------

    def metrics(self) -> Dict[str, Any]:
        """Fleet-wide snapshot (see service/metrics.py for the layout).

        Lock-free with respect to reads: reader stats are atomic cache
        snapshots and the per-entry counters sit behind their micro-lock, so
        a telemetry poll never stalls (or is stalled by) a long read.
        """
        reports: Dict[str, Dict[str, Any]] = {}
        per_file: Dict[str, Dict[str, Any]] = {}
        with self._lock:
            entries = list(self._entries.values())
        for entry in entries:
            if entry.closed:
                continue
            reader = entry.reader
            if reader is not None:
                reports[entry.handle] = reader.stats()
            with entry.cond:
                reads, bytes_served = entry.reads, entry.bytes_served
            per_file[entry.handle] = {
                "tenant": entry.tenant,
                "reads": reads,
                "bytes_served": bytes_served,
                "index_was_warm": entry.index_was_warm,
                "opened": reader is not None,
                "codec": entry.codec,
                "twin": entry.twin,
            }
        with self._gauge_lock:
            service = {
                "reads_in_flight": self._reads_in_flight,
                "reads_started": self._reads_started,
                "reads_serialized": self._reads_serialized,
            }
            self._snapshot_seq += 1
            seq = self._snapshot_seq
        with self._slow_lock:
            slow = list(self._slow_log)
        obs_section = {
            "tracing": _obs_trace.tracing_stats(),
            "histograms": _obs_hist.histogram_snapshots(),
            "slow_request_threshold_s": self._slow_request_s,
            "slow_requests": slow,
        }
        snap = _metrics.collect(
            reader_reports=reports,
            per_file=per_file,
            pool=self.cache_pool,
            executor=self.executor,
            index_store=self.index_store,
            service=service,
            engine=self.device_engine,
            transcode=self.transcoder,
            obs=obs_section,
        )
        # Snapshot provenance: wall timestamp for scrape alignment, a
        # monotonic uptime for rate windows, and a sequence number whose
        # reset (alongside uptime) is the restart signal.
        snap["ts"] = time.time()
        snap["uptime_s"] = round(time.monotonic() - self._started_mono, 3)
        snap["snapshot_seq"] = seq
        return snap
