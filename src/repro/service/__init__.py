"""Archive service: multi-file, multi-client random-access decompression.

Lifts the paper's single-reader cache/prefetch architecture (§3.2) to a
fleet: many `ParallelGzipReader`s behind one shared memory budget
(`CachePool`), one shared decompression thread pool with per-tenant fairness
(`FairExecutor`), a persistent seek-index store so repeat opens skip the
speculative first pass (`IndexStore`), and fleet-wide telemetry (`metrics`).

    from repro.service import ArchiveServer, IndexStore

    with ArchiveServer(cache_budget_bytes=32 << 20,
                       index_store=IndexStore("/var/cache/rpgz")) as srv:
        h = srv.open("corpus-00.json.gz", tenant="search")
        page = srv.read_range(h, 10 << 20, 4096)

`read_range` is stateless and concurrent — N threads on one handle scale
without a shared cursor (see server.py's concurrency contract). For asyncio
services, `AsyncArchiveServer` bridges the same calls off the event loop:

    from repro.service import AsyncArchiveServer

    async with AsyncArchiveServer(cache_budget_bytes=32 << 20) as srv:
        h = await srv.open("corpus-00.json.gz", tenant="search")
        pages = await srv.read_many([(h, off, 4096) for off in offsets])

For network clients, the `gateway` subpackage puts all of this behind an
HTTP/1.1 wire protocol (range reads, chunked streaming, cancellation
propagation, per-tenant admission control) with a FileReader-shaped client:

    from repro.service.gateway import GatewayServer, GatewayClient

    with GatewayServer(cache_budget_bytes=32 << 20) as gw:
        page = GatewayClient(gw.url, source="corpus-00.json.gz").pread(0, 4096)

One gateway is one machine's ceiling; the `fleet` subpackage shards
archives across N gateway peers by rendezvous hashing of file identity,
with health-probe membership, mid-stream failover via exact Range resume,
and cross-node seek-index exchange (a cold open on one node imports the
index another node already built):

    from repro.service.fleet import FleetRouter

    with FleetRouter([gw1.url, gw2.url, gw3.url]) as router:
        page = router.open("corpus-00.json.gz").pread(0, 4096)
"""

from .async_server import AsyncArchiveServer
from .cache_pool import ACCESS, PREFETCH, CachePool, PooledCache, TenantStats, default_size_of
from .index_store import IndexStore, IndexStoreStats, file_identity
from .metrics import aggregate_reader_reports, collect, format_summary
from .scheduler import FairExecutor, TenantExecutor
from .server import ArchiveServer, ArchiveStat
from .gateway import (  # noqa: E402 - gateway builds on the modules above
    AdmissionDenied,
    GatewayClient,
    GatewayError,
    GatewayServer,
    TenantAdmission,
)
from .fleet import (  # noqa: E402 - fleet builds on the gateway
    FleetClient,
    FleetMembership,
    FleetRouter,
    FleetUnavailable,
    make_index_fallback,
)

__all__ = [
    "ACCESS",
    "PREFETCH",
    "AdmissionDenied",
    "ArchiveServer",
    "ArchiveStat",
    "AsyncArchiveServer",
    "CachePool",
    "FairExecutor",
    "FleetClient",
    "FleetMembership",
    "FleetRouter",
    "FleetUnavailable",
    "GatewayClient",
    "GatewayError",
    "GatewayServer",
    "TenantAdmission",
    "IndexStore",
    "IndexStoreStats",
    "PooledCache",
    "TenantExecutor",
    "TenantStats",
    "aggregate_reader_reports",
    "collect",
    "default_size_of",
    "file_identity",
    "format_summary",
    "make_index_fallback",
]
