"""CachePool — one memory budget shared by a fleet of readers.

`core/cache.py` gives every reader two private caches (access + prefetch,
paper §3.2). That is the right shape for one reader over one file; a service
multiplexing dozens of `ParallelGzipReader`s cannot let each of them size its
caches independently — worst-case memory is the *sum* of per-reader maxima.

The pool lifts the paper's two-tier split fleet-wide:

  * one **byte** budget, split into an *access tier* and a *prefetch tier*
    with separate sub-budgets — prefetch churn from any reader can never
    evict any reader's explicitly-accessed chunks (the paper's pollution
    argument, now across files and tenants);
  * **cost-aware LRU** within each tier: candidates come from the LRU end,
    but within a small recency window the victim is the entry with the
    lowest *recompute cost per byte* — a zlib-delegable indexed chunk
    (re-decodable >2x faster than two-stage, paper §1.3) goes before a
    marker-mode first-pass chunk of similar age. Inserters declare the cost
    via ``insert_hinted``; unhinted entries default to cost == size, which
    degrades to plain global LRU. Aging bounds the bias: an entry passed
    over ``EVICTION_WINDOW`` times without a hit is evicted regardless of
    cost, so cold expensive entries cannot pin the tier;
  * per-tenant accounting (bytes held, insertions, evictions suffered/caused,
    cumulative recompute cost of evicted entries) plus soft isolation with
    **weighted shares**: a tenant holding more than
    ``max_tenant_fraction * weight(tenant)`` of a tier evicts its *own*
    entries first, so one hot client cannot monopolize the pool, and
    operators can grant paying tenants a larger slice
    (``set_tenant_weight``).

Member caches are `PooledCache` — drop-in `LRUCache` subclasses, so the chunk
fetcher uses them unchanged via its injectable-cache hooks.

Lock ordering: a member cache always releases its own lock before calling
into the pool, and the pool releases its lock before touching a member cache
(victim eviction collects keys under the pool lock, then pops outside it).
Budget overshoot during the gap is transient and bounded by one entry.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, List, Optional, Tuple

from ..core.cache import CacheStats, LRUCache

ACCESS = "access"
PREFETCH = "prefetch"

#: How many LRU-end entries compete per victim selection. Small: recency
#: stays the primary signal, cost only breaks near-ties — a hot expensive
#: entry is never outlived by a cold cheap one outside the window.
EVICTION_WINDOW = 8


def default_size_of(value: Any) -> int:
    """Approximate resident bytes of a cached value.

    Cached values are numpy arrays (indexed chunks), DecodeResult objects
    (first-pass chunks, dominated by their ``data`` array), or bytes.
    """
    nbytes = getattr(value, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    data = getattr(value, "data", None)
    if data is not None and hasattr(data, "nbytes"):
        return int(data.nbytes) + 256  # DecodeResult bookkeeping overhead
    try:
        return len(value)
    except TypeError:
        return 1024


@dataclass
class TenantStats:
    bytes_held: int = 0
    insertions: int = 0
    hits: int = 0
    misses: int = 0
    evictions_suffered: int = 0  # this tenant's entries evicted
    evictions_caused: int = 0  # evictions triggered by this tenant's inserts
    eviction_cost_suffered: int = 0  # recompute cost of this tenant's victims
    eviction_cost_caused: int = 0  # recompute cost this tenant's inserts evicted

    def as_dict(self) -> Dict[str, int]:
        return {k: int(getattr(self, k)) for k in self.__dataclass_fields__}


@dataclass
class _Entry:
    cache: Any  # PooledCache
    size: int
    cost: int  # estimated bytes-of-work to recompute the value if evicted
    skips: int = 0  # times passed over for a cheaper, younger victim


@dataclass
class _Tier:
    budget: int
    held: int = 0
    # (cache_id, key) -> _Entry; order = LRU .. MRU
    entries: "OrderedDict[Tuple[int, Hashable], _Entry]" = field(
        default_factory=OrderedDict
    )
    evictions: int = 0
    evicted_bytes: int = 0
    evicted_cost: int = 0


class PooledCache(LRUCache):
    """LRUCache whose contents are charged against a shared CachePool.

    Keeps an (optional) per-cache entry capacity on top of the pool's byte
    budget — the fetcher still relies on a size-1 access cache meaning
    "current chunk only".
    """

    def __init__(self, pool: "CachePool", tier: str, tenant: str, capacity: Optional[int]):
        super().__init__(capacity if capacity is not None else 1 << 30)
        self._pool = pool
        self._tier = tier
        self.tenant = tenant
        self._cache_id = pool._next_cache_id()
        # Flipped under the pool lock by _deregister: inserts racing a
        # release (an in-flight decompression task finishing after its
        # reader closed — possible now that reads don't hold the entry
        # lock) must not re-charge the ledger of a deregistered cache.
        self._pool_registered = True

    # Mutations run the base core under the cache lock, then report to the
    # pool after releasing it (see lock-ordering note in the module doc).

    def get(self, key: Hashable) -> Optional[Any]:
        return self.lookup(key)

    def lookup(self, key: Hashable, *, record_miss: bool = True) -> Optional[Any]:
        with self._lock:
            hit, val = self._get_locked(key, record_miss=record_miss)
        self._pool._on_lookup(self, key, hit, record_miss=record_miss)
        return val

    def insert(self, key: Hashable, value: Any) -> None:
        self.insert_hinted(key, value)

    def insert_hinted(
        self, key: Hashable, value: Any, *, recompute_cost: Optional[int] = None
    ) -> None:
        with self._lock:
            _, evicted = self._insert_locked(key, value)
        self._pool._on_insert(self, key, value, evicted, recompute_cost=recompute_cost)

    def pop(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            val = self._pop_locked(key)
        if val is not None:
            self._pool._forget(self, [key])
        return val

    def clear(self) -> None:
        with self._lock:
            keys = list(self._data.keys())
            self._data.clear()
        self._pool._forget(self, keys)

    def _evict_for_pool(self, key: Hashable) -> None:
        """Pool-chosen victim: remove locally without calling back."""
        with self._lock:
            if self._data.pop(key, None) is not None:
                self.stats.evictions += 1

    def release(self) -> None:
        """Empty this cache and deregister it from the pool.

        Must be called when the owning reader closes (the fetcher's shutdown
        does) — otherwise dead caches would pin their bytes against the tier
        budget and accumulate in the pool registry forever.
        """
        self.clear()
        self._pool._deregister(self)


class CachePool:
    def __init__(
        self,
        budget_bytes: int,
        *,
        access_fraction: float = 0.25,
        max_tenant_fraction: float = 0.5,
        size_of=default_size_of,
    ):
        if budget_bytes < 1:
            raise ValueError("budget_bytes must be >= 1")
        if not 0.0 < access_fraction < 1.0:
            raise ValueError("access_fraction must be in (0, 1)")
        self.budget_bytes = budget_bytes
        self.max_tenant_fraction = max_tenant_fraction
        self._size_of = size_of
        self._lock = threading.RLock()
        self._tiers: Dict[str, _Tier] = {
            ACCESS: _Tier(max(1, int(budget_bytes * access_fraction))),
            PREFETCH: _Tier(max(1, budget_bytes - int(budget_bytes * access_fraction))),
        }
        self._tenants: Dict[str, TenantStats] = {}
        self._tenant_weights: Dict[str, float] = {}
        self._cache_id_seq = 0
        self._caches: List[PooledCache] = []

    def set_tenant_weight(self, tenant: str, weight: float) -> None:
        """Scale ``tenant``'s soft-isolation share: its per-tier cap becomes
        ``budget * max_tenant_fraction * weight`` (default weight 1.0)."""
        if weight <= 0:
            raise ValueError("weight must be > 0")
        with self._lock:
            self._tenant_weights[tenant] = float(weight)

    # -- construction -------------------------------------------------------

    def _next_cache_id(self) -> int:
        with self._lock:
            self._cache_id_seq += 1
            return self._cache_id_seq

    def cache(self, *, tier: str, tenant: str = "default", capacity: Optional[int] = None) -> PooledCache:
        """New member cache charged to ``tenant`` in ``tier``."""
        if tier not in self._tiers:
            raise ValueError("tier must be one of %r" % sorted(self._tiers))
        c = PooledCache(self, tier, tenant, capacity)
        with self._lock:
            self._tenants.setdefault(tenant, TenantStats())
            self._caches.append(c)
        return c

    def reader_caches(
        self, tenant: str, *, access_capacity: int = 4, prefetch_capacity: Optional[int] = None
    ) -> Tuple[PooledCache, PooledCache]:
        """(access_cache, prefetch_cache) pair for one ParallelGzipReader."""
        return (
            self.cache(tier=ACCESS, tenant=tenant, capacity=access_capacity),
            self.cache(tier=PREFETCH, tenant=tenant, capacity=prefetch_capacity),
        )

    # -- member-cache callbacks --------------------------------------------

    def _on_lookup(
        self, cache: PooledCache, key: Hashable, hit: bool, record_miss: bool = True
    ) -> None:
        with self._lock:
            tier = self._tiers[cache._tier]
            stats = self._tenants.setdefault(cache.tenant, TenantStats())
            if hit:
                stats.hits += 1
                entry = tier.entries.pop((cache._cache_id, key), None)
                if entry is not None:
                    entry.skips = 0  # re-accessed: young again for aging
                    tier.entries[(cache._cache_id, key)] = entry  # move to MRU
            elif record_miss:
                stats.misses += 1

    def _on_insert(
        self,
        cache: PooledCache,
        key: Hashable,
        value: Any,
        evicted: List[Tuple[Hashable, Any]],
        recompute_cost: Optional[int] = None,
    ) -> None:
        size = self._size_of(value)
        # Unhinted entries cost exactly their size: uniform cost density, so
        # victim selection degrades to plain global LRU.
        cost = size if recompute_cost is None else max(0, int(recompute_cost))
        victims: List[Tuple[PooledCache, Hashable]] = []
        with self._lock:
            if not cache._pool_registered:
                # Released cache: nobody will ever deregister this charge
                # again, so booking it would leak tier.held bytes for good.
                # The orphaned value sits only in the abandoned member dict.
                return
            tier = self._tiers[cache._tier]
            stats = self._tenants.setdefault(cache.tenant, TenantStats())
            for k, _ in evicted:  # entry-capacity evictions inside the cache
                self._forget_locked(tier, cache, k)
            # Unconditionally decharge any prior ledger entry for this key —
            # not just when `replaced` says so: two same-key inserts can race
            # in the gap between the cache lock and this pool lock, and an
            # overwrite without decharge would leak bytes into tier.held
            # permanently.
            self._forget_locked(tier, cache, key)
            tier.entries[(cache._cache_id, key)] = _Entry(cache, size, cost)
            tier.held += size
            stats.bytes_held += size
            stats.insertions += 1
            victims = self._select_victims_locked(tier, cache, key, stats)
        for victim_cache, victim_key in victims:
            victim_cache._evict_for_pool(victim_key)

    def _tenant_cap_locked(self, tier: _Tier, tenant: str) -> int:
        weight = self._tenant_weights.get(tenant, 1.0)
        return int(tier.budget * self.max_tenant_fraction * weight)

    def _select_victims_locked(
        self, tier: _Tier, cache: PooledCache, new_key: Hashable, inserter: TenantStats
    ) -> List[Tuple[PooledCache, Hashable]]:
        victims: List[Tuple[PooledCache, Hashable]] = []

        def take(pred) -> bool:
            # Cost-aware LRU: among the first EVICTION_WINDOW matching
            # entries from the LRU end, evict the one cheapest to recompute
            # per byte. Ties (and unhinted entries, cost == size) fall back
            # to strict LRU order. Aging keeps expensive entries mortal: an
            # entry passed over EVICTION_WINDOW times — a full window of
            # younger victims died around it without it being re-accessed —
            # is evicted regardless of cost (a lookup resets the counter).
            best_key = None
            best_density = None
            scanned = []
            for (cid, k), e in tier.entries.items():
                if (cid, k) == (cache._cache_id, new_key):
                    continue  # never evict the entry being inserted
                if not pred(e.cache):
                    continue
                if e.skips >= EVICTION_WINDOW:
                    best_key = (cid, k)
                    break
                density = e.cost / max(1, e.size)
                if best_density is None or density < best_density:
                    best_key = (cid, k)
                    best_density = density
                scanned.append(((cid, k), e))
                if len(scanned) >= EVICTION_WINDOW:
                    break
            if best_key is None:
                return False
            for key_e, e in scanned:  # only entries older than the victim age
                if key_e == best_key:
                    break
                e.skips += 1
            e = tier.entries.pop(best_key)
            tier.held -= e.size
            tier.evictions += 1
            tier.evicted_bytes += e.size
            tier.evicted_cost += e.cost
            owner = self._tenants.setdefault(e.cache.tenant, TenantStats())
            owner.bytes_held -= e.size
            owner.evictions_suffered += 1
            owner.eviction_cost_suffered += e.cost
            inserter.evictions_caused += 1
            inserter.eviction_cost_caused += e.cost
            victims.append((e.cache, best_key[1]))
            return True

        # Soft isolation: a tenant over its (weighted) fair share sheds its
        # own entries before anyone else's.
        tenant_cap = self._tenant_cap_locked(tier, cache.tenant)
        while inserter.bytes_held > tenant_cap and tier.held > tier.budget:
            if not take(lambda c: c.tenant == cache.tenant):
                break
        while tier.held > tier.budget:
            if not take(lambda c: True):
                break  # only the new entry remains; let it stay
        return victims

    def _forget(self, cache: PooledCache, keys: List[Hashable]) -> None:
        with self._lock:
            tier = self._tiers[cache._tier]
            for k in keys:
                self._forget_locked(tier, cache, k)

    def _deregister(self, cache: PooledCache) -> None:
        """Remove a released cache (and any ledger remnants) from the pool."""
        with self._lock:
            cache._pool_registered = False
            tier = self._tiers[cache._tier]
            stale = [key for key in tier.entries if key[0] == cache._cache_id]
            for key in stale:
                self._forget_locked(tier, cache, key[1])
            try:
                self._caches.remove(cache)
            except ValueError:
                pass

    def _forget_locked(self, tier: _Tier, cache: PooledCache, key: Hashable) -> None:
        entry = tier.entries.pop((cache._cache_id, key), None)
        if entry is not None:
            tier.held -= entry.size
            owner = self._tenants.setdefault(cache.tenant, TenantStats())
            owner.bytes_held -= entry.size

    # -- introspection ------------------------------------------------------

    def bytes_held(self, tier: Optional[str] = None) -> int:
        with self._lock:
            if tier is not None:
                return self._tiers[tier].held
            return sum(t.held for t in self._tiers.values())

    def tenant_stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {name: s.as_dict() for name, s in self._tenants.items()}

    def snapshot(self) -> Dict[str, Any]:
        """Atomic pool-wide view: tier occupancy + per-tenant accounting +
        merged member-cache stats (via CacheStats.merge)."""
        with self._lock:
            tiers = {
                name: {
                    "budget": t.budget,
                    "held": t.held,
                    "entries": len(t.entries),
                    "evictions": t.evictions,
                    "evicted_bytes": t.evicted_bytes,
                    "evicted_cost": t.evicted_cost,
                }
                for name, t in self._tiers.items()
            }
            tenants = {name: s.as_dict() for name, s in self._tenants.items()}
            weights = dict(self._tenant_weights)
            caches = list(self._caches)
        merged = CacheStats().merge(*(c.snapshot()["stats"] for c in caches))
        return {
            "budget_bytes": self.budget_bytes,
            "tiers": tiers,
            "tenants": tenants,
            "tenant_weights": weights,
            "merged_cache_stats": merged.as_dict(),
            "n_caches": len(caches),
        }
