"""CachePool — one memory budget shared by a fleet of readers.

`core/cache.py` gives every reader two private caches (access + prefetch,
paper §3.2). That is the right shape for one reader over one file; a service
multiplexing dozens of `ParallelGzipReader`s cannot let each of them size its
caches independently — worst-case memory is the *sum* of per-reader maxima.

The pool lifts the paper's two-tier split fleet-wide:

  * one **byte** budget, split into an *access tier* and a *prefetch tier*
    with separate sub-budgets — prefetch churn from any reader can never
    evict any reader's explicitly-accessed chunks (the paper's pollution
    argument, now across files and tenants);
  * global LRU within each tier: the victim is the least-recently-used entry
    across *all* member caches of that tier;
  * per-tenant accounting (bytes held, insertions, evictions suffered/caused)
    plus soft isolation: a tenant holding more than ``max_tenant_fraction``
    of a tier evicts its *own* LRU entries first, so one hot client cannot
    monopolize the pool.

Member caches are `PooledCache` — drop-in `LRUCache` subclasses, so the chunk
fetcher uses them unchanged via its injectable-cache hooks.

Lock ordering: a member cache always releases its own lock before calling
into the pool, and the pool releases its lock before touching a member cache
(victim eviction collects keys under the pool lock, then pops outside it).
Budget overshoot during the gap is transient and bounded by one entry.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, List, Optional, Tuple

from ..core.cache import CacheStats, LRUCache

ACCESS = "access"
PREFETCH = "prefetch"


def default_size_of(value: Any) -> int:
    """Approximate resident bytes of a cached value.

    Cached values are numpy arrays (indexed chunks), DecodeResult objects
    (first-pass chunks, dominated by their ``data`` array), or bytes.
    """
    nbytes = getattr(value, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    data = getattr(value, "data", None)
    if data is not None and hasattr(data, "nbytes"):
        return int(data.nbytes) + 256  # DecodeResult bookkeeping overhead
    try:
        return len(value)
    except TypeError:
        return 1024


@dataclass
class TenantStats:
    bytes_held: int = 0
    insertions: int = 0
    hits: int = 0
    misses: int = 0
    evictions_suffered: int = 0  # this tenant's entries evicted
    evictions_caused: int = 0  # evictions triggered by this tenant's inserts

    def as_dict(self) -> Dict[str, int]:
        return {k: int(getattr(self, k)) for k in self.__dataclass_fields__}


@dataclass
class _Tier:
    budget: int
    held: int = 0
    # (cache_id, key) -> (PooledCache, nbytes); order = LRU .. MRU
    entries: "OrderedDict[Tuple[int, Hashable], Tuple[Any, int]]" = field(
        default_factory=OrderedDict
    )
    evictions: int = 0


class PooledCache(LRUCache):
    """LRUCache whose contents are charged against a shared CachePool.

    Keeps an (optional) per-cache entry capacity on top of the pool's byte
    budget — the fetcher still relies on a size-1 access cache meaning
    "current chunk only".
    """

    def __init__(self, pool: "CachePool", tier: str, tenant: str, capacity: Optional[int]):
        super().__init__(capacity if capacity is not None else 1 << 30)
        self._pool = pool
        self._tier = tier
        self.tenant = tenant
        self._cache_id = pool._next_cache_id()

    # Mutations run the base core under the cache lock, then report to the
    # pool after releasing it (see lock-ordering note in the module doc).

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            hit, val = self._get_locked(key)
        self._pool._on_lookup(self, key, hit)
        return val

    def insert(self, key: Hashable, value: Any) -> None:
        with self._lock:
            _, evicted = self._insert_locked(key, value)
        self._pool._on_insert(self, key, value, evicted)

    def pop(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            val = self._pop_locked(key)
        if val is not None:
            self._pool._forget(self, [key])
        return val

    def clear(self) -> None:
        with self._lock:
            keys = list(self._data.keys())
            self._data.clear()
        self._pool._forget(self, keys)

    def _evict_for_pool(self, key: Hashable) -> None:
        """Pool-chosen victim: remove locally without calling back."""
        with self._lock:
            if self._data.pop(key, None) is not None:
                self.stats.evictions += 1

    def release(self) -> None:
        """Empty this cache and deregister it from the pool.

        Must be called when the owning reader closes (the fetcher's shutdown
        does) — otherwise dead caches would pin their bytes against the tier
        budget and accumulate in the pool registry forever.
        """
        self.clear()
        self._pool._deregister(self)


class CachePool:
    def __init__(
        self,
        budget_bytes: int,
        *,
        access_fraction: float = 0.25,
        max_tenant_fraction: float = 0.5,
        size_of=default_size_of,
    ):
        if budget_bytes < 1:
            raise ValueError("budget_bytes must be >= 1")
        if not 0.0 < access_fraction < 1.0:
            raise ValueError("access_fraction must be in (0, 1)")
        self.budget_bytes = budget_bytes
        self.max_tenant_fraction = max_tenant_fraction
        self._size_of = size_of
        self._lock = threading.RLock()
        self._tiers: Dict[str, _Tier] = {
            ACCESS: _Tier(max(1, int(budget_bytes * access_fraction))),
            PREFETCH: _Tier(max(1, budget_bytes - int(budget_bytes * access_fraction))),
        }
        self._tenants: Dict[str, TenantStats] = {}
        self._cache_id_seq = 0
        self._caches: List[PooledCache] = []

    # -- construction -------------------------------------------------------

    def _next_cache_id(self) -> int:
        with self._lock:
            self._cache_id_seq += 1
            return self._cache_id_seq

    def cache(self, *, tier: str, tenant: str = "default", capacity: Optional[int] = None) -> PooledCache:
        """New member cache charged to ``tenant`` in ``tier``."""
        if tier not in self._tiers:
            raise ValueError("tier must be one of %r" % sorted(self._tiers))
        c = PooledCache(self, tier, tenant, capacity)
        with self._lock:
            self._tenants.setdefault(tenant, TenantStats())
            self._caches.append(c)
        return c

    def reader_caches(
        self, tenant: str, *, access_capacity: int = 4, prefetch_capacity: Optional[int] = None
    ) -> Tuple[PooledCache, PooledCache]:
        """(access_cache, prefetch_cache) pair for one ParallelGzipReader."""
        return (
            self.cache(tier=ACCESS, tenant=tenant, capacity=access_capacity),
            self.cache(tier=PREFETCH, tenant=tenant, capacity=prefetch_capacity),
        )

    # -- member-cache callbacks --------------------------------------------

    def _on_lookup(self, cache: PooledCache, key: Hashable, hit: bool) -> None:
        with self._lock:
            tier = self._tiers[cache._tier]
            stats = self._tenants.setdefault(cache.tenant, TenantStats())
            if hit:
                stats.hits += 1
                entry = tier.entries.pop((cache._cache_id, key), None)
                if entry is not None:
                    tier.entries[(cache._cache_id, key)] = entry  # move to MRU
            else:
                stats.misses += 1

    def _on_insert(
        self,
        cache: PooledCache,
        key: Hashable,
        value: Any,
        evicted: List[Tuple[Hashable, Any]],
    ) -> None:
        size = self._size_of(value)
        victims: List[Tuple[PooledCache, Hashable]] = []
        with self._lock:
            tier = self._tiers[cache._tier]
            stats = self._tenants.setdefault(cache.tenant, TenantStats())
            for k, _ in evicted:  # entry-capacity evictions inside the cache
                self._forget_locked(tier, cache, k)
            # Unconditionally decharge any prior ledger entry for this key —
            # not just when `replaced` says so: two same-key inserts can race
            # in the gap between the cache lock and this pool lock, and an
            # overwrite without decharge would leak bytes into tier.held
            # permanently.
            self._forget_locked(tier, cache, key)
            tier.entries[(cache._cache_id, key)] = (cache, size)
            tier.held += size
            stats.bytes_held += size
            stats.insertions += 1
            victims = self._select_victims_locked(tier, cache, key, stats)
        for victim_cache, victim_key in victims:
            victim_cache._evict_for_pool(victim_key)

    def _select_victims_locked(
        self, tier: _Tier, cache: PooledCache, new_key: Hashable, inserter: TenantStats
    ) -> List[Tuple[PooledCache, Hashable]]:
        victims: List[Tuple[PooledCache, Hashable]] = []

        def take(pred) -> bool:
            for (cid, k), (c, sz) in tier.entries.items():
                if (cid, k) == (cache._cache_id, new_key):
                    continue  # never evict the entry being inserted
                if pred(c):
                    del tier.entries[(cid, k)]
                    tier.held -= sz
                    owner = self._tenants.setdefault(c.tenant, TenantStats())
                    owner.bytes_held -= sz
                    owner.evictions_suffered += 1
                    inserter.evictions_caused += 1
                    tier.evictions += 1
                    victims.append((c, k))
                    return True
            return False

        # Soft isolation: a tenant over its fair share sheds its own LRU
        # entries before anyone else's.
        tenant_cap = int(tier.budget * self.max_tenant_fraction)
        while inserter.bytes_held > tenant_cap and tier.held > tier.budget:
            if not take(lambda c: c.tenant == cache.tenant):
                break
        while tier.held > tier.budget:
            if not take(lambda c: True):
                break  # only the new entry remains; let it stay
        return victims

    def _forget(self, cache: PooledCache, keys: List[Hashable]) -> None:
        with self._lock:
            tier = self._tiers[cache._tier]
            for k in keys:
                self._forget_locked(tier, cache, k)

    def _deregister(self, cache: PooledCache) -> None:
        """Remove a released cache (and any ledger remnants) from the pool."""
        with self._lock:
            tier = self._tiers[cache._tier]
            stale = [key for key in tier.entries if key[0] == cache._cache_id]
            for key in stale:
                self._forget_locked(tier, cache, key[1])
            try:
                self._caches.remove(cache)
            except ValueError:
                pass

    def _forget_locked(self, tier: _Tier, cache: PooledCache, key: Hashable) -> None:
        entry = tier.entries.pop((cache._cache_id, key), None)
        if entry is not None:
            _, size = entry
            tier.held -= size
            owner = self._tenants.setdefault(cache.tenant, TenantStats())
            owner.bytes_held -= size

    # -- introspection ------------------------------------------------------

    def bytes_held(self, tier: Optional[str] = None) -> int:
        with self._lock:
            if tier is not None:
                return self._tiers[tier].held
            return sum(t.held for t in self._tiers.values())

    def tenant_stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {name: s.as_dict() for name, s in self._tenants.items()}

    def snapshot(self) -> Dict[str, Any]:
        """Atomic pool-wide view: tier occupancy + per-tenant accounting +
        merged member-cache stats (via CacheStats.merge)."""
        with self._lock:
            tiers = {
                name: {
                    "budget": t.budget,
                    "held": t.held,
                    "entries": len(t.entries),
                    "evictions": t.evictions,
                }
                for name, t in self._tiers.items()
            }
            tenants = {name: s.as_dict() for name, s in self._tenants.items()}
            caches = list(self._caches)
        merged = CacheStats().merge(*(c.snapshot()["stats"] for c in caches))
        return {
            "budget_bytes": self.budget_bytes,
            "tiers": tiers,
            "tenants": tenants,
            "merged_cache_stats": merged.as_dict(),
            "n_caches": len(caches),
        }
