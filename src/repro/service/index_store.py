"""IndexStore — persistent seek-index blobs keyed by file identity.

The paper's index (§1.3/§3.3) is built on the fly during the first pass and
can be exported/imported; with an imported index every read is an indexed
read and the speculative first pass is skipped entirely (paper Fig 9 "with
index"). For a service that reopens the same archives across requests and
restarts, that import path is the difference between O(file) and O(range)
work on every open — so the store makes it automatic: `ArchiveServer`
consults the store on open and persists finalized indexes on close.

Identity is content-addressed cheaply: path + size + mtime_ns for on-disk
files (an edited file gets a new key and a cold first pass — stale indexes
age out of the directory unreferenced), a head/tail content digest for
in-memory buffers, and url + ETag/Last-Modified + size for remote objects
(so a warm index hit skips the speculative first pass without re-downloading
anything beyond a HEAD probe). Blobs are the existing `GzipIndex` binary
format, one file per key under ``root`` (or an in-memory dict when
``root=None``).
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Union

from ..core.filereader import FileReader
from ..core.index import GzipIndex
from ..core.remote import RemoteFileReader, is_remote_url

_EXT = ".rpgzidx"
#: Transcoded-twin slots, all keyed by the *origin's* identity so fleet
#: rendezvous placement never moves when a twin installs:
#:   <key>.twin      — the re-encoded archive bytes (BGZF / zstd-seekable)
#:   <key>.twinidx   — the twin's finalized exact index blob
#:   <key>.twinmeta  — JSON commit record; written *last*, its presence is
#:                     the install point (a crash earlier leaves garbage
#:                     files that resolve_twin ignores)
_TWIN_DATA_EXT = ".twin"
_TWIN_IDX_EXT = ".twinidx"
_TWIN_META_EXT = ".twinmeta"


def file_identity(
    source: Union[str, os.PathLike, bytes, bytearray, memoryview, FileReader],
    *,
    codec: Optional[str] = None,
) -> str:
    """Stable hex key for an archive source.

    Paths hash (realpath, size, mtime_ns) — no bulk content reads, safe for
    huge archives. Byte buffers hash (len, head 64 KiB, tail 64 KiB). Remote
    URLs (and any FileReader exposing ``identity()``) hash (url, ETag or
    Last-Modified, size) — one HEAD round trip, no downloads, and a changed
    object gets a new key so its stale index ages out unreferenced.

    Every branch also mixes in the source's codec tag (``codec=`` to pin it,
    else probed from ≤4 KiB of head bytes): a gzip twin and a zstd twin of
    the same logical content must never collide in the store or in fleet
    rendezvous routing — their indexes have incompatible chunk semantics.
    The probe is deterministic for every caller (router, server, dataset),
    which is what keeps fleet placement consistent.
    """
    if isinstance(source, str) and is_remote_url(source):
        # Small blocks: the probe costs one HEAD, and the codec probe plus
        # the digest fallback (validator-less servers only) a few 64 KiB
        # range GETs, not full-size default blocks. Probing happens on the
        # open reader — a URL string has no local head bytes to sniff.
        with RemoteFileReader(source, block_size=64 << 10, cache_blocks=2) as r:
            return file_identity(r, codec=codec)
    if codec is None:
        from ..core.codec import detect_codec_tag

        codec = detect_codec_tag(source)
    h = hashlib.sha256()
    h.update(b"codec\0")
    h.update(codec.encode())
    if isinstance(source, FileReader):
        ident = source.identity()
        if ident is not None:
            h.update(b"ident\0")
            h.update(ident.encode())
            return h.hexdigest()
        # No cheap identity (e.g. a remote object without validators):
        # fall through to the head/tail content digest below. For an open
        # RemoteFileReader the two 64 KiB preads round out to its block
        # size (up to two full blocks fetched) — bounded, and the blocks
        # stay cached for the header/footer reads that follow an open.
    if isinstance(source, (str, os.PathLike)):
        path = os.path.realpath(os.fspath(source))
        st = os.stat(path)
        h.update(b"path\0")
        h.update(path.encode())
        h.update(str(st.st_size).encode())
        h.update(str(st.st_mtime_ns).encode())
        return h.hexdigest()
    if isinstance(source, (bytes, bytearray, memoryview)):
        data = bytes(source)
        h.update(b"bytes\0")
        h.update(str(len(data)).encode())
        h.update(data[: 64 << 10])
        h.update(data[-(64 << 10):])
        return h.hexdigest()
    if isinstance(source, FileReader):
        size = source.size()
        h.update(b"reader\0")
        h.update(str(size).encode())
        h.update(source.pread(0, 64 << 10))
        h.update(source.pread(max(0, size - (64 << 10)), 64 << 10))
        return h.hexdigest()
    raise TypeError("unsupported source type for identity: %r" % type(source))


@dataclass
class IndexStoreStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    rejected: int = 0  # non-finalized indexes refused
    remote_hits: int = 0  # local misses satisfied by the remote fallback
    remote_misses: int = 0  # fallback consulted and came back empty/invalid
    twin_hits: int = 0  # opens resolved to a transcoded twin
    twin_installs: int = 0  # twins registered (atomic, meta-last)
    twin_rejected: int = 0  # twin registrations refused (unfinalized index)

    def as_dict(self) -> Dict[str, int]:
        return {k: int(getattr(self, k)) for k in self.__dataclass_fields__}


@dataclass
class TwinRecord:
    """A registered transcoded twin of a seek-hostile origin archive.

    ``source`` is what to hand ``ParallelGzipReader``: the twin's data-file
    path for a disk-backed store, or the twin bytes for an in-memory store.
    ``index_blob`` is the twin's finalized exact index. The record is keyed
    by — and carries — the *origin's* identity: ETags, fleet placement, and
    the index-exchange endpoint all keep speaking the origin's name.
    """

    origin_key: str
    codec_tag: str
    source: Any
    index_blob: bytes
    meta: Dict[str, Any] = field(default_factory=dict)


class IndexStore:
    """Get/put of finalized GzipIndex blobs by source identity.

    ``root=None`` keeps blobs in memory (useful for tests and single-process
    services); a path persists them across restarts.

    ``remote_fallback`` is the cross-node index exchange hook: a callable
    ``key -> Optional[bytes]`` consulted on a local miss (e.g. asking fleet
    peers' ``GET /v1/archives/{key}/index``). Fetches are single-flighted
    per key, the returned blob must parse as a *finalized* ``GzipIndex`` or
    it is discarded, and a valid blob is installed locally so later gets hit
    without another network round trip. Identity validation happens on both
    sides: keys are content-addressed (``file_identity``) so the fetcher can
    check the peer's ETag against the very key it asked for, and a blob that
    fails to parse or is unfinalized never reaches a reader.
    """

    def __init__(
        self,
        root: Optional[Union[str, os.PathLike]] = None,
        *,
        remote_fallback: Optional[Callable[[str], Optional[bytes]]] = None,
    ):
        self.root = os.fspath(root) if root is not None else None
        if self.root is not None:
            os.makedirs(self.root, exist_ok=True)
        self._mem: Dict[str, bytes] = {}
        self._twins: Dict[str, TwinRecord] = {}  # root=None twin records
        self._lock = threading.Lock()
        self._fallback = remote_fallback
        self._ff_lock = threading.Lock()
        self._ff_inflight: Dict[str, threading.Event] = {}
        self.stats = IndexStoreStats()

    def set_remote_fallback(
        self, fn: Optional[Callable[[str], Optional[bytes]]]
    ) -> None:
        """Install/replace the fallback after construction (fleet wiring
        happens once every peer's URL is known, after all servers bind)."""
        self._fallback = fn

    # -- keys ---------------------------------------------------------------

    def key_for(self, source, *, codec: Optional[str] = None) -> str:
        if isinstance(source, str) and _is_key(source):
            return source
        return file_identity(source, codec=codec)

    def _path(self, key: str) -> str:
        assert self.root is not None
        return os.path.join(self.root, key + _EXT)

    # -- get/put ------------------------------------------------------------

    def get(self, source) -> Optional[GzipIndex]:
        key = self.key_for(source)
        blob = self._local_blob(key)
        if blob is None and self._fallback is not None:
            blob = self._fetch_remote(key)
        with self._lock:
            if blob is None:
                self.stats.misses += 1
            else:
                self.stats.hits += 1
        return GzipIndex.from_bytes(blob) if blob is not None else None

    def get_blob(self, source) -> Optional[bytes]:
        """Raw local blob by key/source — no fallback, no hit/miss counting.

        This is the serving side of the index exchange (the gateway's
        ``/index`` endpoint): it must never recurse into the fallback (node A
        asking node B asking node A) and must not skew the open-path hit
        rate with exchange traffic.
        """
        return self._local_blob(self.key_for(source))

    def _local_blob(self, key: str) -> Optional[bytes]:
        if self.root is None:
            with self._lock:
                return self._mem.get(key)
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def _install(self, key: str, blob: bytes) -> None:
        if self.root is None:
            with self._lock:
                self._mem[key] = blob
            return
        tmp = "%s.%d.%x.tmp" % (self._path(key), os.getpid(), threading.get_ident())
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self._path(key))

    def _fetch_remote(self, key: str) -> Optional[bytes]:
        """Single-flight remote fetch: concurrent cold opens of the same
        archive share one network fetch; losers wait and read the installed
        blob. A failed fetch lets the next waiter try again (bounded by the
        number of waiting threads), so a transient peer error does not stick."""
        while True:
            with self._ff_lock:
                ev = self._ff_inflight.get(key)
                if ev is None:
                    self._ff_inflight[key] = threading.Event()
                    break
            ev.wait()
            blob = self._local_blob(key)
            if blob is not None:
                return blob
            # Winner failed; fall through and claim the fetch ourselves.
        fallback = self._fallback
        blob = None
        try:
            try:
                raw = fallback(key) if fallback is not None else None
            except Exception:
                # Peer/network faults must degrade to a cold first pass,
                # never fail the open.
                raw = None
            blob = self._validate_remote(raw)
            with self._lock:
                if blob is None:
                    self.stats.remote_misses += 1
                else:
                    self.stats.remote_hits += 1
            if blob is not None:
                self._install(key, blob)
            return blob
        finally:
            with self._ff_lock:
                self._ff_inflight.pop(key).set()

    @staticmethod
    def _validate_remote(raw: Optional[bytes]) -> Optional[bytes]:
        if raw is None:
            return None
        try:
            index = GzipIndex.from_bytes(raw)
        except Exception:
            return None
        return raw if index.finalized else None

    def put(self, source, index: GzipIndex) -> Optional[str]:
        """Persist a *finalized* index; returns its key (None if refused)."""
        if not index.finalized:
            with self._lock:
                self.stats.rejected += 1
            return None
        key = self.key_for(source)
        # _install writes to a unique tmp per writer then renames: two
        # threads closing handles on the same archive race put() for the
        # same key, and a shared '<key>.tmp' would interleave their writes
        # before the rename, installing a torn blob despite the atomic
        # replace.
        self._install(key, index.to_bytes())
        with self._lock:
            self.stats.puts += 1
        return key

    # -- transcoded twins ---------------------------------------------------

    def _twin_paths(self, key: str) -> Dict[str, str]:
        assert self.root is not None
        base = os.path.join(self.root, key)
        return {
            "data": base + _TWIN_DATA_EXT,
            "idx": base + _TWIN_IDX_EXT,
            "meta": base + _TWIN_META_EXT,
        }

    def twin_tmp_path(self, source) -> Optional[str]:
        """Where a transcoder should stream the twin-in-progress: a unique
        tmp path next to the final data file (same filesystem, so the
        install ``os.replace`` is atomic), or None for an in-memory store
        (stream to a buffer and pass bytes to :meth:`register_twin`)."""
        if self.root is None:
            return None
        key = self.key_for(source)
        return "%s.%d.%x.tmp" % (
            self._twin_paths(key)["data"], os.getpid(), threading.get_ident(),
        )

    def register_twin(
        self,
        origin,
        *,
        codec_tag: str,
        data: Union[str, bytes],
        index: GzipIndex,
        meta: Optional[Dict[str, Any]] = None,
    ) -> Optional[str]:
        """Atomically install a transcoded twin under the origin's key.

        ``data`` is a tmp-file path (disk store: renamed into place) or the
        twin bytes (in-memory store). Install order is data → index → meta,
        with the meta JSON written last as the commit point: a crash at any
        earlier step leaves files that :meth:`resolve_twin` never returns.
        Refuses a non-finalized index (counted in ``stats.twin_rejected``).
        """
        if not index.finalized:
            with self._lock:
                self.stats.twin_rejected += 1
            return None
        key = self.key_for(origin)
        index_blob = index.to_bytes()
        record_meta = dict(meta or {})
        record_meta["codec"] = codec_tag
        record_meta.setdefault("decompressed", index.decompressed_size)
        if self.root is None:
            if not isinstance(data, (bytes, bytearray, memoryview)):
                raise TypeError("in-memory store needs twin bytes, not a path")
            blob = bytes(data)
            record_meta["bytes_out"] = len(blob)
            record = TwinRecord(key, codec_tag, blob, index_blob, record_meta)
            with self._lock:
                self._twins[key] = record
                self.stats.twin_installs += 1
            return key
        if not isinstance(data, (str, os.PathLike)):
            raise TypeError("disk store needs a tmp-file path for twin data")
        paths = self._twin_paths(key)
        record_meta["bytes_out"] = os.stat(data).st_size
        os.replace(os.fspath(data), paths["data"])
        self._install_at(paths["idx"], index_blob)
        self._install_at(paths["meta"], json.dumps(record_meta).encode())
        with self._lock:
            self.stats.twin_installs += 1
        return key

    def _install_at(self, path: str, blob: bytes) -> None:
        """Unique-tmp + fsync + atomic rename at an explicit path."""
        tmp = "%s.%d.%x.tmp" % (path, os.getpid(), threading.get_ident())
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    def resolve_twin(self, origin) -> Optional[TwinRecord]:
        """The installed twin for an origin identity, or None.

        A half-written install (no meta, truncated data, unparseable or
        unfinalized index, codec mismatch) is treated as absent — the open
        path falls back to the origin archive, never a torn twin.
        """
        key = self.key_for(origin)
        if self.root is None:
            with self._lock:
                record = self._twins.get(key)
                if record is not None:
                    self.stats.twin_hits += 1
                return record
        paths = self._twin_paths(key)
        try:
            with open(paths["meta"], "rb") as f:
                meta = json.loads(f.read())
            if not isinstance(meta, dict):
                return None
            if os.stat(paths["data"]).st_size != meta.get("bytes_out"):
                return None
            with open(paths["idx"], "rb") as f:
                index_blob = f.read()
        except (OSError, ValueError):
            return None
        codec_tag = meta.get("codec")
        if self._validate_remote(index_blob) is None:
            return None
        if codec_tag != GzipIndex.from_bytes(index_blob).codec_tag:
            return None
        with self._lock:
            self.stats.twin_hits += 1
        return TwinRecord(key, codec_tag, paths["data"], index_blob, meta)

    def drop_twin(self, origin) -> None:
        """Uninstall a twin (meta removed first, so a concurrent resolve
        sees either the full record or nothing)."""
        key = self.key_for(origin)
        if self.root is None:
            with self._lock:
                self._twins.pop(key, None)
            return
        paths = self._twin_paths(key)
        for name in ("meta", "idx", "data"):
            try:
                os.unlink(paths[name])
            except FileNotFoundError:
                pass

    def __contains__(self, source) -> bool:
        key = self.key_for(source)
        if self.root is None:
            with self._lock:
                return key in self._mem
        return os.path.exists(self._path(key))

    def keys(self):
        if self.root is None:
            with self._lock:
                return list(self._mem.keys())
        return [
            name[: -len(_EXT)]
            for name in os.listdir(self.root)
            if name.endswith(_EXT)
        ]

    def clear(self) -> None:
        if self.root is None:
            with self._lock:
                self._mem.clear()
                self._twins.clear()
            return
        exts = (_EXT, _TWIN_DATA_EXT, _TWIN_IDX_EXT, _TWIN_META_EXT)
        for name in os.listdir(self.root):
            if name.endswith(exts):
                os.unlink(os.path.join(self.root, name))


def _is_key(s: str) -> bool:
    return len(s) == 64 and all(c in "0123456789abcdef" for c in s)
