"""Source resolution + background transcoding of seek-hostile archives.

The paper's architecture exists because gzip resists seeking, and its worst
case (§4.8: fixed-Huffman / splitless archives) degrades to sequential-only
decompression *on every cold open, forever*. ACEAPEX's observation is that
the durable fix is encode-time resolution: pay one sequential pass, re-encode
as a parallel-friendly format, and every later read is O(1)-seekable. This
module implements both halves:

* :func:`resolve_source` — the open-path seam. Every ``ArchiveServer`` handle
  resolves through it: compute the origin's ``file_identity``, consult the
  ``IndexStore`` for a registered twin, and transparently bind the reader to
  the twin's bytes + exact index when one exists. The handle keeps the
  *origin's* identity (ETags, fleet rendezvous placement, and the
  index-exchange endpoint are unchanged), and the served bytes are
  bit-identical by construction — the twin re-encodes the same decompressed
  stream and is byte-compared against the origin before install.

* :class:`TranscodeManager` — the background half. When a freshly built
  index probes hostile (``Codec.seek_hostility`` over the reader's
  first-pass observations), the manager re-encodes the archive as BGZF (or
  zstd-seekable) via ``core.synth`` streaming writers. The work runs as a
  chain of small batch-lane ``FairExecutor`` steps with byte-cost hints —
  DRR interleaves interactive reads between spans, so a transcode never
  starves a tenant — and survives crash/partial-write: the twin streams to a
  unique tmp file, is fsynced, re-opened, and byte-compared against the
  origin *before* ``IndexStore.register_twin`` commits it (meta-last, so a
  torn install is never resolved).
"""

from __future__ import annotations

import io
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from ..core.index import GzipIndex
from ..core.reader import ParallelGzipReader
from ..core.synth import BgzfStreamWriter, ZstdSeekableStreamWriter
from .index_store import IndexStore, file_identity

__all__ = [
    "ResolvedSource",
    "TranscodeError",
    "TranscodeManager",
    "resolve_source",
]


class TranscodeError(RuntimeError):
    """A transcode job failed validation or could not be installed."""


@dataclass
class ResolvedSource:
    """What the server should actually open for a requested source.

    ``identity`` is always the *origin's* key — twin-bound or not — which is
    what keeps ETag semantics and fleet placement stable across a twin
    install. ``twin`` names the twin's codec tag when the read path was
    rebound (None = serving the origin directly).
    """

    source: Any
    codec: Optional[str]
    index: Optional[GzipIndex]
    identity: str
    index_was_warm: bool
    twin: Optional[str] = None


def resolve_source(
    store: Optional[IndexStore], source: Any, *, codec: Optional[str] = None
) -> ResolvedSource:
    """Resolve ``source`` through the store: twin first, then warm index.

    A registered twin rebinds the read path to the twin's bytes and exact
    index (cold open does zero speculative work); otherwise the origin is
    opened with its warm index when one is stored. A twin whose index blob
    fails to parse is ignored — the origin always remains servable.
    """
    identity = file_identity(source, codec=codec)
    if store is None:
        return ResolvedSource(source, codec, None, identity, False)
    twin = store.resolve_twin(identity)
    if twin is not None:
        try:
            index = GzipIndex.from_bytes(twin.index_blob)
        except Exception:
            index = None
        if index is not None and index.finalized:
            return ResolvedSource(
                twin.source, twin.codec_tag, index, identity, True, twin.codec_tag
            )
    index = store.get(identity)
    return ResolvedSource(source, codec, index, identity, index is not None)


#: Sources a background job can re-open by value, independently of the
#: handle that triggered it. An already-open FileReader object is excluded:
#: the job would share (and race the close of) the server entry's reader.
_REOPENABLE = (str, os.PathLike, bytes, bytearray, memoryview)


@dataclass
class _Job:
    identity: str
    source: Any
    origin_codec: str
    twin_codec: str
    hostility: float
    origin_index_blob: bytes
    bytes_in: int
    decompressed: int
    state: str = "pending"  # pending -> running -> installed | failed
    error: Optional[str] = None
    offset: int = 0
    spans_done: int = 0
    twin_points: int = 0
    started: float = 0.0
    elapsed_s: float = 0.0
    reader: Optional[ParallelGzipReader] = None
    sink: Any = None
    writer: Any = None
    tmp_path: Optional[str] = None
    done: threading.Event = field(default_factory=threading.Event)


class TranscodeManager:
    """Background re-encoder for archives that probe seek-hostile.

    ``consider(identity, source, reader)`` is the trigger (the server calls
    it after an archive's first full decompression): when the codec's
    hostility score clears ``hostility_threshold`` and no twin or job exists
    yet, a job is scheduled as a chain of ``span_bytes``-sized batch-lane
    steps on ``executor`` under the dedicated ``tenant`` (whose DRR quantum
    is scaled down by ``batch_quantum`` so interactive tenants always win
    contention).

    ``fault_hook(stage)`` is a test seam: called at the named lifecycle
    stages (``"open"``, ``"span"``, ``"finish"``, ``"validate"``,
    ``"install"``); an exception it raises fails the job exactly as a crash
    at that point would — the atomicity tests kill the transcoder mid-install
    through it.
    """

    _STATES = ("pending", "running", "installed", "failed")

    def __init__(
        self,
        index_store: IndexStore,
        executor,
        *,
        tenant: str = "transcode",
        twin_codec: str = "auto",
        span_bytes: int = 4 << 20,
        hostility_threshold: float = 0.7,
        min_input_bytes: int = 1 << 12,
        batch_quantum: float = 0.25,
        compare_span: int = 4 << 20,
        fault_hook=None,
    ):
        if twin_codec not in ("auto", "bgzf", "zstd"):
            raise ValueError("twin_codec must be 'auto', 'bgzf', or 'zstd'")
        self.store = index_store
        self.tenant = tenant
        # BGZF decodes through the deflate stack everywhere; zstd twins need
        # a zstd library at *serve* time too, so they are opt-in.
        self.twin_codec = "bgzf" if twin_codec == "auto" else twin_codec
        self.span_bytes = max(1 << 16, int(span_bytes))
        self.hostility_threshold = float(hostility_threshold)
        self.min_input_bytes = int(min_input_bytes)
        self.compare_span = max(1 << 16, int(compare_span))
        self._executor = executor
        self._fault_hook = fault_hook
        self._lock = threading.Lock()
        self._jobs: Dict[str, _Job] = {}
        self._closed = False
        self.counters = {
            "considered": 0,
            "scheduled": 0,
            "installed": 0,
            "failed": 0,
            "skipped_unresolvable": 0,
        }
        if executor is not None:
            executor.set_tenant_quantum(tenant, batch_quantum)

    # -- trigger ------------------------------------------------------------

    def consider(self, identity: str, source: Any, reader) -> bool:
        """Probe a freshly built index; schedule a transcode if hostile.

        Idempotent and cheap on the read path: dedups against running jobs
        and installed twins, and returns False without side effects for
        friendly archives. Returns True when a job was scheduled.
        """
        if self._closed or self._executor is None:
            return False
        index = reader.index
        if not index.finalized:
            return False
        with self._lock:
            self.counters["considered"] += 1
        hostility = reader.codec.seek_hostility(index)
        if hostility < self.hostility_threshold:
            return False
        if (index.compressed_size or 0) < self.min_input_bytes:
            return False
        if not isinstance(source, _REOPENABLE):
            with self._lock:
                self.counters["skipped_unresolvable"] += 1
            return False
        with self._lock:
            if identity in self._jobs:
                return False
        if self.store.resolve_twin(identity) is not None:
            return False
        job = _Job(
            identity=identity,
            source=source,
            origin_codec=reader.codec.tag,
            twin_codec=self.twin_codec,
            hostility=hostility,
            origin_index_blob=index.to_bytes(),
            bytes_in=int(index.compressed_size or 0),
            decompressed=int(index.decompressed_size or 0),
        )
        with self._lock:
            if identity in self._jobs:
                return False
            self._jobs[identity] = job
            self.counters["scheduled"] += 1
        # Persist the origin's finalized index under the origin key first:
        # later cold opens of the *origin* (twin install may still fail) and
        # fleet index exchange both serve this blob, and the job's private
        # reader re-opens from it without a second sequential pass.
        self.store.put(identity, index)
        self._submit_step(job)
        return True

    # -- job steps (batch-lane executor tasks) ------------------------------

    def _submit_step(self, job: _Job) -> None:
        try:
            fut = self._executor.submit(
                self.tenant, self._step, job,
                _cost=self.span_bytes, _priority=False,
            )
        except RuntimeError as exc:  # executor already shut down
            self._fail(job, exc)
            return
        fut.add_done_callback(lambda f: self._step_reaped(job, f))

    def _step_reaped(self, job: _Job, fut) -> None:
        # _step handles its own exceptions; this reaps steps that never ran
        # (cancelled in queue by shutdown/cancel_view) so a job cannot hang
        # in "running" with no step scheduled.
        if fut.cancelled():
            self._fail(job, TranscodeError("transcode step cancelled"))

    def _fault(self, stage: str) -> None:
        if self._fault_hook is not None:
            self._fault_hook(stage)

    def _step(self, job: _Job) -> None:
        """One bounded unit of transcoding: re-encode ``span_bytes`` of
        decompressed origin, then reschedule. Small steps + byte-cost hints
        are what let DRR interleave interactive reads between spans."""
        if job.done.is_set():
            return
        if self._closed:
            self._fail(job, TranscodeError("transcode manager closed"))
            return
        try:
            if job.reader is None:
                self._open_job(job)
            total = job.decompressed
            data = job.reader.pread(job.offset, min(self.span_bytes, total - job.offset))
            self._fault("span")
            job.writer.write(data)
            job.offset += len(data)
            job.spans_done += 1
            if job.offset >= total or not data:
                self._finalize(job)
            else:
                self._submit_step(job)
        except Exception as exc:  # noqa: BLE001 — any failure fails the job
            self._fail(job, exc)

    def _open_job(self, job: _Job) -> None:
        self._fault("open")
        # Private single-threaded reader over the origin's finalized index:
        # every span is an indexed (often zlib-delegated) read, no frontier
        # work, no shared state with the triggering server handle.
        job.reader = ParallelGzipReader(
            job.source,
            index=job.origin_index_blob,
            parallelization=1,
            verify=False,
        )
        job.tmp_path = self.store.twin_tmp_path(job.identity)
        if job.tmp_path is None:
            job.sink = io.BytesIO()
        else:
            job.sink = open(job.tmp_path, "wb")
        if job.twin_codec == "zstd":
            job.writer = ZstdSeekableStreamWriter(job.sink)
        else:
            job.writer = BgzfStreamWriter(job.sink)
        job.state = "running"
        job.started = time.perf_counter()

    def _finalize(self, job: _Job) -> None:
        """Finish + fsync + validate-before-install + atomic registration."""
        self._fault("finish")
        job.writer.finish()
        if job.tmp_path is not None:
            job.sink.flush()
            os.fsync(job.sink.fileno())
            job.sink.close()
            twin_source: Any = job.tmp_path
        else:
            twin_source = job.sink.getvalue()
        self._fault("validate")
        twin_index = self._validate(job, twin_source)
        job.twin_points = len(twin_index)
        self._fault("install")
        key = self.store.register_twin(
            job.identity,
            codec_tag=job.twin_codec,
            data=twin_source,
            index=twin_index,
            meta={
                "origin_codec": job.origin_codec,
                "bytes_in": job.bytes_in,
                "hostility": round(job.hostility, 4),
                "spans": job.spans_done,
            },
        )
        if key is None:
            raise TranscodeError("twin registration refused (unfinalized index)")
        job.elapsed_s = time.perf_counter() - job.started
        with self._lock:
            job.state = "installed"
            self.counters["installed"] += 1
        # Cleanup strictly before done.set(): wait() returning must mean
        # every job-owned resource (reader, sink, tmp file) is gone.
        self._cleanup(job, drop_tmp=False)
        job.done.set()

    def _validate(self, job: _Job, twin_source: Any) -> GzipIndex:
        """Re-open the twin from its tmp bytes and prove, before install,
        that (a) its exact index finalizes from metadata alone and (b) its
        decompressed stream is bit-identical to the origin's."""
        twin_reader = ParallelGzipReader(
            twin_source, codec=job.twin_codec, parallelization=1, verify=False
        )
        try:
            index = twin_reader.index
            if not index.finalized:
                raise TranscodeError("twin index did not finalize from metadata")
            if (index.decompressed_size or 0) != job.decompressed:
                raise TranscodeError(
                    "twin decompressed size %s != origin %s"
                    % (index.decompressed_size, job.decompressed)
                )
            off = 0
            while off < job.decompressed:
                n = min(self.compare_span, job.decompressed - off)
                if twin_reader.pread(off, n) != job.reader.pread(off, n):
                    raise TranscodeError("twin bytes differ at offset %d" % off)
                off += n
            return index
        finally:
            twin_reader.close()

    # -- failure / cleanup ---------------------------------------------------

    def _fail(self, job: _Job, exc: BaseException) -> None:
        with self._lock:
            if job.state in ("installed", "failed"):
                return
            job.state = "failed"
            job.error = "%s: %s" % (type(exc).__name__, exc)
            self.counters["failed"] += 1
        if job.started:
            job.elapsed_s = time.perf_counter() - job.started
        # Cleanup strictly before done.set() — see _finalize.
        self._cleanup(job, drop_tmp=True)
        job.done.set()

    def _cleanup(self, job: _Job, *, drop_tmp: bool) -> None:
        reader, job.reader = job.reader, None
        sink, job.sink = job.sink, None
        job.writer = None
        if reader is not None:
            try:
                reader.close()
            except Exception:
                pass
        if sink is not None:
            try:
                sink.close()
            except Exception:
                pass
        if drop_tmp and job.tmp_path is not None:
            try:
                os.unlink(job.tmp_path)
            except OSError:
                pass

    # -- introspection -------------------------------------------------------

    def wait(self, identity: str, timeout: Optional[float] = None) -> Optional[str]:
        """Block until the job for ``identity`` reaches a terminal state;
        returns that state (or the current one on timeout, None if no job)."""
        with self._lock:
            job = self._jobs.get(identity)
        if job is None:
            return None
        job.done.wait(timeout)
        return job.state

    def job_state(self, identity: str) -> Optional[str]:
        with self._lock:
            job = self._jobs.get(identity)
            return job.state if job is not None else None

    def snapshot(self) -> Dict[str, Any]:
        """The ``metrics()["transcode"]`` section: counters + per-archive
        job state. ``speedup`` for an installed twin is the expected
        sequential-work reduction for a uniform random cold seek — the
        origin decodes O(offset) while the twin decodes O(span), so the
        ratio is the twin's seek-point count."""
        with self._lock:
            jobs = {
                j.identity: {
                    "state": j.state,
                    "codec": j.twin_codec,
                    "origin_codec": j.origin_codec,
                    "hostility": round(j.hostility, 4),
                    "bytes_in": j.bytes_in,
                    "bytes_out": int(j.writer.bytes_out) if j.writer is not None
                    else int((j.state == "installed") and self._installed_bytes(j)),
                    "decompressed": j.decompressed,
                    "spans_done": j.spans_done,
                    "speedup": float(max(1, j.twin_points)) if j.state == "installed" else None,
                    "elapsed_s": round(j.elapsed_s, 4),
                    "error": j.error,
                }
                for j in self._jobs.values()
            }
            counters = dict(self.counters)
        return {
            "tenant": self.tenant,
            "twin_codec": self.twin_codec,
            "hostility_threshold": self.hostility_threshold,
            "counters": counters,
            "jobs": jobs,
        }

    def _installed_bytes(self, job: _Job) -> int:
        # Writer is gone after cleanup; the store's meta carries the size.
        record = self.store.resolve_twin(job.identity)
        return int(record.meta.get("bytes_out", 0)) if record is not None else 0

    def close(self) -> None:
        """Stop accepting work; in-flight steps notice and fail their jobs.
        Queued steps are reaped by the executor's own shutdown/cancel."""
        self._closed = True
