"""AsyncArchiveServer — asyncio front-end over `ArchiveServer`.

The synchronous server is already concurrency-ready: ``read_range`` is
stateless (no shared cursor, no entry lock — see server.py's concurrency
contract), so an async front-end only needs a non-blocking bridge from the
event loop into threads. This wrapper provides one:

  * ``await read_range(...)`` / ``await size(...)`` run the blocking call on
    a small dedicated **front-end bridge pool** and suspend the coroutine —
    the event loop never blocks, however long the first pass takes;
  * ``await read_many([...])`` fans a batch of ranges out concurrently
    (``asyncio.gather`` over the bridge) — with a warm index the underlying
    preads proceed genuinely in parallel;
  * ``open`` and ``stat`` complete inline: registration is a dict insert and
    ``stat`` is a lock-free snapshot by design, so neither can stall the
    loop.

Why a dedicated bridge pool instead of dispatching front-end calls into the
shared `FairExecutor`: a read *blocks on decompression futures queued into
that same executor*. Running the blocking wrapper on a FairExecutor worker
could occupy every worker with callers that are all waiting for fetch tasks
none of the workers are free to run — classic pool-starvation deadlock. The
bridge threads therefore only *wait*; every byte of decompression work still
flows through the shared FairExecutor underneath with its per-tenant
fairness intact. Bridge threads are cheap (they sleep on futures), so
``front_end_threads`` bounds front-end concurrency, not CPU.

**Cancellation propagates into the bridge.** Awaiting coroutines get
cancelled (client disconnects, timeouts, gather siblings failing); the
bridged call must not keep consuming a bridge thread on behalf of a caller
that is gone. Every bridged await therefore:

  * cancels the underlying ``concurrent.futures`` future on
    ``asyncio.CancelledError`` — a call still *queued* for the bridge never
    starts, so a burst of abandoned requests cannot occupy bridge threads
    it no longer wants (the ``bridge_stats()['cancelled']`` counter is the
    audit trail);
  * a call already *running* finishes on its bridge thread (blocking reads
    are not preemptible) and its result is dropped — but ``read_many``
    cancels its still-queued siblings as soon as any range fails, so one
    bad range no longer leaks K-1 bridge occupancies past the await.

    from repro.service import AsyncArchiveServer

    async with AsyncArchiveServer(cache_budget_bytes=64 << 20) as srv:
        h = await srv.open("corpus-00.json.gz", tenant="search")
        page = await srv.read_range(h, 10 << 20, 4096)
        pages = await srv.read_many([(h, off, 4096) for off in offsets])
"""

from __future__ import annotations

import asyncio
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..obs import hist as _obs_hist
from ..obs import trace as _obs_trace
from .server import ArchiveServer, ArchiveStat


class AsyncArchiveServer:
    """Async facade over an `ArchiveServer` (owned or wrapped).

    Construct either around an existing server (``AsyncArchiveServer(srv)``
    — lifecycle stays with the caller) or standalone with `ArchiveServer`
    kwargs (``AsyncArchiveServer(cache_budget_bytes=...)`` — ``shutdown`` /
    ``async with`` then tears the inner server down too).
    """

    def __init__(
        self,
        server: Optional[ArchiveServer] = None,
        *,
        front_end_threads: int = 8,
        **server_kwargs: Any,
    ):
        if server is not None and server_kwargs:
            raise ValueError("pass either a server or ArchiveServer kwargs, not both")
        self._server = server if server is not None else ArchiveServer(**server_kwargs)
        self._owns_server = server is None
        self._bridge = ThreadPoolExecutor(
            max_workers=max(1, front_end_threads),
            thread_name_prefix="archive-async",
        )
        self._closed = False
        # Bridge-side cancel accounting: submitted awaits, calls that
        # actually started on a bridge thread, and cancels that landed while
        # still queued (those never start — the regression tests' invariant).
        self._bridge_lock = threading.Lock()
        self._bridge_submitted = 0
        self._bridge_started = 0
        self._bridge_cancelled = 0

    @property
    def server(self) -> ArchiveServer:
        """The wrapped synchronous server (telemetry, sync co-access)."""
        return self._server

    # ------------------------------------------------------------------
    # bridge
    # ------------------------------------------------------------------

    def _bridged_call(self, ctx, t_submit, fn, *args, **kwargs):
        with self._bridge_lock:
            self._bridge_started += 1
        # Bridge queue wait: loop-side submit -> bridge-thread start. The
        # histogram is always on (it is the "bridge pool saturated" signal);
        # the span exists only while tracing and joins the caller's trace
        # via the context captured on the event loop.
        t0 = time.perf_counter()
        _obs_hist.observe("bridge.queue_wait", t0 - t_submit)
        if ctx is None and not _obs_trace.tracing_enabled():
            return fn(*args, **kwargs)
        # `attach` alone propagates the caller's context into this bridge
        # thread (the bridged read's own spans parent under the gateway
        # request); the bridge hop itself is recorded post-hoc, which keeps
        # the warm path at one completed-span append instead of a live Span.
        with _obs_trace.attach(ctx):
            t1 = time.perf_counter()
            try:
                return fn(*args, **kwargs)
            finally:
                _obs_trace.record_span(
                    "bridge.call",
                    t1,
                    time.perf_counter() - t1,
                    {"queue_wait_s": round(t0 - t_submit, 6)},
                    parent=ctx,
                )

    async def _run(self, fn, *args, **kwargs):
        """Await ``fn(*args)`` on the bridge, propagating cancellation.

        Unlike a bare ``loop.run_in_executor`` await, a cancelled await here
        *guarantees* ``future.cancel()`` is attempted on the bridged future
        and books the outcome: a still-queued call never reaches a bridge
        thread at all. (A call already running completes and is dropped —
        blocking reads cannot be preempted mid-decompression.)
        """
        if self._closed:
            raise RuntimeError("AsyncArchiveServer is closed")
        # Book the submission *before* handing it to the pool: a fast bridge
        # thread could otherwise bump `started` first and a concurrent
        # telemetry poll would transiently see started > submitted.
        with self._bridge_lock:
            self._bridge_submitted += 1
        try:
            fut = self._bridge.submit(
                partial(
                    self._bridged_call,
                    _obs_trace.capture(),
                    time.perf_counter(),
                    fn,
                    *args,
                    **kwargs,
                )
            )
        except BaseException:
            with self._bridge_lock:
                self._bridge_submitted -= 1
            raise
        try:
            return await asyncio.wrap_future(fut)
        except asyncio.CancelledError:
            if fut.cancel():
                with self._bridge_lock:
                    self._bridge_cancelled += 1
            raise

    def bridge_stats(self) -> Dict[str, int]:
        """{submitted, started, cancelled} for the front-end bridge. At
        quiescence ``submitted == started + cancelled`` — no bridged call is
        ever both cancelled-while-queued and run."""
        with self._bridge_lock:
            return {
                "submitted": self._bridge_submitted,
                "started": self._bridge_started,
                "cancelled": self._bridge_cancelled,
            }

    # ------------------------------------------------------------------
    # request API
    # ------------------------------------------------------------------

    async def open(
        self, source, *, tenant: str = "default", quantum: Optional[float] = None
    ) -> str:
        """Register a source (lazy reader creation, like the sync server).

        Pure registry work — runs inline, no executor round-trip.
        ``quantum`` forwards to the sync server's weighted-DRR knob.
        """
        if self._closed:
            raise RuntimeError("AsyncArchiveServer is closed")
        return self._server.open(source, tenant=tenant, quantum=quantum)

    async def read_range(self, handle: str, offset: int, size: int) -> bytes:
        """Decompressed [offset, offset+size) without blocking the loop."""
        return await self._run(self._server.read_range, handle, offset, size)

    async def read_many(
        self, requests: Sequence[Tuple[str, int, int]]
    ) -> List[bytes]:
        """Serve many ``(handle, offset, size)`` ranges concurrently.

        Results keep request order. Concurrency = min(len(requests),
        front_end_threads) at the bridge; the decompression itself fans out
        further through the shared executor. Any failed range fails the
        batch — and, unlike a bare ``asyncio.gather``, the batch's other
        still-pending awaits are cancelled immediately (queued bridge calls
        never start), so one bad range cannot keep occupying bridge threads
        on work whose result nobody will read. Issue ranges individually if
        partial results are wanted.
        """
        tasks = [
            asyncio.ensure_future(self.read_range(h, off, size))
            for h, off, size in requests
        ]
        try:
            return list(await asyncio.gather(*tasks))
        except BaseException:
            # First failure (or our own cancellation): reap the siblings.
            for t in tasks:
                t.cancel()
            await asyncio.gather(*tasks, return_exceptions=True)
            raise

    async def stat(self, handle: str) -> ArchiveStat:
        """Handle snapshot — lock-free in the sync server, so served inline."""
        if self._closed:
            raise RuntimeError("AsyncArchiveServer is closed")
        return self._server.stat(handle)

    async def cancel_queued(self, handle: str) -> int:
        """Drop the handle's queued prefetch backlog (disconnect cleanup).

        A brief scheduler-lock sweep, never a blocking wait — served inline.
        """
        if self._closed:
            raise RuntimeError("AsyncArchiveServer is closed")
        return self._server.cancel_queued(handle)

    async def size(self, handle: str) -> int:
        """Decompressed size (may drive a whole first pass: bridged)."""
        return await self._run(self._server.size, handle)

    def metrics(self) -> Dict[str, Any]:
        """Fleet snapshot + this bridge's books (sync: non-blocking)."""
        snap = self._server.metrics()
        snap["bridge"] = self.bridge_stats()
        return snap

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    async def persist_index(self, handle: str) -> Optional[str]:
        return await self._run(self._server.persist_index, handle)

    async def close(self, handle: str, *, persist_index: bool = True) -> None:
        await self._run(self._server.close, handle, persist_index=persist_index)

    async def shutdown(self) -> None:
        """Drain the bridge; shut the inner server down iff we created it."""
        if self._closed:
            return
        try:
            if self._owns_server:
                await self._run(self._server.shutdown)
        finally:
            self._closed = True
            self._bridge.shutdown(wait=False)

    async def __aenter__(self) -> "AsyncArchiveServer":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.shutdown()
