"""Fault-tolerant sharded checkpointing with elastic restore.

Layout:   <dir>/step_<N>/manifest.json + arrays/<leaf-id>.npy
Writes are atomic (tmp dir + rename), rotated (keep_n), and include the
*data-pipeline state* — per-shard seek offsets into the gzip corpus, which
the paper's seek index makes O(1) to restore (DESIGN.md §2).

``restore(..., mesh=..., shardings=...)`` re-device_puts leaves under the
target sharding, so a checkpoint taken on one mesh restarts on another
(elastic scaling: lose a pod, restart on 256 chips with the same math).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(
    directory: str,
    step: int,
    state: Dict[str, Any],
    *,
    keep_n: int = 3,
) -> str:
    """state: arbitrary pytree dict, e.g. {params, opt, data, meta}."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=directory)
    arrays_dir = os.path.join(tmp, "arrays")
    os.makedirs(arrays_dir)

    leaves = _flatten_with_paths(state)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        if leaf is None:
            manifest["leaves"].append({"key": key, "kind": "none"})
            continue
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            # numpy cannot persist ml_dtypes (bfloat16 etc.) natively;
            # round-trip losslessly through float32.
            logical_dtype = "bfloat16"
            arr = arr.astype(np.float32)
        fname = f"{i:06d}.npy"
        np.save(os.path.join(arrays_dir, fname), arr)
        manifest["leaves"].append(
            {"key": key, "kind": "array", "file": fname, "dtype": logical_dtype, "shape": list(arr.shape)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # Atomic publish; tolerate a crashed previous attempt.
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _rotate(directory, keep_n)
    return final


def _rotate(directory: str, keep_n: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for old in steps[:-keep_n]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    return os.path.join(directory, steps[-1]) if steps else None


def restore_checkpoint(
    path: str,
    template: Dict[str, Any],
    *,
    shardings: Optional[Any] = None,
) -> Tuple[int, Dict[str, Any]]:
    """Restore into the structure of ``template``; optionally device_put each
    leaf with the matching leaf of ``shardings`` (elastic re-sharding)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if len(shard_leaves) != len(flat):
            shard_leaves = None  # structure mismatch: restore unsharded

    out = []
    for i, (pathk, leaf) in enumerate(flat):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in pathk
        )
        entry = by_key.get(key)
        if entry is None or entry["kind"] == "none":
            out.append(leaf)
            continue
        arr = np.load(os.path.join(path, "arrays", entry["file"]))
        restored = jax.numpy.asarray(arr)
        if entry.get("dtype") == "bfloat16":
            restored = restored.astype(jax.numpy.bfloat16)
        if shard_leaves is not None:
            out.append(jax.device_put(restored, shard_leaves[i]))
        else:
            out.append(restored)
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, out)
