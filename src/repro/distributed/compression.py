"""Gradient compression: int8 quantization with error feedback.

At 512 chips the cross-pod gradient all-reduce is the only collective that
leaves a pod (DESIGN.md §6); int8 halves-to-quarters its volume. Under jit
the DP all-reduce is inserted by GSPMD, so compression is exposed two ways:

  * ``compress``/``decompress`` + error-feedback state — composable pure ops
    (property-tested); wired into the train step as quantize->dequantize
    around the gradient, which preserves optimizer semantics and models the
    volume reduction (the dry-run's collective term is scaled accordingly
    when enabled).
  * ``compressed_psum`` — the explicit shard_map collective for manual-DP
    code paths (pipeline stages), where the int8 wire format is real.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_with_feedback(
    grads: Any, error_state: Any
) -> Tuple[Any, Any]:
    """Quantize a gradient tree, carrying the quantization error forward.

    error feedback: e_{t} = g_t + e_{t-1} - deq(q(g_t + e_{t-1})) — keeps
    the long-run update unbiased (1-bit Adam / EF-SGD literature).
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = compress(target)
        deq = decompress(q, scale)
        return deq.astype(g.dtype), target - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        jax.tree.unflatten(treedef, [o[1] for o in out]),
    )


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce inside shard_map: quantize, psum int32, dequantize.

    Scales are made uniform with a max-reduce first so the sum stays exact
    in the quantized domain (each shard contributes <= 127 * scale).
    """
    xf = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    del n
    return (total.astype(jnp.float32) * scale).astype(x.dtype)
