"""Logical-axis sharding rules for the production mesh.

Every parameter and key activation carries a tuple of *logical* axis names;
``ShardingRules`` maps those to mesh axes. The production mesh is
``("data", "model")`` single-pod or ``("pod", "data", "model")`` multi-pod
(see launch/mesh.py); "pod" acts as an extra pure-DP axis by default.

Conventions (see DESIGN.md §6):
  * batch                  -> ("pod", "data")   (DP)
  * heads / kv_heads / ffn / vocab -> "model"   (TP, Megatron col->row)
  * experts                -> "data"            (EP; a2a stays intra-pod)
  * embed / model dims     -> replicated
  * optimizer states       -> additionally sharded over "data" (ZeRO-1)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


def shard_map_compat(fn, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions.

    jax >= 0.5 exposes it top-level with ``check_vma``; 0.4.x has
    ``jax.experimental.shard_map.shard_map`` with the same semantics under
    ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Dict[str, MeshAxes]

    def spec(self, logical_axes: Optional[Sequence[Optional[str]]]) -> P:
        if logical_axes is None:
            return P()
        parts = []
        used: set = set()
        for ax in logical_axes:
            mesh_axes = self.rules.get(ax) if ax is not None else None
            if mesh_axes is None:
                parts.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            # A mesh axis may appear at most once in a PartitionSpec.
            free = tuple(m for m in mesh_axes if m not in used)
            used.update(free)
            parts.append(free if len(free) > 1 else (free[0] if free else None))
        return P(*parts)

    def sharding(self, mesh: Mesh, logical_axes) -> NamedSharding:
        return NamedSharding(mesh, self.spec(logical_axes))

    def with_overrides(self, **overrides: MeshAxes) -> "ShardingRules":
        merged = dict(self.rules)
        merged.update(overrides)
        return ShardingRules(merged)


def default_rules(mesh: Mesh) -> ShardingRules:
    """Rules for both single-pod and multi-pod meshes."""
    has_pod = "pod" in mesh.axis_names
    batch_axes: MeshAxes = ("pod", "data") if has_pod else ("data",)
    return ShardingRules(
        {
            # activations
            "batch": batch_axes,
            "seq": None,
            "seq_shard": ("data",),  # sequence parallelism (long-context)
            "embed": None,
            # attention
            "heads": ("model",),
            "kv_heads": ("model",),
            "head_dim": None,
            "qk_lora": None,
            # mlp
            "ffn": ("model",),
            # embeddings / output
            "vocab": ("model",),
            # MoE
            "experts": ("data",),
            "expert_ffn": ("model",),
            # recurrent / ssm
            "ssm_inner": ("model",),
            "ssm_state": None,
            # conv frontends
            "conv_k": None,
        }
    )


def logical_sharding_tree(abstract_tree, logical_tree, mesh: Mesh, rules: ShardingRules):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda _, la: rules.sharding(mesh, la),
        abstract_tree,
        logical_tree,
        is_leaf=lambda x: x is None,
    )


def constrain(x: jax.Array, rules: ShardingRules, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axes (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(logical_axes))
    except (ValueError, RuntimeError):
        return x


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the dimension they shard.

    Explicit jit in_shardings require exact divisibility; dims that cannot
    shard evenly fall back to replication (e.g. qwen2.5's 40 heads on a
    16-wide model axis — a documented baseline cost, see EXPERIMENTS.md
    §Perf). Axis *prefixes* that divide are kept: ('pod','data') on a batch
    divisible by pod but not pod*data keeps 'pod'.
    """
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, p in zip(shape, parts):
        if p is None:
            out.append(None)
            continue
        axes = p if isinstance(p, tuple) else (p,)
        kept = []
        size = 1
        for a in axes:
            nxt = size * mesh.shape[a]
            if dim % nxt == 0:
                kept.append(a)
                size = nxt
            else:
                break
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def batch_partition(mesh: Mesh, n: int) -> P:
    """Largest prefix of DP axes that divides a batch of size n."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen = []
    size = 1
    for a in axes:
        if n % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    if not chosen:
        return P()
    return P(tuple(chosen) if len(chosen) > 1 else chosen[0])


def zero1_spec(param_spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: extend a parameter spec with 'data' sharding on the first
    free dimension divisible by the data-axis size (optimizer states only).

    Falls back to the unmodified spec when nothing divides — correctness
    first, memory second.
    """
    if "data" not in mesh.axis_names:
        return param_spec
    data_size = mesh.shape["data"]
    parts = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = set()
    for p in parts:
        for a in (p if isinstance(p, tuple) else (p,)):
            if a:
                used.add(a)
    if "data" in used:
        return param_spec
    # Account for existing sharding when checking divisibility.
    for i, (dim, p) in enumerate(zip(shape, parts)):
        denom = 1
        for a in (p if isinstance(p, tuple) else (p,)):
            if a:
                denom *= mesh.shape[a]
        local = dim // denom if denom and dim % denom == 0 else dim
        if p is None and dim % data_size == 0:
            parts[i] = "data"
            return P(*parts)
        if p is not None and dim % (denom * data_size) == 0:
            cur = p if isinstance(p, tuple) else (p,)
            parts[i] = tuple(a for a in cur if a) + ("data",)
            return P(*parts)
        del local
    return param_spec


def mesh_device_count(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
