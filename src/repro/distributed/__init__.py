from .sharding import ShardingRules, constrain, default_rules, logical_sharding_tree, zero1_spec

__all__ = ["ShardingRules", "constrain", "default_rules", "logical_sharding_tree", "zero1_spec"]
