"""GPipe-style pipeline parallelism over a mesh axis (default: "pod").

``pipeline_apply`` runs S stages over M microbatches inside shard_map with
``jax.lax.ppermute`` boundary transfers: the classic (M + S - 1)-tick
schedule. Stage parameters are sharded over the pipeline axis (stage s lives
on pipeline rank s), so per-chip parameter memory drops by S at the cost of
bubble fraction (S-1)/(M+S-1).

This is the ``--pipeline pod`` option of the launcher: with 2 pods the
cross-pod link carries only [B_micro, S, D] activations per tick instead of
a full gradient all-reduce. The trade-off is measured in EXPERIMENTS.md
§Perf.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # leaves with leading [n_stages, ...]
    x: jax.Array,  # [n_micro, B_micro, ...] microbatched activations
    *,
    mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run ``x`` through S pipeline stages; returns transformed microbatches.

    stage_fn(params_slice, x_micro) -> x_micro. Stage parameters enter
    sharded over ``axis`` (leading dim); activations are replicated across
    ``axis`` outside and stream through ranks inside.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro >= 1

    def inner(params_local, x_local):
        # params_local: [1, ...] this rank's stage. x_local: all microbatches.
        params_here = jax.tree.map(lambda l: l[0], params_local)
        rank = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(x_local[0])
        outputs = jnp.zeros_like(x_local)

        def tick(t, carry):
            buf, outputs = carry
            # Stage 0 ingests microbatch t (if any); others use the received buffer.
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(rank == 0, x_local[mb_idx], buf)
            y = stage_fn(params_here, x_in)
            # Mask ticks where this rank has no live microbatch.
            live = (t - rank >= 0) & (t - rank < n_micro)
            y = jnp.where(live, y, jnp.zeros_like(y))
            # Last stage writes its finished microbatch t - (S-1).
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            write = (rank == n_stages - 1) & live
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(write, y, outputs[out_idx]),
                out_idx,
                axis=0,
            )
            # Shift activations to the next rank.
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return buf, outputs

        _, outputs = jax.lax.fori_loop(0, ticks, tick, (buf, outputs))
        # Outputs are only valid on the last rank: mask + psum broadcasts.
        if n_stages > 1:
            outputs = jax.lax.psum(
                jnp.where(rank == n_stages - 1, outputs, jnp.zeros_like(outputs)), axis
            )
        return outputs

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params),
        P(),
    )
    from .sharding import shard_map_compat

    return shard_map_compat(
        inner, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
    )(stage_params, x)
