"""Low-overhead span tracer with cross-thread and cross-wire propagation.

Design constraints (the pread hot path runs through here):

  * **~Zero cost disabled.** `span()` checks one module-level bool and
    returns a shared no-op context manager; `capture()` returns None.
    Nothing allocates, nothing takes a lock, no clock is read.
  * **Ring buffer, monotonic clocks.** Finished spans land in a bounded
    deque (oldest dropped); durations come from ``perf_counter`` and
    timestamps are wall-anchored once at import so a trace file lines up
    with log timestamps without ever going backwards.
  * **Propagation.** The current span context lives in a `ContextVar`, so
    it follows asyncio tasks for free. Thread hops (executor submit →
    worker, async bridge, engine dispatcher) carry it explicitly:
    ``ctx = capture()`` at submit, ``with attach(ctx):`` in the worker.
    The wire uses a W3C ``traceparent``-style header
    (``00-<trace32>-<span16>-01``): `current_traceparent()` on the client,
    `parse_traceparent()` + ``span(..., parent=ctx)`` on the server — one
    fleet read that crosses two gateways yields a single stitched trace.
  * **Histograms at span boundaries.** Every finished span observes its
    duration into the process histogram registry (`obs.hist`), so latency
    distributions accumulate whenever tracing is on. `timed()` is the
    always-on variant for service boundaries: it records the histogram
    even while tracing is disabled, and becomes a real span when enabled.

Span identity is (trace_id: 16 bytes hex, span_id: 8 bytes hex); a context
is the ``(trace_id, span_id)`` tuple. `dump_trace()` writes Chrome
trace-event JSON readable by chrome://tracing / Perfetto.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import deque
from contextvars import ContextVar
from time import perf_counter as _pc
from typing import Any, Dict, List, Optional, Tuple

from . import hist as _hist

SpanContext = Tuple[str, str]  # (trace_id, span_id)

#: Wall-clock anchor: span timestamps are ``_WALL0 + (perf_counter() -
#: _MONO0)`` — monotone within the process, comparable across processes to
#: within clock skew (good enough to line a trace up with server logs).
_WALL0 = time.time()
_MONO0 = time.perf_counter()

_DEFAULT_CAPACITY = 8192

_enabled = False
_lock = threading.Lock()
_spans: deque = deque(maxlen=_DEFAULT_CAPACITY)
_recorded_total = 0

_current: ContextVar[Optional[SpanContext]] = ContextVar("repro_obs_span", default=None)

#: Id scheme: one process-wide random 64-bit prefix (collision resistance
#: across processes) plus an atomic counter (uniqueness within the
#: process). ``os.urandom`` per span is a ~700 ns syscall — far too slow
#: for the pread hot path; ``next()`` on an ``itertools.count`` is a
#: GIL-atomic C call (~50 ns). The counter starts on a random 56-bit value
#: so span ids are never zero and never repeat for the process lifetime.
_TRACE_PREFIX = os.urandom(8).hex()
_id_counter = itertools.count(int.from_bytes(os.urandom(7), "big") + 1)

#: tid → thread name, filled lazily on first record from each thread:
#: ``threading.current_thread()`` costs ~300 ns, a dict probe ~40 ns.
_thread_names: Dict[int, str] = {}


def _wall(t_mono: float) -> float:
    return _WALL0 + (t_mono - _MONO0)


def _record(name, trace_id, span_id, parent_id, t0, dur, attrs) -> None:
    """Append one finished span (compact tuple; dicts are materialized at
    read time — the ring sees far more appends than reads).

    Lock-free on purpose: ``deque.append`` is a single GIL-atomic C call,
    and the total counter tolerates a (rare) lost increment under thread
    races — `tracing_stats` clamps ``dropped`` at 0, and exact accounting
    only matters to single-threaded tests. The lock guards the *read/clear*
    side (snapshot vs. resize), where consistency is worth its cost.
    """
    global _recorded_total
    tid = threading.get_ident()
    if tid not in _thread_names:
        _thread_names[tid] = threading.current_thread().name
    _spans.append((name, trace_id, span_id, parent_id, t0, dur, tid, attrs))
    _recorded_total += 1
    _hist.observe(name, dur)


def _materialize(rec) -> Dict[str, Any]:
    name, trace_id, span_id, parent_id, t0, dur, tid, attrs = rec
    return {
        "name": name,
        "trace_id": trace_id,
        "span_id": span_id,
        "parent_id": parent_id,
        "ts": _wall(t0),
        "dur_s": dur,
        "thread": tid,
        "thread_name": _thread_names.get(tid, str(tid)),
        "attrs": attrs or {},
    }


# -- enable / disable --------------------------------------------------------


def enable_tracing(capacity: Optional[int] = None) -> None:
    """Turn the recorder on. ``capacity`` sizes the ring buffer; None means
    the default (8192), not "keep the current size" — so enable/disable
    cycles are deterministic regardless of what a previous caller chose."""
    global _enabled, _spans
    want = max(1, capacity if capacity is not None else _DEFAULT_CAPACITY)
    with _lock:
        if want != _spans.maxlen:
            _spans = deque(_spans, maxlen=want)
        _enabled = True


def disable_tracing() -> None:
    global _enabled
    _enabled = False


def tracing_enabled() -> bool:
    return _enabled


def reset_tracing() -> None:
    """Clear recorded spans and counters (tests/benchmarks)."""
    global _recorded_total
    with _lock:
        _spans.clear()
        _recorded_total = 0


def tracing_stats() -> Dict[str, Any]:
    with _lock:
        recorded = len(_spans)
        total = _recorded_total
        cap = _spans.maxlen or 0
    return {
        "enabled": _enabled,
        "recorded": recorded,
        "recorded_total": total,
        "dropped": max(0, total - recorded),
        "capacity": cap,
    }


# -- context -----------------------------------------------------------------


def current_context() -> Optional[SpanContext]:
    """The (trace_id, span_id) of the innermost live span, if any."""
    return _current.get()


def capture() -> Optional[SpanContext]:
    """Context to carry across a thread hop (None while disabled: a
    submit-side flag check is the only cost of instrumented executors)."""
    if not _enabled:
        return None
    return _current.get()


class _Attach:
    """Install a carried context as current for the worker-side block."""

    __slots__ = ("_ctx", "_token")

    def __init__(self, ctx: Optional[SpanContext]):
        self._ctx = ctx
        self._token = None

    def __enter__(self):
        if self._ctx is not None:
            self._token = _current.set(self._ctx)
        return self._ctx

    def __exit__(self, *exc):
        if self._token is not None:
            _current.reset(self._token)
        return False


def attach(ctx: Optional[SpanContext]) -> _Attach:
    return _Attach(ctx)


# -- traceparent header ------------------------------------------------------

TRACEPARENT_HEADER = "traceparent"


def current_traceparent() -> Optional[str]:
    """``00-<trace_id>-<span_id>-01`` for the current context, else None."""
    ctx = _current.get()
    if ctx is None:
        return None
    return "00-%s-%s-01" % ctx


def parse_traceparent(value: Optional[str]) -> Optional[SpanContext]:
    """Parse a traceparent header into a SpanContext (None when absent or
    malformed — a bad header must never fail the request)."""
    if not value:
        return None
    parts = value.strip().split("-")
    if len(parts) < 3:
        return None
    trace_id, span_id = parts[1], parts[2]
    if len(trace_id) != 32 or len(span_id) != 16:
        return None
    try:
        int(trace_id, 16), int(span_id, 16)
    except ValueError:
        return None
    if int(trace_id, 16) == 0 or int(span_id, 16) == 0:
        return None
    return (trace_id, span_id)


# -- spans -------------------------------------------------------------------


class Span:
    """A live span; records itself into the ring buffer on exit."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "attrs", "_t0", "_token")

    def __init__(
        self,
        name: str,
        attrs: Optional[Dict[str, Any]],
        parent: Optional[SpanContext],
    ):
        if parent is None:
            parent = _current.get()
        self.span_id = sid = "%016x" % next(_id_counter)
        if parent is None:
            # Root: reuse the just-formatted span id as the trace-id suffix
            # (one %x format instead of two — this runs on the warm path).
            self.trace_id = _TRACE_PREFIX + sid
            self.parent_id = None
        else:
            self.trace_id = parent[0]
            self.parent_id = parent[1]
        self.name = name
        self.attrs = attrs
        self._t0 = 0.0
        self._token = None

    @property
    def context(self) -> SpanContext:
        return (self.trace_id, self.span_id)

    def set_attr(self, key: str, value: Any) -> None:
        if self.attrs is None:
            self.attrs = {}
        self.attrs[key] = value

    def __enter__(self) -> "Span":
        self._token = _current.set((self.trace_id, self.span_id))
        self._t0 = _pc()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = _pc()
        if self._token is not None:
            _current.reset(self._token)
        if exc_type is not None:
            self.set_attr("error", exc_type.__name__)
        # _record() inlined: this is the hottest exit in obs and the extra
        # frame showed up in the warm-pread overhead budget.
        global _recorded_total
        tid = threading.get_ident()
        if tid not in _thread_names:
            _thread_names[tid] = threading.current_thread().name
        dur = t1 - self._t0
        _spans.append(
            (self.name, self.trace_id, self.span_id, self.parent_id,
             self._t0, dur, tid, self.attrs)
        )
        _recorded_total += 1
        _hist.observe(self.name, dur)
        return False


class _NoopSpan:
    """Shared do-nothing span for the disabled path."""

    __slots__ = ()
    trace_id = None
    span_id = None
    parent_id = None
    context = None

    def set_attr(self, key: str, value: Any) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP = _NoopSpan()


def span(
    name: str,
    attrs: Optional[Dict[str, Any]] = None,
    parent: Optional[SpanContext] = None,
):
    """A span while tracing is enabled; a shared no-op otherwise."""
    if not _enabled:
        return _NOOP
    return Span(name, attrs, parent)


class _Timed:
    """Histogram-only timer: the always-on fallback for `timed()`."""

    __slots__ = ("name", "_t0")
    trace_id = None
    span_id = None
    parent_id = None
    context = None

    def __init__(self, name: str):
        self.name = name
        self._t0 = 0.0

    def set_attr(self, key: str, value: Any) -> None:
        pass

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        _hist.observe(self.name, time.perf_counter() - self._t0)
        return False


def record_span(
    name: str,
    t0: float,
    dur_s: float,
    attrs: Optional[Dict[str, Any]] = None,
    parent: Optional[SpanContext] = None,
) -> None:
    """Append an already-measured interval as a completed span.

    For hot paths that decide *after the fact* whether the interval is
    interesting (e.g. a cache lookup records only on miss): the caller pays
    one ``perf_counter()`` up front and only builds a span for the rare
    outcome, instead of allocating a live `Span` on every iteration. The
    recorded span parents under the current context (or ``parent``) like a
    live span would, but cannot itself have children — by the time it is
    recorded, the interval is over.
    """
    if not _enabled:
        return
    ctx = parent if parent is not None else _current.get()
    if ctx is None:
        trace_id = _TRACE_PREFIX + ("%016x" % next(_id_counter))
        parent_id = None
    else:
        trace_id, parent_id = ctx
    _record(name, trace_id, "%016x" % next(_id_counter), parent_id, t0, dur_s, attrs)


def timed(
    name: str,
    attrs: Optional[Dict[str, Any]] = None,
    parent: Optional[SpanContext] = None,
):
    """Always-on latency boundary: observes the duration histogram even
    while tracing is disabled, upgrades to a full span when enabled. Use at
    service boundaries (read_range, gateway request, bridge, executor) —
    not in per-chunk hot loops, which use `span()` and cost one flag check
    while disabled."""
    if _enabled:
        return Span(name, attrs, parent)
    return _Timed(name)


# -- recorded-span access ----------------------------------------------------


def recorded_spans() -> List[Dict[str, Any]]:
    """Snapshot of the ring buffer, oldest first."""
    with _lock:
        out = list(_spans)
    return [_materialize(r) for r in out]


def drain_spans() -> List[Dict[str, Any]]:
    """Snapshot and clear the ring buffer."""
    global _recorded_total
    with _lock:
        out = list(_spans)
        _spans.clear()
        _recorded_total = 0
    return [_materialize(r) for r in out]


def spans_for(trace_id: str) -> List[Dict[str, Any]]:
    """All recorded spans of one trace (the slow-request span tree)."""
    with _lock:
        out = [r for r in _spans if r[1] == trace_id]
    return [_materialize(r) for r in out]


def span_tree(trace_id: str) -> List[Dict[str, Any]]:
    """`spans_for` sorted by start time — readable as an indented tree."""
    return sorted(spans_for(trace_id), key=lambda s: s["ts"])


def dump_trace(path: Optional[str] = None, spans: Optional[List[Dict[str, Any]]] = None):
    """Chrome trace-event JSON for the recorded spans.

    Returns the trace dict; writes it to ``path`` when given. Load the file
    in chrome://tracing or https://ui.perfetto.dev — one row per thread,
    spans nested by duration, args carry the span/trace ids so a wire hop
    can be followed across two processes' dumps.
    """
    if spans is None:
        spans = recorded_spans()
    pid = os.getpid()
    events: List[Dict[str, Any]] = []
    seen_threads: Dict[int, str] = {}
    for s in spans:
        tid = s["thread"] or 0
        if tid not in seen_threads:
            seen_threads[tid] = s["thread_name"] or str(tid)
            events.append({
                "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                "args": {"name": seen_threads[tid]},
            })
        args = dict(s["attrs"])
        args["trace_id"] = s["trace_id"]
        args["span_id"] = s["span_id"]
        if s["parent_id"]:
            args["parent_id"] = s["parent_id"]
        events.append({
            "ph": "X",
            "name": s["name"],
            "cat": "repro",
            "pid": pid,
            "tid": tid,
            "ts": s["ts"] * 1e6,
            "dur": s["dur_s"] * 1e6,
            "args": args,
        })
    trace = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f)
            f.write("\n")
    return trace
