"""Prometheus text exposition for the nested ``metrics()`` snapshot.

The snapshot is a tree of counters/gauges with a handful of *instance-keyed*
sections (per-file handles, per-tenant tables, fleet peers, cache tiers).
`render_prometheus` flattens it:

  * nested dict keys join into the metric name
    (``scheduler.done`` → ``repro_scheduler_done``);
  * instance-keyed sections become labels instead of name parts
    (``per_file["f0"]["reads"]`` →
    ``repro_file_reads{handle="f0",...}``) — the mapping lives in
    `LABEL_DIMENSIONS`;
  * *string* fields inside a dict become labels on that dict's numeric
    samples (``per_file["f0"]["codec"] == "gzip"`` attaches
    ``codec="gzip"``), which is how tenant/handle/codec ride along;
  * the ``obs.histograms`` section renders as real Prometheus histograms:
    one ``<prefix>_latency_seconds`` family, ``span`` label per series,
    cumulative ``_bucket`` rows (``le`` in seconds), ``_sum``/``_count``;
  * booleans render 0/1; None and non-finite floats are dropped; lists are
    dropped (histogram bucket vectors are the one list that matters and it
    is handled above).

Everything is typed ``gauge`` except the histogram family: the snapshot
does not distinguish counters from gauges, and an untyped/gauge series
scrapes identically — ``rate()`` still works on monotone series.
"""

from __future__ import annotations

import math
import re
from typing import Any, Dict, List, Mapping, Tuple

#: Sections whose immediate children are instances: key becomes this label.
LABEL_DIMENSIONS: Dict[str, str] = {
    "per_file": "handle",
    "per_reader": "handle",
    "tenants": "tenant",
    "per_tenant": "tenant",
    "dispatch_per_tenant": "tenant",
    "dispatched_bytes_per_tenant": "tenant",
    "tenant_quanta": "tenant",
    "deficit_per_tenant": "tenant",
    "bytes_served_per_tenant": "tenant",
    "admission": "tenant",
    "streams_in_progress": "stream",
    "jobs": "job",
    "peers": "peer",
    "tiers": "tier",
}

#: Name segments dropped when a key was converted to a label ("per_file"
#: reads better as "file_..." than "per_file_...").
_NAME_REWRITES = {"per_file": "file", "per_reader": "reader", "streams_in_progress": "stream"}

_NAME_OK = re.compile(r"[^a-zA-Z0-9_]")
_FIRST_OK = re.compile(r"^[^a-zA-Z_]")

Sample = Tuple[Dict[str, str], float]


def _metric_name(parts: List[str]) -> str:
    name = "_".join(_NAME_OK.sub("_", p) for p in parts if p)
    return _FIRST_OK.sub("_", name) if _FIRST_OK.match(name) else name


def _escape_label(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _render_labels(labels: Mapping[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        '%s="%s"' % (k, _escape_label(str(v))) for k, v in sorted(labels.items())
    )
    return "{%s}" % inner


def _format_value(v: float) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def _walk(
    node: Any,
    parts: List[str],
    labels: Dict[str, str],
    out: Dict[str, List[Sample]],
) -> None:
    if isinstance(node, bool):
        out.setdefault(_metric_name(parts), []).append((labels, 1.0 if node else 0.0))
        return
    if isinstance(node, (int, float)):
        if isinstance(node, float) and not math.isfinite(node):
            return
        out.setdefault(_metric_name(parts), []).append((labels, node))
        return
    if not isinstance(node, Mapping):
        return  # strings were promoted to labels by the caller; lists drop
    # String fields of this dict label its (and its children's) samples.
    here = dict(labels)
    for k, v in node.items():
        if isinstance(v, str):
            lk = _NAME_OK.sub("_", str(k))
            if lk and lk not in here:
                here[lk] = v
    for k, v in node.items():
        if isinstance(v, str):
            continue
        key = str(k)
        dim = LABEL_DIMENSIONS.get(key)
        if dim is not None and isinstance(v, Mapping):
            base = parts + [_NAME_REWRITES.get(key, key.replace("per_", "", 1) if key.startswith("per_") else key)]
            for inst, sub in v.items():
                inst_labels = dict(here)
                inst_labels[dim] = str(inst)
                _walk(sub, base, inst_labels, out)
        else:
            _walk(v, parts + [key], here, out)


def _render_histograms(
    hists: Mapping[str, Mapping[str, Any]], prefix: str, lines: List[str]
) -> None:
    family = "%s_latency_seconds" % prefix
    lines.append("# HELP %s Span/boundary latency (log2 buckets)." % family)
    lines.append("# TYPE %s histogram" % family)
    for name in sorted(hists):
        snap = hists[name]
        labels = {"span": name}
        count = int(snap.get("count", 0))
        cum = 0
        for le_s, cumulative in snap.get("buckets", []):
            cum = int(cumulative)
            bl = dict(labels)
            bl["le"] = repr(float(le_s))
            lines.append(
                "%s_bucket%s %d" % (family, _render_labels(bl), cum)
            )
        bl = dict(labels)
        bl["le"] = "+Inf"
        lines.append("%s_bucket%s %d" % (family, _render_labels(bl), count))
        lines.append(
            "%s_sum%s %s"
            % (family, _render_labels(labels), _format_value(float(snap.get("sum_s", 0.0))))
        )
        lines.append("%s_count%s %d" % (family, _render_labels(labels), count))


def render_prometheus(snapshot: Mapping[str, Any], prefix: str = "repro") -> str:
    """The full nested snapshot as Prometheus exposition text (version
    0.0.4 text format; one trailing newline)."""
    snapshot = dict(snapshot)
    obs_section = snapshot.get("obs")
    hists: Mapping[str, Any] = {}
    if isinstance(obs_section, Mapping):
        obs_rest = dict(obs_section)
        maybe = obs_rest.pop("histograms", {})
        if isinstance(maybe, Mapping):
            hists = maybe
        obs_rest.pop("slow_requests", None)  # span trees are not samples
        snapshot["obs"] = obs_rest

    samples: Dict[str, List[Sample]] = {}
    _walk(snapshot, [prefix], {}, samples)

    lines: List[str] = []
    for name in sorted(samples):
        lines.append("# TYPE %s gauge" % name)
        for labels, value in samples[name]:
            lines.append("%s%s %s" % (name, _render_labels(labels), _format_value(value)))
    if hists:
        _render_histograms(hists, prefix, lines)
    return "\n".join(lines) + "\n"
