"""Coerce arbitrary snapshot trees into strictly-JSON-serializable form.

`metrics()`/`snapshot()`/`stats()` dicts are *supposed* to be plain JSON,
but drift happens: a numpy scalar from the device engine, a float nan from
a rate with zero denominator, a tuple key, an exception stashed in a job
record. `sanitize_snapshot` is the gateway-boundary guard: whatever leaks
in, what goes over the wire round-trips through ``json.dumps``/``loads``
without a custom encoder.
"""

from __future__ import annotations

import math
from typing import Any

_SCALARS = (str, int, bool)


def sanitize_snapshot(obj: Any) -> Any:
    """Deep-copy ``obj`` into dict/list/str/int/float/bool/None only.

    Rules: mapping keys become strings; tuples/sets/frozensets become
    lists; non-finite floats become None (json.dumps would emit invalid
    ``NaN``/``Infinity`` tokens); numpy-style scalars are unwrapped via
    ``.item()``; anything else falls back to ``repr``.
    """
    if obj is None or isinstance(obj, _SCALARS):
        return obj
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {str(k): sanitize_snapshot(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [sanitize_snapshot(v) for v in obj]
    if isinstance(obj, (bytes, bytearray)):
        return obj.decode("utf-8", "replace")
    # numpy scalars (and 0-d arrays) unwrap to python scalars via .item().
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            return sanitize_snapshot(item())
        except Exception:  # noqa: BLE001 - fall through to repr
            pass
    return repr(obj)
