"""Fixed log-bucket latency histograms: lock-cheap, mergeable, Prom-ready.

Bucket scheme: powers of two in **microseconds**. Bucket *i* (0-based)
counts observations with ``value <= 2^i µs`` (and above the previous
bound); the final bucket is the +Inf overflow. 27 finite bounds span 1 µs
to ~67 s — a cache-hit pread and a cold remote scan land in the same
scheme, with ~2x relative error, and every histogram in the fleet shares
the bounds so snapshots merge by plain vector addition.

``observe`` is a single GIL-atomic ``deque.append`` of the raw float;
bucketization is deferred to snapshot time. That asymmetry is deliberate:
the hot path runs *between* megabyte memcpys, so its true cost is cache
misses, not instructions — an append touches two objects (the histogram
and its deque) where bucketize-under-lock touches dozens (lock, counts
list, boxed ints), and each cold line is ~100-300 ns on a virtualized
host. Readers (`snapshot`, `merge`) drain the pending deque into the
bucket vector under the lock; a reader racing a writer can miss an
in-flight append, which the next snapshot picks up — counts are still
monotone, which is all scrapers assume. Percentiles are read from the
cumulative vector at snapshot time: the reported pXX is the *upper bound*
of the bucket containing that quantile (conservative: the true latency is
≤ the reported number).
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, Dict, List, Mapping, Optional

#: Finite bucket upper bounds, in microseconds: 1µs, 2µs, 4µs ... 2^26µs.
_FINITE_BUCKETS = 27
BUCKET_BOUNDS_US: List[int] = [1 << i for i in range(_FINITE_BUCKETS)]
_NBUCKETS = _FINITE_BUCKETS + 1  # + overflow (+Inf)


def bucket_index(seconds: float) -> int:
    """Index of the bucket whose upper bound first covers ``seconds``."""
    us = seconds * 1e6
    if us <= 1.0:
        return 0
    u = int(us)
    if u < us:
        u += 1  # ceil: the bound must be >= the value
    idx = (u - 1).bit_length()
    return idx if idx < _NBUCKETS else _NBUCKETS - 1


class LogHistogram:
    """One latency distribution; thread-safe; merge by vector addition."""

    __slots__ = ("_lock", "_counts", "_sum", "_count", "_pending")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counts = [0] * _NBUCKETS
        self._sum = 0.0
        self._count = 0
        self._pending: deque = deque()

    def observe(self, seconds: float) -> None:
        # Hot path: one GIL-atomic append, no lock, no arithmetic. The
        # bucketization happens in `_drain_locked` when someone reads.
        self._pending.append(seconds)

    def _drain_locked(self) -> None:
        """Fold pending observations into the bucket vector (lock held).

        `popleft` until empty rather than swapping the deque out: an append
        racing the drain either lands before the final popleft (folded now)
        or after (folded by the next reader) — never lost.
        """
        pending = self._pending
        while True:
            try:
                seconds = pending.popleft()
            except IndexError:
                break
            if seconds < 0.0:
                seconds = 0.0
            us = seconds * 1e6
            if us <= 1.0:
                idx = 0
            else:
                u = int(us)
                if u < us:
                    u += 1
                idx = (u - 1).bit_length()
                if idx >= _NBUCKETS:
                    idx = _NBUCKETS - 1
            self._counts[idx] += 1
            self._sum += seconds
            self._count += 1

    def merge(self, other: "LogHistogram") -> None:
        with other._lock:
            other._drain_locked()
            counts = list(other._counts)
            total, s = other._count, other._sum
        with self._lock:
            self._drain_locked()
            for i, c in enumerate(counts):
                self._counts[i] += c
            self._count += total
            self._sum += s

    def _percentile_locked(self, counts: List[int], total: int, q: float) -> float:
        """Upper bound (seconds) of the bucket holding quantile ``q``."""
        if total <= 0:
            return 0.0
        target = q * total
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if cum >= target:
                if i < _FINITE_BUCKETS:
                    return BUCKET_BOUNDS_US[i] / 1e6
                # Overflow bucket: no finite bound; report twice the last
                # finite bound as a sentinel ("slower than the scheme").
                return (BUCKET_BOUNDS_US[-1] * 2) / 1e6
        return (BUCKET_BOUNDS_US[-1] * 2) / 1e6

    def snapshot(self) -> Dict[str, Any]:
        """Plain-JSON summary: count/sum, p50/p90/p99, cumulative buckets.

        ``buckets`` is a list of ``[le_seconds, cumulative_count]`` pairs
        over the finite bounds (the +Inf cumulative equals ``count``) —
        exactly the series Prometheus exposition needs. Empty buckets are
        elided to keep snapshots small; cumulative counts make that
        lossless.
        """
        with self._lock:
            self._drain_locked()
            counts = list(self._counts)
            total = self._count
            s = self._sum
        buckets: List[List[float]] = []
        cum = 0
        prev = 0
        for i in range(_FINITE_BUCKETS):
            cum += counts[i]
            if cum != prev:
                buckets.append([BUCKET_BOUNDS_US[i] / 1e6, cum])
                prev = cum
        return {
            "count": total,
            "sum_s": s,
            "p50_s": self._percentile_locked(counts, total, 0.50),
            "p90_s": self._percentile_locked(counts, total, 0.90),
            "p99_s": self._percentile_locked(counts, total, 0.99),
            "buckets": buckets,
        }


class HistogramRegistry:
    """Name → LogHistogram map; creation is locked, observation is not."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._hists: Dict[str, LogHistogram] = {}

    def get(self, name: str) -> LogHistogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, LogHistogram())
        return h

    def observe(self, name: str, seconds: float) -> None:
        self.get(name).observe(seconds)

    def names(self) -> List[str]:
        if self is _REGISTRY:
            _flush_pending()
        with self._lock:
            return sorted(self._hists)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        if self is _REGISTRY:
            _flush_pending()
        with self._lock:
            items = list(self._hists.items())
        return {name: h.snapshot() for name, h in sorted(items)}

    def reset(self) -> None:
        with self._lock:
            self._hists.clear()


#: Process-wide registry: spans and the always-on `timed()` boundaries all
#: observe here; `ArchiveServer.metrics()` snapshots it.
_REGISTRY = HistogramRegistry()

#: Module-wide pending (name, seconds) observations. `observe` appends here
#: — one GIL-atomic deque touch, no registry dict probe — and readers fold
#: the backlog into per-name histograms via `_flush_pending` before every
#: snapshot. Same monotone-counts contract as LogHistogram's own pending
#: deque, one level up.
_PENDING: deque = deque()


def registry() -> HistogramRegistry:
    return _REGISTRY


def observe(name: str, seconds: float) -> None:
    _PENDING.append((name, seconds))


def _flush_pending() -> None:
    pending = _PENDING
    get = _REGISTRY.get
    while True:
        try:
            name, seconds = pending.popleft()
        except IndexError:
            break
        get(name)._pending.append(seconds)


def histogram_snapshots() -> Dict[str, Dict[str, Any]]:
    _flush_pending()
    return _REGISTRY.snapshot()


def reset_histograms() -> None:
    _PENDING.clear()
    _REGISTRY.reset()


def merge_snapshots(
    a: Mapping[str, Any], b: Mapping[str, Any]
) -> Dict[str, Any]:
    """Merge two ``LogHistogram.snapshot()`` dicts (cross-process rollup).

    Percentiles are recomputed from the merged cumulative vectors, so the
    result is exactly what one histogram fed both streams would report.
    """
    def expand(snap: Mapping[str, Any]) -> List[int]:
        counts = [0] * _NBUCKETS
        cum_prev = 0
        bounds = {b: i for i, b in enumerate(BUCKET_BOUNDS_US)}
        for le_s, cum in snap.get("buckets", []):
            idx = bounds.get(int(round(le_s * 1e6)))
            if idx is None:
                continue
            counts[idx] += int(cum) - cum_prev
            cum_prev = int(cum)
        counts[_NBUCKETS - 1] += int(snap.get("count", 0)) - cum_prev
        return counts

    merged = LogHistogram()
    for snap in (a, b):
        counts = expand(snap)
        with merged._lock:
            for i, c in enumerate(counts):
                merged._counts[i] += c
            merged._count += int(snap.get("count", 0))
            merged._sum += float(snap.get("sum_s", 0.0))
    return merged.snapshot()
