"""Observability substrate: tracing, latency histograms, Prometheus text.

Dependency-free (stdlib only) on purpose — `repro.core` imports this from
its hottest paths, so nothing here may pull in jax, numpy, or the service
layer. Three pieces:

  * `trace` — ring-buffer span recorder with contextvar propagation across
    thread hops and a ``traceparent``-style header for the wire. ~Zero cost
    while disabled (one flag check per instrumentation site); enable with
    `enable_tracing()`. Export with `dump_trace()` (Chrome trace-event
    JSON, load in chrome://tracing or Perfetto).
  * `hist` — fixed log-bucket latency histograms (power-of-two microsecond
    buckets), lock-cheap and mergeable; every finished span observes into
    the process registry, plus a few always-on service boundaries record
    even while tracing is off.
  * `prom` — renders the nested ``metrics()`` snapshot as Prometheus
    exposition text, histograms included (`_bucket`/`_sum`/`_count`).

`sanitize_snapshot` is the gateway-boundary helper that coerces any
snapshot into strictly-JSON-serializable form.
"""

from .hist import (  # noqa: F401
    BUCKET_BOUNDS_US,
    HistogramRegistry,
    LogHistogram,
    histogram_snapshots,
    observe,
    registry,
    reset_histograms,
)
from .prom import render_prometheus  # noqa: F401
from .sanitize import sanitize_snapshot  # noqa: F401
from .trace import (  # noqa: F401
    attach,
    capture,
    current_context,
    current_traceparent,
    disable_tracing,
    drain_spans,
    dump_trace,
    enable_tracing,
    parse_traceparent,
    recorded_spans,
    reset_tracing,
    span,
    spans_for,
    timed,
    tracing_enabled,
    tracing_stats,
)

__all__ = [
    "BUCKET_BOUNDS_US",
    "HistogramRegistry",
    "LogHistogram",
    "attach",
    "capture",
    "current_context",
    "current_traceparent",
    "disable_tracing",
    "drain_spans",
    "dump_trace",
    "enable_tracing",
    "histogram_snapshots",
    "observe",
    "parse_traceparent",
    "recorded_spans",
    "registry",
    "render_prometheus",
    "reset_histograms",
    "reset_tracing",
    "sanitize_snapshot",
    "span",
    "spans_for",
    "timed",
    "tracing_enabled",
    "tracing_stats",
]
