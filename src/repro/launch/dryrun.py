import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax-importing module: jax locks the
device count at first backend init, and the dry-run needs 512 placeholder
host devices to build the production meshes ((16,16) and (2,16,16)).
Everything else (tests, benches, examples) keeps seeing 1 CPU device.

Per cell this lowers the *real* step function (train_step with AdamW+ZeRO-1
for train shapes; prefill/serve steps for inference shapes), compiles it,
prints ``memory_analysis()`` (proof-of-fit) and ``cost_analysis()``, parses
the collective mix out of the optimized HLO, and appends everything to a
resumable JSON results file consumed by EXPERIMENTS.md §Dry-run/§Roofline
and benchmarks/roofline_report.py.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun.json
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, all_configs, get_config, input_specs, shape_applicable
from ..distributed.sharding import default_rules
from ..models.model import build_model
from ..train.optimizer import AdamWConfig, init_opt_state
from ..train.train_step import batch_shardings, make_train_step, opt_state_shardings, param_shardings
from ..serve.serve_step import make_serve_steps
from .mesh import make_production_mesh
from .roofline import collective_wire_bytes, model_flops, roofline_terms


from ..distributed.sharding import batch_partition as _batch_sharding_for


def _layer_variants(cfg):
    """Two reduced-layer configs for per-layer cost extrapolation.

    XLA's cost analysis counts while-loop (scan) bodies once, so raw
    cost_analysis under-reports per-step flops/bytes by ~n_layers. Lowering
    the same cell at two small layer counts and extrapolating linearly
    recovers the true totals (§Roofline methodology).
    """
    import dataclasses

    if cfg.slstm_every:  # xlstm: layer count quantized to groups
        g = cfg.slstm_every
        return (
            dataclasses.replace(cfg, n_layers=g, scan_unroll=True),
            dataclasses.replace(cfg, n_layers=2 * g, scan_unroll=True),
            cfg.n_layers,
            g,
            2 * g,
        )
    if cfg.encoder_layers:  # whisper: encoder+decoder scale together
        return (
            dataclasses.replace(cfg, n_layers=1, encoder_layers=1, scan_unroll=True),
            dataclasses.replace(cfg, n_layers=2, encoder_layers=2, scan_unroll=True),
            cfg.n_layers,
            1,
            2,
        )
    fd = cfg.first_dense_layers
    return (
        dataclasses.replace(cfg, n_layers=fd + 1, scan_unroll=True),
        dataclasses.replace(cfg, n_layers=fd + 2, scan_unroll=True),
        cfg.n_layers,
        fd + 1,
        fd + 2,
    )


def _measure(cfg, shape, mesh, rules, n_chips) -> Dict[str, float]:
    """Lower+compile one variant; return (flops, bytes, wire) per chip."""
    model = build_model(cfg)
    params_abs = model.abstract()
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        jit_step, _ = make_train_step(model, mesh, rules, AdamWConfig(total_steps=1000))
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        lowered = jit_step.lower(params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        from ..models.transformer import ModelContext

        ctx = ModelContext(mesh, rules)
        p_shard = param_shardings(model, mesh, rules)
        b_shard = {
            k: NamedSharding(
                mesh,
                P(*(list(_batch_sharding_for(mesh, v.shape[0])) + [None] * (len(v.shape) - 1))),
            )
            for k, v in specs.items()
        }
        fn = jax.jit(lambda p, b: model.prefill(p, b, ctx), in_shardings=(p_shard, b_shard))
        lowered = fn.lower(params_abs, specs)
    else:
        _, jit_decode, caches_abs, _ = make_serve_steps(
            model, mesh, rules, batch=shape.global_batch, max_len=shape.seq_len
        )
        lowered = jit_decode.lower(
            params_abs, specs["tokens"], caches_abs, jax.ShapeDtypeStruct((), jnp.int32)
        )
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    wire = collective_wire_bytes(compiled.as_text(), default_group=n_chips)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": float(wire["total"]),
    }


def calibrate_cell(arch: str, shape_name: str, *, multi_pod: bool) -> Dict[str, Any]:
    """Per-layer extrapolated roofline terms (see _layer_variants)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    rules = default_rules(mesh)
    cfg1, cfg2, L, l1, l2 = _layer_variants(cfg)
    m1 = _measure(cfg1, shape, mesh, rules, n_chips)
    m2 = _measure(cfg2, shape, mesh, rules, n_chips)
    out: Dict[str, Any] = {}
    for k in ("flops", "bytes", "wire"):
        per_layer = max(0.0, (m2[k] - m1[k]) / (l2 - l1))
        out[k] = m2[k] + per_layer * (L - l2)
        out[k + "_per_layer"] = per_layer
    terms = roofline_terms(
        {"flops": out["flops"], "bytes accessed": out["bytes"]}, {"total": out["wire"]}
    )
    out["roofline"] = {k: (v if isinstance(v, str) else float(v)) for k, v in terms.items()}
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    cell: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    if not ok:
        cell["status"] = "skipped"
        cell["reason"] = reason
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    rules = default_rules(mesh)
    model = build_model(cfg)
    params_abs = model.abstract()
    specs = input_specs(cfg, shape)

    t0 = time.time()
    if shape.kind == "train":
        jit_step, shardings = make_train_step(
            model, mesh, rules, AdamWConfig(total_steps=1000), grad_accum=1
        )
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        lowered = jit_step.lower(params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        from ..models.transformer import ModelContext

        ctx = ModelContext(mesh, rules)
        p_shard = param_shardings(model, mesh, rules)
        b_shard = {
            k: NamedSharding(
                mesh,
                P(*(list(_batch_sharding_for(mesh, v.shape[0])) + [None] * (len(v.shape) - 1))),
            )
            for k, v in specs.items()
        }
        fn = jax.jit(
            lambda p, b: model.prefill(p, b, ctx), in_shardings=(p_shard, b_shard)
        )
        lowered = fn.lower(params_abs, specs)
    else:  # decode
        _, jit_decode, caches_abs, _ = make_serve_steps(
            model, mesh, rules, batch=shape.global_batch, max_len=shape.seq_len
        )
        tokens_abs = specs["tokens"]
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jit_decode.lower(params_abs, tokens_abs, caches_abs, pos_abs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    hlo = compiled.as_text()
    wire = collective_wire_bytes(hlo, default_group=n_chips)
    counts = wire.pop("counts")
    terms = roofline_terms(cost, wire)

    mflops = model_flops(cfg, shape)
    per_chip_model_flops = mflops / n_chips
    cell.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
        cost={k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
        collectives={k: float(v) for k, v in wire.items()},
        collective_counts=counts,
        roofline={k: (v if isinstance(v, str) else float(v)) for k, v in terms.items()},
        model_flops_total=float(mflops),
        model_flops_per_chip=float(per_chip_model_flops),
        useful_flops_fraction=(
            per_chip_model_flops / terms["flops"] if terms["flops"] else 0.0
        ),
        n_chips=n_chips,
    )
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--calibrate",
        action="store_true",
        help="add per-layer-extrapolated roofline terms to existing ok cells",
    )
    args = ap.parse_args()

    archs = sorted(all_configs()) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: Dict[str, Any] = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                key = f"{arch}|{shape_name}|{'multi' if multi_pod else 'single'}"
                if args.calibrate:
                    cell = results.get(key)
                    if cell is None or cell.get("status") != "ok":
                        continue
                    if "calibrated" in cell and not args.force:
                        print(f"[dryrun] {key}: calibrated (cached)")
                        continue
                    print(f"[dryrun] {key}: calibrating...", flush=True)
                    try:
                        cell["calibrated"] = calibrate_cell(arch, shape_name, multi_pod=multi_pod)
                        r = cell["calibrated"]["roofline"]
                        print(
                            f"[dryrun] {key}: calibrated compute={r['t_compute']:.3e}s "
                            f"memory={r['t_memory']:.3e}s collective={r['t_collective']:.3e}s "
                            f"dominant={r['dominant']}",
                            flush=True,
                        )
                    except Exception as exc:  # noqa: BLE001
                        cell["calibrated"] = {"error": f"{type(exc).__name__}: {exc}"}
                        print(f"[dryrun] {key}: calibration error {exc}", flush=True)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
                    continue
                if key in results and results[key].get("status") in ("ok", "skipped") and not args.force:
                    print(f"[dryrun] {key}: cached ({results[key]['status']})")
                    continue
                print(f"[dryrun] {key}: lowering...", flush=True)
                try:
                    cell = lower_cell(arch, shape_name, multi_pod=multi_pod)
                except Exception as exc:  # noqa: BLE001 — recorded, not fatal
                    cell = {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": "2x16x16" if multi_pod else "16x16",
                        "status": "error",
                        "error": f"{type(exc).__name__}: {exc}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                results[key] = cell
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = cell["status"]
                extra = ""
                if status == "ok":
                    r = cell["roofline"]
                    extra = (
                        f" compute={r['t_compute']:.3e}s memory={r['t_memory']:.3e}s "
                        f"collective={r['t_collective']:.3e}s dominant={r['dominant']} "
                        f"compile={cell['compile_s']:.0f}s"
                    )
                print(f"[dryrun] {key}: {status}{extra}", flush=True)

    n_ok = sum(1 for c in results.values() if c["status"] == "ok")
    n_skip = sum(1 for c in results.values() if c["status"] == "skipped")
    n_err = sum(1 for c in results.values() if c["status"] == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped-by-design, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
