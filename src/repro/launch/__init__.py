# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and must
# only ever be imported as the entry module of a fresh process.
from .mesh import make_host_mesh, make_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_mesh", "make_production_mesh"]
