"""End-to-end training driver: gzip corpus -> parallel decompression ->
tokens -> pjit train step, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train \
        --arch granite-3-2b --smoke --steps 50 --corpus /tmp/corpus \
        --ckpt /tmp/ckpt --ckpt-every 20

On restart the driver restores model+optimizer state AND the data-pipeline
seek state (O(1) thanks to the gzip seek index — the paper's random-access
capability is what makes data restart cheap).
"""

from __future__ import annotations

import argparse
import glob
import os
import time

import jax
import numpy as np

from ..checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from ..configs import all_configs, get_config, smoke_config
from ..data import GzipCorpusDataset
from ..distributed import default_rules
from ..models import build_model
from ..train import AdamWConfig, init_train_state, make_train_step
from .mesh import make_host_mesh


def make_corpus(directory: str, n_shards: int = 2, shard_bytes: int = 1 << 20) -> None:
    """Synthesize a small gzip text corpus if none exists."""
    import gzip as _gzip

    os.makedirs(directory, exist_ok=True)
    rng = np.random.default_rng(0)
    words = [b"the", b"quick", b"brown", b"fox", b"jumps", b"over", b"lazy",
             b"dog", b"training", b"corpus", b"gzip", b"parallel"]
    for i in range(n_shards):
        path = os.path.join(directory, f"shard_{i:03d}.gz")
        if os.path.exists(path):
            continue
        idx = rng.integers(0, len(words), shard_bytes // 5)
        data = b" ".join(words[j] for j in idx)[:shard_bytes]
        with open(path, "wb") as f:
            f.write(_gzip.compress(data, 6))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=sorted(all_configs()))
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--corpus", default="/tmp/repro_corpus")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--decomp-parallelism", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    model = build_model(cfg)
    mesh = make_host_mesh()
    rules = default_rules(mesh)

    make_corpus(args.corpus)
    shards = sorted(glob.glob(os.path.join(args.corpus, "*.gz")))
    ds = GzipCorpusDataset(
        shards,
        seq_len=args.seq,
        batch_size=args.batch * args.grad_accum,
        parallelization=args.decomp_parallelism,
        chunk_size=256 << 10,
    )

    params, opt = init_train_state(model, jax.random.PRNGKey(0), compress_grads=args.compress_grads)
    start_step = 0
    if args.ckpt:
        path = latest_checkpoint(args.ckpt)
        if path:
            template = {"params": params, "opt": opt, "data": ds.state_dict()}
            start_step, state = restore_checkpoint(path, template)
            params, opt = state["params"], state["opt"]
            ds.load_state_dict(state["data"])
            print(f"[train] restored step {start_step} from {path}")

    step_fn, _ = make_train_step(
        model, mesh, rules,
        AdamWConfig(peak_lr=args.lr, warmup_steps=max(5, args.steps // 20), total_steps=args.steps),
        grad_accum=args.grad_accum,
        compress_grads=args.compress_grads,
    )

    t_data = t_step = 0.0
    for step in range(start_step, args.steps):
        t0 = time.perf_counter()
        batch = ds.next_batch()
        t_data += time.perf_counter() - t0
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        t_step += time.perf_counter() - t0
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, step + 1,
                            {"params": params, "opt": opt, "data": ds.state_dict()})
            print(f"[train] checkpoint @ step {step + 1}")

    tokens = args.steps * args.batch * args.grad_accum * args.seq
    print(f"[train] done: {tokens} tokens; data {t_data:.1f}s, step {t_step:.1f}s "
          f"(data-pipeline share {100*t_data/max(t_data+t_step,1e-9):.1f}%)")
    ds.close()


if __name__ == "__main__":
    main()
