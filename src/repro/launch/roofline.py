"""Roofline-term extraction from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_chip / 197e12        (bf16 peak, TPU v5e)
    memory     = HLO_bytes_per_chip / 819e9          (HBM bandwidth)
    collective = wire_bytes_per_chip / 50e9          (one ICI link, conservative)

``cost_analysis()`` on an SPMD-partitioned executable reports the
*per-partition* program, so terms are per-chip by construction. Collective
wire bytes are parsed from the optimized HLO: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op's shape,
scaled by the ring-algorithm wire factor for its replica-group size n:

    all-reduce      2 * (n-1)/n * size
    all-gather      (n-1)/n * size          (size = gathered output)
    reduce-scatter  (n-1) * size            (size = scattered output)
    all-to-all      (n-1)/n * size
    collective-permute  size
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1)
        return max(1, first.count(",") + 1)
    return default


def collective_wire_bytes(hlo_text: str, *, default_group: int = 1) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind (ring-algorithm accounting)."""
    out: Dict[str, float] = {
        "all-gather": 0.0,
        "all-reduce": 0.0,
        "reduce-scatter": 0.0,
        "all-to-all": 0.0,
        "collective-permute": 0.0,
    }
    counts: Dict[str, int] = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        n = _group_size(line, default_group)
        if n <= 1:
            continue
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif kind == "all-gather":
            wire = (n - 1) / n * size
        elif kind == "reduce-scatter":
            wire = float(n - 1) * size
        elif kind == "all-to-all":
            wire = (n - 1) / n * size
        else:  # collective-permute
            wire = float(size)
        out[kind] += wire
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts  # type: ignore[assignment]
    return out


def roofline_terms(
    cost: Dict[str, float],
    wire: Dict[str, float],
    *,
    while_trip_counts: Optional[List[int]] = None,
) -> Dict[str, float]:
    """Three roofline terms in seconds (per chip, per step)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = float(wire.get("total", 0.0))
    terms = {
        "flops": flops,
        "bytes": byts,
        "collective_bytes": coll,
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": byts / HBM_BW,
        "t_collective": coll / ICI_BW,
    }
    dominant = max(("t_compute", "t_memory", "t_collective"), key=lambda k: terms[k])
    terms["dominant"] = dominant  # type: ignore[assignment]
    bound = max(terms["t_compute"], terms["t_memory"], terms["t_collective"])
    terms["roofline_fraction"] = terms["t_compute"] / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape, n_layers_active: Optional[int] = None) -> float:
    """6 * N(_active) * D for the step's token count (train) or token (decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:
        tokens = shape.global_batch  # one new token per sequence
        mult = 2.0
    return mult * n_active * tokens
