"""Production mesh construction.

Single pod: (data=16, model=16) — 256 TPU v5e chips.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; "pod" is a pure-DP axis
by default (gradient all-reduce crosses pods once per step; EP all-to-all and
TP collectives stay intra-pod), or a 2-stage pipeline axis with
``--pipeline pod``.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto, ...)`` where supported; {} on older jax.

    jax.sharding.AxisType only exists from jax 0.5; Auto is already the
    default there, so omitting the kwarg is behavior-identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh with Auto axis types (smoke tests, examples)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """Whatever devices exist on this host as a (data, model) mesh."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
