"""Whisper tiny [arXiv:2212.04356; unverified]: enc-dec backbone; the conv
audio frontend is a stub (input_specs provides 1500 frame embeddings)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,              # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    encoder_layers=4,
    encoder_frames=1500,
    tie_embeddings=True,
))
