"""DeepSeekMoE 16B [arXiv:2401.06066; hf]: 2 shared + 64 routed top-6
fine-grained experts, 28L, d_model 2048, first layer dense."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,              # dense FFN of the first layer
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    first_dense_layers=1,
    remat_policy="dots_plus_collectives",
))
