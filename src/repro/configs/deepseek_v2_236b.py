"""DeepSeek-V2 236B [arXiv:2405.04434; hf]: MLA (kv_lora=512) + fine-grained
MoE (2 shared + 160 routed, top-6), 60L, d_model 5120, 128 heads."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,              # dense FFN of the first (non-MoE) layer
    vocab_size=102400,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1536,
    first_dense_layers=1,
    remat_policy="dots_plus_collectives",
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
))
