"""Architecture configs and assigned input shapes.

Ten architectures (public-literature configs, DESIGN.md §5) selectable via
``--arch <id>``; each pairs with the four assigned LM shapes. ``input_specs``
returns ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no device
allocation) for the dry-run; modality frontends (audio/vision) are stubs
whose precomputed embeddings appear directly in the specs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    activation: str = "silu"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: x *= sqrt(d_model)
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- hybrid / ssm ---
    ssm_state: int = 0
    ssm_expand: int = 2
    sliding_window: int = 0  # 0 = full attention
    slstm_every: int = 0  # xLSTM: one sLSTM per this many blocks
    # --- enc-dec / frontends ---
    encoder_layers: int = 0
    encoder_frames: int = 0  # audio stub: frames provided by input_specs
    vision_tokens: int = 0  # vlm stub: patch embeddings provided
    # --- numerics / perf knobs ---
    dtype: Any = jnp.bfloat16
    remat_policy: str = "dots"  # none | dots | full
    #: unroll layer/chunk scans (cost-measurement mode: XLA's cost analysis
    #: counts while bodies once, so roofline calibration compiles unrolled
    #: reduced-layer variants)
    scan_unroll: bool = False
    attn_q_chunk: int = 1024
    logit_softcap: float = 0.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 0.5M context (bounded per-token state)?"""
        return self.family in ("hybrid", "ssm")

    def param_count(self) -> int:
        """Approximate total parameter count (for 6ND roofline accounting)."""
        import math

        from ..models.model import build_model  # lazy: avoid cycle

        model = build_model(self)
        leaves = jax.tree.leaves(model.abstract())
        return sum(math.prod(l.shape) for l in leaves)

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: shared + top_k experts)."""
        total = self.param_count()
        if not self.n_experts:
            return total
        per_expert = 3 * self.d_model * self.d_ff_expert
        n_moe_layers = self.n_layers - self.first_dense_layers
        inactive = (self.n_experts - self.top_k) * per_expert * n_moe_layers
        return total - inactive


# ---------------------------------------------------------------------------
# assigned shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) — the 40-cell applicability matrix."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 0.5M-token dense KV decode skipped by design"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S + 1), i32)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_frames, cfg.d_model), cfg.dtype
            )
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), cfg.dtype
            )
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_frames, cfg.d_model), cfg.dtype
            )
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), cfg.dtype
            )
    else:  # decode: one new token against a cache of seq_len
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    return specs


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    _ensure_loaded()
    return dict(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from . import (  # noqa: F401
        deepseek_moe_16b,
        deepseek_v2_236b,
        gemma_2b,
        granite_3_2b,
        hymba_1_5b,
        internlm2_20b,
        internvl2_76b,
        qwen2_5_32b,
        whisper_tiny,
        xlstm_350m,
    )


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (small everything)."""
    replace: Dict[str, Any] = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.first_dense_layers else 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32 if cfg.head_dim else None,
        attn_q_chunk=64,
    )
    if cfg.n_experts:
        replace.update(
            n_experts=8,
            top_k=2,
            d_ff_expert=64,
            n_shared_experts=min(cfg.n_shared_experts, 1),
            first_dense_layers=min(cfg.first_dense_layers, 1),
            n_layers=3,
            # dropless at smoke scale so decode/prefill/train paths agree
            # exactly (capacity effects are length-dependent by design)
            capacity_factor=8.0,
        )
    if cfg.use_mla:
        replace.update(
            q_lora_rank=64,
            kv_lora_rank=32,
            qk_nope_head_dim=32,
            qk_rope_head_dim=16,
            v_head_dim=32,
            head_dim=None,
        )
    if cfg.ssm_state:
        replace.update(ssm_state=8)
    if cfg.sliding_window:
        replace.update(sliding_window=64)
    if cfg.slstm_every:
        replace.update(slstm_every=2, n_layers=4)
    if cfg.encoder_layers:
        replace.update(encoder_layers=2, encoder_frames=32)
    if cfg.vision_tokens:
        replace.update(vision_tokens=16)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **replace)
