from .base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    all_configs,
    get_config,
    input_specs,
    shape_applicable,
    smoke_config,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "all_configs",
    "get_config",
    "input_specs",
    "shape_applicable",
    "smoke_config",
]
