"""Hymba 1.5B [arXiv:2411.13676; hf]: hybrid blocks with parallel attention
(sliding-window GQA kv=5) + selective-SSM heads (state 16). Meta tokens are
simplified away (DESIGN.md §5)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    sliding_window=1024,
))
