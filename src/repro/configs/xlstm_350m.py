"""xLSTM 350M [arXiv:2405.04517; unverified]: sLSTM + mLSTM blocks (7:1),
24L, d_model 1024, matrix-memory heads; d_ff=0 (no separate FFN)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,           # one sLSTM per 8 blocks (7 mLSTM + 1 sLSTM)
))
