"""Gemma 2B [arXiv:2403.08295; hf]: MQA (kv=1), GeGLU, head_dim=256,
scaled + tied embeddings."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    activation="gelu",
    tie_embeddings=True,
    scale_embeddings=True,
))
