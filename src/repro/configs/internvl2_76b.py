"""InternVL2 76B [arXiv:2404.16821; unverified]: Llama3-70B-class LM backbone
(80L, d=8192, GQA kv=8); InternViT frontend is a stub (input_specs provides
patch embeddings)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    vision_tokens=256,
))
