"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

MARKER_BASE = 256
WINDOW_SIZE = 32768
TABLE_SIZE = MARKER_BASE + WINDOW_SIZE  # 33 024


# -- marker replacement -------------------------------------------------------

def marker_replace_ref(syms: jax.Array, table: jax.Array) -> jax.Array:
    """out = table[syms] (identity for literals, window gather for markers)."""
    return jnp.take(table, syms, axis=0)


def marker_replace_multi_ref(
    syms: jax.Array, tables: jax.Array, tile_tables: jax.Array
) -> jax.Array:
    """Oracle for the batched multi-window kernel: per-tile table select.

    syms: (n_tiles, R, C) int32; tables: (n_tables, TABLE_SIZE) int32;
    tile_tables: (n_tiles,) int32.
    """
    per_tile = jnp.take(tables, tile_tables, axis=0)  # (n_tiles, TABLE_SIZE)
    return jnp.take_along_axis(
        per_tile[:, :, None], syms.reshape(syms.shape[0], -1, 1), axis=1
    ).reshape(syms.shape)


def make_replacement_table(window: np.ndarray) -> np.ndarray:
    """int32 replacement table from a (possibly short) window."""
    table = np.empty(TABLE_SIZE, dtype=np.int32)
    table[:MARKER_BASE] = np.arange(MARKER_BASE)
    padded = np.zeros(WINDOW_SIZE, dtype=np.int32)
    w = np.asarray(window, dtype=np.int32)[-WINDOW_SIZE:]
    padded[WINDOW_SIZE - w.shape[0] :] = w
    table[MARKER_BASE:] = padded
    return table


# -- precode / block-finder precheck ------------------------------------------

def precode_check_ref(bits: jax.Array) -> jax.Array:
    """Candidate mask over a flat int32 0/1 bit plane (halo included).

    bits: (n,) with n >= offsets + 74; returns (n - 74,) int32 mask.
    """
    n = bits.shape[0] - 74

    def field(at, width):
        out = jax.lax.dynamic_slice_in_dim(bits, at, n)
        for j in range(1, width):
            out = out | (jax.lax.dynamic_slice_in_dim(bits, at + j, n) << j)
        return out

    b0 = jax.lax.dynamic_slice_in_dim(bits, 0, n)
    b1 = jax.lax.dynamic_slice_in_dim(bits, 1, n)
    b2 = jax.lax.dynamic_slice_in_dim(bits, 2, n)
    ok = (b0 == 0) & (b1 == 0) & (b2 == 1)
    ok &= field(3, 5) < 30
    n_codes = field(13, 4) + 4
    kraft = jnp.zeros((n,), jnp.int32)
    for k in range(19):
        cl = field(17 + 3 * k, 3)
        active = (k < n_codes) & (cl > 0)
        kraft = kraft + jnp.where(active, jax.lax.shift_right_logical(jnp.int32(128), cl), 0)
    ok &= kraft == 128
    return ok.astype(jnp.int32)


# -- crc32 --------------------------------------------------------------------

def crc32_segments_ref(data: jax.Array, table: jax.Array) -> jax.Array:
    """Per-segment CRC32 over (R, C, L) int32 bytes."""
    def step(crc, byte):
        idx = (crc ^ byte) & 0xFF
        return jax.lax.shift_right_logical(crc, 8) ^ jnp.take(table, idx, axis=0), None

    init = jnp.full(data.shape[:2], jnp.int32(-1))
    crc, _ = jax.lax.scan(step, init, jnp.moveaxis(data, -1, 0))
    return ~crc


def crc32_segments_batched_ref(data: jax.Array, table: jax.Array) -> jax.Array:
    """Oracle for the batched CRC kernel: (B, R, C, L) -> (B, R, C)."""
    def step(crc, byte):
        idx = (crc ^ byte) & 0xFF
        return jax.lax.shift_right_logical(crc, 8) ^ jnp.take(table, idx, axis=0), None

    init = jnp.full(data.shape[:3], jnp.int32(-1))
    crc, _ = jax.lax.scan(step, init, jnp.moveaxis(data, -1, 0))
    return ~crc
