"""Pallas TPU kernel: parallel CRC32 (paper §6 future work, implemented here).

CRC32 is bit-serial per byte, but splits perfectly: each of S segments is
CRC'd independently and the per-segment values are merged on the host with
the GF(2) combine (``core/crc32.py``) — O(S log L) scalar work.

On TPU the segments map to vector lanes: one (8, 128)-shaped register of
segment states advances one byte per ``fori_loop`` step through the
byte-LUT — 1024 segment streams in parallel per tile, i.e. the classic
table-driven CRC with the table in VMEM and the "slice" dimension across
lanes instead of across the word.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SEG_ROWS = 8
SEG_COLS = 128
N_SEGMENTS = SEG_ROWS * SEG_COLS

#: The byte LUT is a pure constant — built (and transferred) once per
#: process, not per call. ``crc32_parallel`` used to rebuild it every call,
#: a measurable per-dispatch overhead on the hot path.
_CRC_TABLE: "jax.Array | None" = None


def make_crc_table() -> jax.Array:
    """Standard reflected CRC-32 (poly 0xEDB88320) byte table as int32.

    Cached at module level: repeated callers share one device-resident copy.
    """
    global _CRC_TABLE
    if _CRC_TABLE is None:
        import numpy as np

        table = np.empty(256, dtype=np.uint32)
        for i in range(256):
            c = np.uint32(i)
            for _ in range(8):
                c = (c >> np.uint32(1)) ^ (np.uint32(0xEDB88320) * (c & np.uint32(1)))
            table[i] = c
        _CRC_TABLE = jnp.asarray(table.view(np.int32))
    return _CRC_TABLE


def _crc32_kernel(data_ref, table_ref, out_ref):
    """data: (SEG_ROWS, SEG_COLS, seg_len) int32 bytes; out: per-segment CRC."""
    seg_len = data_ref.shape[-1]
    table = table_ref[...]

    def step(i, crc):
        byte = data_ref[:, :, i]
        idx = (crc ^ byte) & 0xFF
        return jax.lax.shift_right_logical(crc, 8) ^ table[idx]

    init = jnp.full((SEG_ROWS, SEG_COLS), jnp.int32(-1))  # 0xFFFFFFFF
    crc = jax.lax.fori_loop(0, seg_len, step, init)
    out_ref[...] = ~crc  # final XOR with 0xFFFFFFFF


@functools.partial(jax.jit, static_argnames=("interpret",))
def crc32_segments(data: jax.Array, table: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Per-segment CRC32.

    data: (SEG_ROWS, SEG_COLS, seg_len) int32 byte values (zero-padded
          segments contribute CRC-of-zeros; the host combine accounts for
          true lengths).
    returns (SEG_ROWS, SEG_COLS) int32 CRCs.
    """
    return pl.pallas_call(
        _crc32_kernel,
        in_specs=[
            pl.BlockSpec(data.shape, lambda: (0, 0, 0)),
            pl.BlockSpec((256,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((SEG_ROWS, SEG_COLS), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((SEG_ROWS, SEG_COLS), jnp.int32),
        interpret=interpret,
    )(data, table)


def _crc32_batch_kernel(data_ref, table_ref, out_ref):
    """One grid step = one request's (SEG_ROWS, SEG_COLS, seg_len) lanes."""
    seg_len = data_ref.shape[-1]
    table = table_ref[...]

    def step(i, crc):
        byte = data_ref[0, :, :, i]
        idx = (crc ^ byte) & 0xFF
        return jax.lax.shift_right_logical(crc, 8) ^ table[idx]

    init = jnp.full((SEG_ROWS, SEG_COLS), jnp.int32(-1))
    out_ref[0] = ~jax.lax.fori_loop(0, seg_len, step, init)


@functools.partial(jax.jit, static_argnames=("interpret",))
def crc32_segments_batched(
    data: jax.Array, table: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """Per-segment CRC32 for a *batch* of byte streams in one dispatch.

    data: (batch, SEG_ROWS, SEG_COLS, seg_len) int32 byte values — each batch
          row holds one request's bytes laid out lane-major (zero-padded
          lanes/tails; the host combine honors true lengths per request).
    returns (batch, SEG_ROWS, SEG_COLS) int32 CRCs.

    The grid walks the batch dimension so the whole batch costs one kernel
    launch + one host↔device round trip instead of one per request — the
    batching win the engine exists for (CODAG's lesson applied to TPU lanes).
    """
    batch = data.shape[0]
    return pl.pallas_call(
        _crc32_batch_kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1,) + data.shape[1:], lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, SEG_ROWS, SEG_COLS), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, SEG_ROWS, SEG_COLS), jnp.int32),
        interpret=interpret,
    )(data, table)
