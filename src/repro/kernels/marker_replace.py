"""Pallas TPU kernel: stage-2 marker replacement (paper §2.2 step 3, Table 2).

Marker replacement is the data-parallel half of two-stage decompression:

    out[i] = sym[i]                      if sym[i] < 256   (resolved literal)
    out[i] = window[sym[i] - 256]        otherwise         (marker)

which collapses into a single gather through a 33 024-entry replacement
table (``[0..255] ++ window``). On TPU the table (132 KiB as int32) is
pinned whole in VMEM while symbol tiles stream HBM→VMEM; the gather runs on
the VPU at memory bandwidth — the TPU-native analogue of the paper's
L1-resident window on CPU.

Tiling: symbols are processed in (8, 1024) int32 tiles (8×128-lane VREG
granularity); the grid walks the flattened symbol stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import TABLE_SIZE

# One tile = SUBLANES x LANES*4 elements; int32 VREGs are (8, 128).
TILE_ROWS = 8
TILE_COLS = 1024
TILE = TILE_ROWS * TILE_COLS


def _marker_replace_kernel(syms_ref, table_ref, out_ref):
    """out = table[syms] — table resident in VMEM, symbols tiled."""
    syms = syms_ref[...]
    table = table_ref[...]
    out_ref[...] = table[syms]


@functools.partial(jax.jit, static_argnames=("interpret",))
def marker_replace_tiles(syms: jax.Array, table: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Gather-replace over tiled int32 symbols.

    syms:  (n_tiles, TILE_ROWS, TILE_COLS) int32 (padded, values < TABLE_SIZE)
    table: (TABLE_SIZE,) int32 replacement table
    returns same shape int32 with markers resolved to byte values.
    """
    n_tiles = syms.shape[0]
    return pl.pallas_call(
        _marker_replace_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_COLS), lambda i: (i, 0, 0)),
            pl.BlockSpec((TABLE_SIZE,), lambda i: (0,)),  # whole table in VMEM
        ],
        out_specs=pl.BlockSpec((1, TILE_ROWS, TILE_COLS), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(syms.shape, jnp.int32),
        interpret=interpret,
    )(syms, table)


def _marker_replace_multi_kernel(tids_ref, syms_ref, tables_ref, out_ref):
    """out = tables[tid][syms] — one table per tile, selected dynamically.

    The batched-engine variant: a dispatch carries tiles from many chunks
    (each chunk resolved against its own window), so the replacement table
    becomes a small VMEM-resident stack of tables plus a per-tile int32
    selector. The gather itself is unchanged; only the table load gains one
    dynamic index (a VMEM-local dynamic slice, free on the VPU).
    """
    tid = tids_ref[0]
    syms = syms_ref[...]
    table = tables_ref[tid, :]
    out_ref[...] = table[syms]


@functools.partial(jax.jit, static_argnames=("interpret",))
def marker_replace_tiles_multi(
    syms: jax.Array,
    tables: jax.Array,
    tile_tables: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Gather-replace over tiles drawn from many chunks/windows in one call.

    syms:        (n_tiles, TILE_ROWS, TILE_COLS) int32 (padded)
    tables:      (n_tables, TABLE_SIZE) int32 — one replacement table per
                 distinct window in the batch (all resident in VMEM: 132 KiB
                 each, so a 16-window batch is ~2 MiB, well inside v5e VMEM)
    tile_tables: (n_tiles,) int32 — table index for each tile
    returns syms-shaped int32 with markers resolved.

    On real TPU hardware the per-tile selector would ride scalar prefetch
    (``PrefetchScalarGridSpec``) so the index is known before the body runs;
    interpret mode (this container) takes it as a 1-element block.
    """
    n_tiles = syms.shape[0]
    n_tables = tables.shape[0]
    return pl.pallas_call(
        _marker_replace_multi_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, TILE_ROWS, TILE_COLS), lambda i: (i, 0, 0)),
            pl.BlockSpec((n_tables, TABLE_SIZE), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE_ROWS, TILE_COLS), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(syms.shape, jnp.int32),
        interpret=interpret,
    )(tile_tables, syms, tables)
