"""jit'd high-level wrappers around the Pallas kernels.

These are the entry points the rest of the system uses: they pad/reshape
host data into kernel tiling, dispatch (interpret=True on CPU — TPU v5e is
the compile target), and restore shapes/dtypes.

Constant tables are cached at module level: the CRC byte LUT (one device
transfer per process, via ``make_crc_table``'s own memo) and the
empty-window replacement table (the common case for the first chunk of a
stream). The jitted dispatch functions themselves are module-level
``jax.jit``s, so traces are shared per shape bucket across calls — per-call
work is reduced to padding + the dispatch itself. For cross-chunk batching
on the serving hot path, see ``kernels/engine.py``.
"""

from __future__ import annotations

import zlib as _zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.crc32 import combine_parts
from .crc32 import N_SEGMENTS, SEG_COLS, SEG_ROWS, crc32_segments, make_crc_table
from .marker_replace import TILE, TILE_COLS, TILE_ROWS, marker_replace_tiles
from .precode_check import BLOCK, HALO, precode_check_blocks
from .ref import make_replacement_table

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
#: interpret=True executes kernel bodies in Python on CPU — the validation
#: mode for this container; on real TPU hardware the same calls compile.
INTERPRET = not _ON_TPU

_EMPTY_WINDOW_TABLE: Optional[jax.Array] = None


def replacement_table_device(window: Optional[bytes]) -> jax.Array:
    """Device-resident int32 replacement table for ``window``.

    The empty-window table (every marker resolves to 0 — the first chunk of
    any stream) is a constant and cached; real windows are content-dependent
    and built per call.
    """
    global _EMPTY_WINDOW_TABLE
    if not window:
        if _EMPTY_WINDOW_TABLE is None:
            _EMPTY_WINDOW_TABLE = jnp.asarray(
                make_replacement_table(np.empty(0, np.uint8))
            )
        return _EMPTY_WINDOW_TABLE
    return jnp.asarray(make_replacement_table(np.frombuffer(window, np.uint8)))


# -- marker replacement -------------------------------------------------------

def marker_replace(symbols: np.ndarray, window: Optional[bytes]) -> np.ndarray:
    """Resolve a uint16 marker stream to bytes via the Pallas kernel."""
    n = symbols.shape[0]
    table = replacement_table_device(window)
    n_tiles = max(1, -(-n // TILE))
    padded = np.zeros(n_tiles * TILE, dtype=np.int32)
    padded[:n] = symbols.astype(np.int32)
    tiles = jnp.asarray(padded.reshape(n_tiles, TILE_ROWS, TILE_COLS))
    out = marker_replace_tiles(tiles, table, interpret=INTERPRET)
    return np.asarray(out).reshape(-1)[:n].astype(np.uint8)


# -- block-finder precheck ----------------------------------------------------

def precode_candidates(data: bytes, start_bit: int = 0, end_bit: Optional[int] = None) -> np.ndarray:
    """Bit offsets passing finder steps 1-4, computed on-device.

    Returns absolute candidate bit offsets; callers confirm with the strict
    host-side header parse (steps 5-7), exactly like the production finder.
    """
    total_bits = len(data) * 8
    if end_bit is None:
        end_bit = total_bits - HALO
    end_bit = min(end_bit, total_bits - HALO)
    if end_bit <= start_bit:
        return np.empty(0, dtype=np.int64)
    n = end_bit - start_bit

    first_byte = start_bit // 8
    need_bits = (start_bit - first_byte * 8) + n + HALO
    need_bytes = -(-need_bits // 8)
    raw = np.frombuffer(data, np.uint8, count=min(need_bytes, len(data) - first_byte), offset=first_byte)
    bits = np.unpackbits(raw, bitorder="little").astype(np.int32)
    rel = start_bit - first_byte * 8

    n_blocks = max(1, -(-n // BLOCK))
    padded = np.zeros((n_blocks + 1) * BLOCK, dtype=np.int32)
    usable = min(bits.shape[0] - rel, padded.shape[0])
    padded[:usable] = bits[rel : rel + usable]
    blocks = jnp.asarray(padded.reshape(n_blocks + 1, BLOCK))
    mask = np.asarray(precode_check_blocks(blocks, interpret=INTERPRET)).reshape(-1)[:n]
    return np.nonzero(mask)[0].astype(np.int64) + start_bit


# -- crc32 --------------------------------------------------------------------

def crc32_parallel(data: bytes) -> int:
    """CRC32 of ``data`` via N_SEGMENTS parallel lanes + GF(2) combine."""
    n = len(data)
    if n == 0:
        return 0
    seg_len = max(1, -(-n // N_SEGMENTS))
    padded = np.zeros(N_SEGMENTS * seg_len, dtype=np.uint8)
    padded[:n] = np.frombuffer(data, np.uint8)
    tiles = jnp.asarray(
        padded.reshape(SEG_ROWS, SEG_COLS, seg_len).astype(np.int32)
    )
    crcs = np.asarray(crc32_segments(tiles, make_crc_table(), interpret=INTERPRET)).astype(np.uint32)
    # Combine per-segment CRCs; the tail segment may be short — zero padding
    # inside a segment changes its CRC, so true lengths are honored by
    # recomputing the last (partial) segment's CRC on the host.
    parts = []
    flat = crcs.reshape(-1)
    full_segments = n // seg_len
    for s in range(full_segments):
        parts.append((int(flat[s]), seg_len))
    rem = n - full_segments * seg_len
    if rem:
        tail = data[full_segments * seg_len :]
        parts.append((_zlib.crc32(tail) & 0xFFFFFFFF, rem))
    return combine_parts(parts)
