"""DeviceDecodeEngine — batched stage-2 dispatch on the serving hot path.

The paper's two-stage scheme (§2.2) leaves stage 2 — marker resolution and
CRC32 — embarrassingly data-parallel, which is exactly what an accelerator
rewards *if* it is fed full batches. The per-chunk wrappers in ``ops.py``
pay one host↔device round trip, one table upload, and one dispatch per
chunk; CODAG and Sitaridi et al. (PAPERS.md) both show that decompression
on wide-SIMD hardware lives or dies on amortizing exactly those costs.

This engine is the process-wide aggregation point: every reader/tenant
submits marker-resolution and CRC requests here; a single dispatcher thread
packs them into fixed-size tile batches, dispatches the batched Pallas
kernels (``marker_replace_tiles_multi`` / ``crc32_segments_batched``) once
per batch, and scatters results back to per-request futures.

Layout and policy:

  * **Tile packing** — symbol streams are padded into (8, 1024) int32 tiles
    (``marker_replace.TILE``); a batch is a stack of tiles from many chunks
    plus a per-tile ``int32`` table selector. Distinct windows dedupe into a
    small VMEM-resident stack of replacement tables (132 KiB each, capped at
    ``max_tables`` per dispatch).
  * **Shape bucketing** — tile counts and table counts round up to powers of
    two (capped at ``max_batch_tiles``), so the jitted dispatches compile a
    bounded set of shapes once and are reused forever (cached compiled
    kernels). The CRC path buckets ``seg_len`` the same way.
  * **Double-buffered staging** — two host staging buffers per bucket shape
    alternate between dispatches, and result readback of batch N overlaps
    the launch of batch N+1 (one dispatch in flight), so host packing and
    device compute pipeline instead of serializing.
  * **Crossover routing** — small or singleton requests take the existing
    CPU path inline (``core.markers`` / ``zlib.crc32``) and are counted as
    ``fallbacks``: interactive p99 never pays the batching latency tax. The
    threshold is derived from the committed ``BENCH_kernels.json`` batched
    dispatch sweep (see ``derive_crossover``); on hosts where the device
    never wins (e.g. interpret mode on CPU) the derived crossover is None
    and *everything* falls back — the engine stays on the hot path only for
    accounting, costing one branch per request.
  * **Degradation** — when jax is unavailable the engine constructs fine,
    reports ``available=False``, and routes every request to the CPU path.

Bit-identity: the device path computes the same gather/CRC as the host path
(int32 tables hold byte values; CRCs are exact), so results are
bit-identical regardless of routing — verified by the parity suite in
``tests/test_device_engine.py`` and the reader round-trip tests.
"""

from __future__ import annotations

import json
import os
import re
import threading
import time
import zlib as _zlib
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.crc32 import combine_parts
from ..core.markers import replace_markers as _cpu_replace_markers
from ..obs import trace as _obs_trace

try:  # pragma: no cover - exercised via available=False paths in tests
    import jax.numpy as jnp

    from .crc32 import N_SEGMENTS, crc32_segments_batched, make_crc_table
    from .marker_replace import (
        TABLE_SIZE,
        TILE,
        TILE_COLS,
        TILE_ROWS,
        marker_replace_tiles_multi,
    )
    from .ops import INTERPRET
    from .ref import make_replacement_table

    _HAVE_JAX = True
except Exception:  # noqa: BLE001 - any import failure means "no device"
    _HAVE_JAX = False
    INTERPRET = True
    TILE, TILE_ROWS, TILE_COLS, N_SEGMENTS = 8192, 8, 1024, 1024

_TILE_BYTES = TILE  # one symbol resolves to one output byte


class EngineClosedError(RuntimeError):
    """Raised on futures queued (or submits attempted) after shutdown."""


def _pow2_at_least(n: int, cap: Optional[int] = None) -> int:
    p = 1
    while p < n:
        p *= 2
    return min(p, cap) if cap is not None else p


_MBPS_RE = re.compile(r"([0-9]+(?:\.[0-9]+)?)MB/s")


def derive_crossover(rows: Sequence[Dict[str, Any]]) -> Dict[str, Optional[int]]:
    """Roofline-style CPU/device crossover from ``BENCH_kernels.json`` rows.

    Model: CPU resolves a request of ``n`` bytes in ``n / cpu_bw`` seconds;
    the device costs a fixed per-dispatch overhead plus ``n / dev_bw``. The
    crossover is where the lines meet::

        n* = overhead / (1/cpu_bw - 1/dev_bw)      (only if dev_bw > cpu_bw)

    Inputs are the sweep rows ``bench_kernels`` persists:
      * ``kernel_engine_cpu_replace``  — CPU gather bandwidth (MB/s derived)
      * ``kernel_engine_batched_b16``  — batched device bandwidth (MB/s)
      * ``kernel_engine_batched_b1``   — single-tile dispatch latency (us),
        whose fixed part estimates the per-dispatch overhead.

    Returns ``{"replace": bytes_or_None, "crc": bytes_or_None}`` — None
    means the device never wins at any size on this artifact (the honest
    answer for interpret mode on a CPU-only host) and all requests of that
    kind should take the CPU path.
    """
    by_name = {r.get("name"): r for r in rows or ()}

    def _mbps(name: str) -> Optional[float]:
        row = by_name.get(name)
        if not row:
            return None
        m = _MBPS_RE.search(str(row.get("derived", "")))
        return float(m.group(1)) * 1e6 if m else None

    def _us(name: str) -> Optional[float]:
        row = by_name.get(name)
        return float(row["value_us"]) if row and "value_us" in row else None

    def _one(cpu_name: str, dev_name: str, b1_name: str) -> Optional[int]:
        cpu_bw, dev_bw, b1 = _mbps(cpu_name), _mbps(dev_name), _us(b1_name)
        if not cpu_bw or not dev_bw or b1 is None or dev_bw <= cpu_bw:
            return None
        overhead_s = max(0.0, b1 * 1e-6 - _TILE_BYTES / dev_bw)
        if overhead_s == 0.0:
            return _TILE_BYTES
        return int(overhead_s / (1.0 / cpu_bw - 1.0 / dev_bw))

    return {
        "replace": _one(
            "kernel_engine_cpu_replace",
            "kernel_engine_batched_b16",
            "kernel_engine_batched_b1",
        ),
        "crc": _one(
            "kernel_engine_cpu_crc",
            "kernel_engine_crc_batched_b8",
            "kernel_engine_crc_batched_b1",
        ),
    }


def load_crossover(root: Optional[str] = None) -> Dict[str, Optional[int]]:
    """``derive_crossover`` over the committed ``BENCH_kernels.json``.

    Missing or malformed artifacts degrade to all-None (CPU path) — an
    installed package without the repo checkout must still construct.
    """
    if root is None:
        root = os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        )
    path = os.path.join(root, "BENCH_kernels.json")
    try:
        with open(path) as f:
            payload = json.load(f)
        return derive_crossover(payload.get("results", []))
    except (OSError, ValueError):
        return {"replace": None, "crc": None}


class _Request:
    __slots__ = ("kind", "symbols", "window", "data", "tiles", "nbytes", "future")

    def __init__(self, kind: str, *, symbols=None, window=None, data=None):
        self.kind = kind
        self.symbols = symbols
        self.window = window
        self.data = data
        if kind == "replace":
            self.nbytes = int(symbols.shape[0])
            self.tiles = max(1, -(-self.nbytes // TILE))
        else:
            self.nbytes = len(data)
            self.tiles = 0
        self.future: Future = Future()


class DeviceDecodeEngine:
    """Process-wide batched dispatcher for stage-2 device work.

    One engine per process (the service layer owns it like ``CachePool`` /
    ``FairExecutor``); every entry point is thread-safe. The duck-typed
    resolver surface consumed by ``core.codec`` / ``core.chunk_fetcher``:

      * ``replace_markers(symbols, window) -> np.uint8 ndarray`` (blocking)
      * ``crc32(data) -> int`` (blocking)
      * ``submit_replace`` / ``submit_crc`` -> Future (async variants)
      * ``stats() -> dict`` / ``shutdown()``
    """

    def __init__(
        self,
        *,
        max_batch_tiles: int = 32,
        max_tables: int = 8,
        max_batch_crc_bytes: int = 4 << 20,
        max_crc_requests: int = 16,
        max_delay_s: float = 0.002,
        crossover: Union[str, None, Dict[str, Optional[int]]] = "auto",
        force_device: bool = False,
        interpret: Optional[bool] = None,
        artifact_root: Optional[str] = None,
    ):
        self.max_batch_tiles = max(1, max_batch_tiles)
        self.max_tables = _pow2_at_least(max(1, max_tables))
        self.max_batch_crc_bytes = max(1 << 10, max_batch_crc_bytes)
        self.max_crc_requests = max(1, max_crc_requests)
        self.max_delay_s = max(0.0, max_delay_s)
        self.force_device = force_device
        self.interpret = INTERPRET if interpret is None else interpret
        self.available = _HAVE_JAX
        if crossover == "auto":
            self.crossover = load_crossover(artifact_root)
        elif crossover is None:
            self.crossover = {"replace": None, "crc": None}
        else:
            self.crossover = {
                "replace": crossover.get("replace"),
                "crc": crossover.get("crc"),
            }

        self._cond = threading.Condition()
        self._rq: Deque[_Request] = deque()
        self._cq: Deque[_Request] = deque()
        self._closed = False
        # Replacement tables are pure functions of the window; serving reads
        # hit the same windows repeatedly (re-reads, overlapping ranges), so
        # an LRU of built tables (132 KiB each) turns the per-dispatch table
        # cost into a cache probe. Worker-thread only — no lock needed.
        self._table_cache: "OrderedDict[bytes, np.ndarray]" = OrderedDict()
        self._table_cache_cap = 32
        # Device-side cache of padded, uploaded table *stacks* keyed by the
        # dispatch's window set — a repeat batch skips assembly + transfer.
        self._stack_cache: "OrderedDict[Tuple, Any]" = OrderedDict()
        self._stack_cache_cap = 8
        # Double-buffered host staging: two numpy buffers per bucket shape,
        # alternating between consecutive dispatches so packing batch N+1
        # never scribbles over memory the in-flight transfer of batch N may
        # still be reading (pinned-buffer discipline on real hardware).
        self._staging: Dict[Tuple, List[np.ndarray]] = {}
        self._staging_phase = 0

        # Counters (mutated under self._cond).
        self._requests = {"replace": 0, "crc": 0}
        self._fallbacks = {"replace": 0, "crc": 0}
        self._batches = 0
        self._dispatches = 0
        self._batched_requests = 0
        self._tiles_dispatched = 0
        self._tiles_padded = 0
        self._crc_bytes = 0
        self._max_queue_depth = 0
        self._errors = 0

        self._worker: Optional[threading.Thread] = None
        if self.available:
            self._worker = threading.Thread(
                target=self._worker_loop, name="device-decode-engine", daemon=True
            )
            self._worker.start()

    # ------------------------------------------------------------------
    # routing policy
    # ------------------------------------------------------------------

    def _route_device(self, kind: str, nbytes: int) -> bool:
        if not self.available or self._closed:
            return False
        if self.force_device:
            return True
        threshold = self.crossover.get(kind)
        return threshold is not None and nbytes >= threshold

    def _count(self, counter: Dict[str, int], kind: str) -> None:
        with self._cond:
            counter[kind] += 1

    # ------------------------------------------------------------------
    # submission API
    # ------------------------------------------------------------------

    def submit_replace(self, symbols: np.ndarray, window: Optional[bytes]) -> Future:
        """Queue a marker-resolution request; resolves to a uint8 array.

        Tiny/degenerate requests resolve immediately without touching the
        queue; when the device is unavailable the work happens inline on the
        caller's thread (counted as a fallback) so the future contract holds
        everywhere.
        """
        self._count(self._requests, "replace")
        fut: Future = Future()
        if symbols.dtype == np.uint8 or symbols.shape[0] == 0:
            fut.set_result(np.asarray(symbols, dtype=np.uint8))
            return fut
        if not self.available:
            self._count(self._fallbacks, "replace")
            fut.set_result(_cpu_replace_markers(symbols, window))
            return fut
        req = _Request("replace", symbols=symbols, window=window)
        self._enqueue(self._rq, req)
        return req.future

    def submit_crc(self, data) -> Future:
        """Queue a CRC32 request; resolves to the int checksum."""
        self._count(self._requests, "crc")
        data = _as_bytes(data)
        fut: Future = Future()
        if len(data) == 0:
            fut.set_result(0)
            return fut
        if not self.available:
            self._count(self._fallbacks, "crc")
            fut.set_result(_zlib.crc32(data) & 0xFFFFFFFF)
            return fut
        req = _Request("crc", data=data)
        self._enqueue(self._cq, req)
        return req.future

    def _enqueue(self, queue: Deque[_Request], req: _Request) -> None:
        with self._cond:
            if self._closed:
                raise EngineClosedError("DeviceDecodeEngine is shut down")
            queue.append(req)
            depth = len(self._rq) + len(self._cq)
            if depth > self._max_queue_depth:
                self._max_queue_depth = depth
            self._cond.notify_all()

    # ------------------------------------------------------------------
    # blocking resolver surface (what codec/fetcher call)
    # ------------------------------------------------------------------

    def replace_markers(self, symbols: np.ndarray, window: Optional[bytes]) -> np.ndarray:
        """Resolve a marker stream — batched on-device above the crossover,
        inline on the CPU below it (or whenever the device cannot win)."""
        if symbols.dtype == np.uint8:
            return symbols
        if self._route_device("replace", symbols.shape[0]):
            try:
                fut = self.submit_replace(symbols, window)
                with _obs_trace.timed(
                    "engine.batch_wait", {"kind": "replace", "symbols": int(symbols.shape[0])}
                ):
                    return fut.result()
            except EngineClosedError:
                pass  # raced shutdown: serve on the CPU like any fallback
        else:
            self._count(self._requests, "replace")
        self._count(self._fallbacks, "replace")
        return _cpu_replace_markers(symbols, window)

    def crc32(self, data) -> int:
        """CRC32 — batched on-device above the crossover, zlib below it."""
        data = _as_bytes(data)
        if self._route_device("crc", len(data)):
            try:
                fut = self.submit_crc(data)
                with _obs_trace.timed("engine.batch_wait", {"kind": "crc", "nbytes": len(data)}):
                    return fut.result()
            except EngineClosedError:
                pass
        else:
            self._count(self._requests, "crc")
        self._count(self._fallbacks, "crc")
        return _zlib.crc32(data) & 0xFFFFFFFF

    # ------------------------------------------------------------------
    # dispatcher thread
    # ------------------------------------------------------------------

    def _collect_batch(self) -> Optional[Tuple[List[_Request], List[_Request]]]:
        """Block until work (or shutdown); return one coalesced batch.

        After the first request arrives, waits up to ``max_delay_s`` for the
        batch to fill — the window in which concurrent readers' stage-2 work
        coalesces into one dispatch. Returns None at shutdown.
        """
        with self._cond:
            while not self._closed and not self._rq and not self._cq:
                self._cond.wait()
            if self._closed:
                return None
            if self.max_delay_s > 0.0:
                deadline = time.monotonic() + self.max_delay_s
                while not self._closed:
                    tiles = sum(r.tiles for r in self._rq)
                    crc_bytes = sum(r.nbytes for r in self._cq)
                    if (
                        tiles >= self.max_batch_tiles
                        or len(self._cq) >= self.max_crc_requests
                        or crc_bytes >= self.max_batch_crc_bytes
                    ):
                        break
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(remaining)
                if self._closed:
                    return None

            rep: List[_Request] = []
            tiles = 0
            tables: set = set()
            while self._rq:
                req = self._rq[0]
                key = bytes(req.window or b"")
                new_table = key not in tables
                if rep and (
                    tiles + req.tiles > self.max_batch_tiles
                    or (new_table and len(tables) >= self.max_tables)
                ):
                    break
                self._rq.popleft()
                rep.append(req)
                tiles += req.tiles
                tables.add(key)
            crc: List[_Request] = []
            crc_bytes = 0
            while self._cq and len(crc) < self.max_crc_requests:
                req = self._cq[0]
                if crc and crc_bytes + req.nbytes > self.max_batch_crc_bytes:
                    break
                self._cq.popleft()
                crc.append(req)
                crc_bytes += req.nbytes
            return rep, crc

    def _worker_loop(self) -> None:
        pending = None  # resolve-callback of the previous (in-flight) batch
        while True:
            batch = self._collect_batch()
            if batch is None:
                break
            rep, crc = batch
            launched = []
            try:
                if rep:
                    launched.append(self._dispatch_replace(rep))
                if crc:
                    launched.append(self._dispatch_crc(crc))
            except BaseException as exc:  # noqa: BLE001 - fail the batch, keep serving
                with self._cond:
                    self._errors += 1
                for req in rep + crc:
                    if not req.future.done():
                        req.future.set_exception(exc)
                continue
            # Pipeline: resolve the *previous* dispatch only after launching
            # this one — readback of batch N overlaps device work of N+1.
            if pending is not None:
                self._resolve_safely(pending)
            if launched:
                with self._cond:
                    self._batches += 1
                    self._batched_requests += len(rep) + len(crc)
            pending = launched or None
            with self._cond:
                idle = not self._rq and not self._cq
            if idle and pending is not None:
                self._resolve_safely(pending)
                pending = None
        if pending is not None:
            self._resolve_safely(pending)

    def _resolve_safely(self, launched) -> None:
        for resolve in launched:
            try:
                resolve()
            except BaseException:  # noqa: BLE001 - resolve() fails its own futures
                with self._cond:
                    self._errors += 1

    # -- marker replacement dispatch ------------------------------------

    def _replacement_table(self, window: bytes) -> np.ndarray:
        table = self._table_cache.get(window)
        if table is not None:
            self._table_cache.move_to_end(window)
            return table
        table = make_replacement_table(np.frombuffer(window, np.uint8))
        self._table_cache[window] = table
        if len(self._table_cache) > self._table_cache_cap:
            self._table_cache.popitem(last=False)
        return table

    def _staging_buffer(self, key: Tuple, shape: Tuple[int, ...]) -> np.ndarray:
        bufs = self._staging.get(key)
        if bufs is None:
            bufs = [np.zeros(shape, np.int32), np.zeros(shape, np.int32)]
            self._staging[key] = bufs
        return bufs[self._staging_phase & 1]

    def _table_stack(self, keys: Tuple[bytes, ...]) -> Any:
        """Device-resident (n_tables, TABLE_SIZE) stack for a window set.

        Window sets recur across dispatches (the same few chunks' windows
        serve a burst of reads), so the padded, uploaded stack is cached
        whole — a hit skips both the host assembly and the transfer.
        """
        n_tables = _pow2_at_least(len(keys), self.max_tables)
        cache_key = (n_tables,) + keys
        stack = self._stack_cache.get(cache_key)
        if stack is not None:
            self._stack_cache.move_to_end(cache_key)
            return stack
        tab_stack = np.zeros((n_tables, TABLE_SIZE), np.int32)
        for i in range(n_tables):
            tab_stack[i] = self._replacement_table(keys[min(i, len(keys) - 1)])
        stack = jnp.asarray(tab_stack)
        self._stack_cache[cache_key] = stack
        if len(self._stack_cache) > self._stack_cache_cap:
            self._stack_cache.popitem(last=False)
        return stack

    def _dispatch_replace(self, reqs: List[_Request]):
        """Pack, upload, and launch one marker batch; returns resolve()."""
        self._staging_phase += 1
        # Dedupe windows into a table stack; selector per tile.
        table_ids: Dict[bytes, int] = {}
        total_tiles = sum(r.tiles for r in reqs)
        tid_flat = np.zeros(total_tiles, np.int32)
        spans: List[Tuple[_Request, int, int]] = []
        single = total_tiles <= self.max_batch_tiles
        if single:
            # Common case: the whole batch is one slab — pack symbols
            # straight into the staging buffer, no intermediate copy. Pad
            # gaps keep whatever the buffer last held: stale values were
            # themselves valid symbols (< TABLE_SIZE), so the gather stays
            # in range and the padded outputs are simply never read.
            bucket = _pow2_at_least(total_tiles, self.max_batch_tiles)
            stage = self._staging_buffer(
                ("rep", bucket), (bucket, TILE_ROWS, TILE_COLS)
            )
            sym_flat = stage.reshape(-1)
        else:
            sym_flat = np.zeros(total_tiles * TILE, np.int32)
        pos = 0
        for req in reqs:
            key = bytes(req.window or b"")
            tid = table_ids.get(key)
            if tid is None:
                tid = len(table_ids)
                table_ids[key] = tid
            n = req.nbytes
            sym_flat[pos * TILE : pos * TILE + n] = req.symbols
            tid_flat[pos : pos + req.tiles] = tid
            spans.append((req, pos * TILE, n))
            pos += req.tiles

        tab_dev = self._table_stack(tuple(table_ids))

        # Slab the packed tiles: oversized single requests span multiple
        # kernel launches, everything else fits one. Bucketed shapes keep
        # the set of compiled kernels small and cached.
        outs: List[Tuple[Any, int]] = []
        slabs = 0
        for s0 in range(0, total_tiles, self.max_batch_tiles):
            n = min(self.max_batch_tiles, total_tiles - s0)
            bucket = _pow2_at_least(n, self.max_batch_tiles)
            if single:
                stage_slab = stage
            else:
                stage_slab = self._staging_buffer(
                    ("rep", bucket), (bucket, TILE_ROWS, TILE_COLS)
                )
                stage_slab.reshape(-1)[: n * TILE] = (
                    sym_flat[s0 * TILE : (s0 + n) * TILE]
                )
            tids = np.zeros(bucket, np.int32)
            tids[:n] = tid_flat[s0 : s0 + n]
            out = marker_replace_tiles_multi(
                jnp.asarray(stage_slab), tab_dev, jnp.asarray(tids),
                interpret=self.interpret,
            )
            outs.append((out, n))
            slabs += 1
            with self._cond:
                self._tiles_dispatched += n
                self._tiles_padded += bucket - n
        with self._cond:
            self._dispatches += slabs

        def resolve() -> None:
            flat_out = np.concatenate(
                [np.asarray(out).reshape(-1)[: n * TILE] for out, n in outs]
            )
            for req, off, n in spans:
                if not req.future.done():
                    req.future.set_result(
                        flat_out[off : off + n].astype(np.uint8)
                    )

        return resolve

    # -- CRC dispatch ----------------------------------------------------

    def _dispatch_crc(self, reqs: List[_Request]):
        """Pack many byte streams into one (B, 8, 128, seg_len) dispatch."""
        self._staging_phase += 1
        seg_len = _pow2_at_least(
            max(1, max(-(-r.nbytes // N_SEGMENTS) for r in reqs))
        )
        batch = _pow2_at_least(len(reqs))
        from .crc32 import SEG_COLS, SEG_ROWS  # local: shapes only

        stage = self._staging_buffer(
            ("crc", batch, seg_len), (batch, SEG_ROWS, SEG_COLS, seg_len)
        )
        stage.fill(0)
        fulls: List[int] = []
        for bi, req in enumerate(reqs):
            full = req.nbytes // seg_len
            fulls.append(full)
            if full:
                lanes = stage[bi].reshape(N_SEGMENTS, seg_len)
                lanes[:full] = np.frombuffer(
                    req.data, np.uint8, count=full * seg_len
                ).reshape(full, seg_len)
        out = crc32_segments_batched(
            jnp.asarray(stage), make_crc_table(), interpret=self.interpret
        )
        with self._cond:
            self._dispatches += 1
            self._crc_bytes += sum(r.nbytes for r in reqs)

        def resolve() -> None:
            crcs = np.asarray(out).astype(np.uint32)
            for bi, req in enumerate(reqs):
                lanes = crcs[bi].reshape(-1)
                full = fulls[bi]
                parts = [(int(lanes[s]), seg_len) for s in range(full)]
                rem = req.nbytes - full * seg_len
                if rem:
                    parts.append(
                        (_zlib.crc32(req.data[full * seg_len :]) & 0xFFFFFFFF, rem)
                    )
                if not req.future.done():
                    req.future.set_result(combine_parts(parts))

        return resolve

    # ------------------------------------------------------------------
    # lifecycle & telemetry
    # ------------------------------------------------------------------

    def shutdown(self) -> None:
        """Stop the dispatcher and fail queued requests loudly.

        Requests already collected into an in-flight batch complete; anything
        still queued gets ``EngineClosedError`` — callers must never hang on
        a future the worker will no longer serve.
        """
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=30)
        with self._cond:
            leftovers = list(self._rq) + list(self._cq)
            self._rq.clear()
            self._cq.clear()
        for req in leftovers:
            if not req.future.done():
                req.future.set_exception(
                    EngineClosedError("DeviceDecodeEngine shut down with requests queued")
                )

    def __enter__(self) -> "DeviceDecodeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def stats(self) -> Dict[str, Any]:
        """Snapshot for ``/v1/metrics`` (server threads it through)."""
        with self._cond:
            tiles_total = self._tiles_dispatched + self._tiles_padded
            return {
                "available": self.available,
                "interpret": self.interpret,
                "force_device": self.force_device,
                "crossover_bytes": dict(self.crossover),
                "requests": dict(self._requests),
                "fallbacks": dict(self._fallbacks),
                "batches": self._batches,
                "dispatches": self._dispatches,
                "batched_requests": self._batched_requests,
                "tiles_dispatched": self._tiles_dispatched,
                "tiles_padded": self._tiles_padded,
                "occupancy": (
                    self._tiles_dispatched / tiles_total if tiles_total else 0.0
                ),
                "crc_bytes": self._crc_bytes,
                "queue_depth": len(self._rq) + len(self._cq),
                "max_queue_depth": self._max_queue_depth,
                "errors": self._errors,
                "closed": self._closed,
            }


def _as_bytes(data) -> bytes:
    """Normalize ndarray/memoryview/bytes input to bytes for zlib/packing."""
    if isinstance(data, bytes):
        return data
    if isinstance(data, np.ndarray):
        return np.ascontiguousarray(data).tobytes()
    return bytes(data)
