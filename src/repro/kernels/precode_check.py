"""Pallas TPU kernel: vectorized Dynamic-Block finder precheck (paper §3.4.2).

The paper walks bit offsets sequentially with a skip-LUT; the TPU-native
reformulation evaluates the check cascade for *every bit offset in a tile
simultaneously* on the VPU:

  (1) final-block bit == 0
  (2) block type == 0b01 (stream order 0,1)
  (3) HLIT not in {30, 31}
  (4) precode histogram is a valid, complete Huffman code (Kraft sum == 128)

Step (4) re-expresses the paper's bit-level-parallel packed histogram across
vector lanes: the 19 precode code lengths are gathered with strided bit
reads and the Kraft term ``128 >> cl`` accumulated per offset. Offsets that
survive (≈0.05 % on random data, Table 1) are confirmed on the host with the
full strict header parse (steps 5–7) — the same split as the production
finder in ``core/block_finder.py``.

Input is the LSB-first bit plane as int32 0/1. Each tile needs a 74-bit
halo, provided by passing the *neighbor block* as a second view of the same
operand (standard Pallas halo pattern: two in_specs over one array with
shifted index maps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: bits of header probed beyond an offset: 17 header bits + 19*3 precode bits
HALO = 74

BLOCK = 2048  # offsets checked per grid step (>= HALO so one neighbor suffices)


def _field(bits, at: int, width: int, n: int):
    """value[i] = LSB-first ``width``-bit field at offset i+at (vectorized)."""
    out = jax.lax.dynamic_slice_in_dim(bits, at, n)
    for j in range(1, width):
        out = out | (jax.lax.dynamic_slice_in_dim(bits, at + j, n) << j)
    return out


def _precode_check_kernel(bits_ref, halo_ref, out_ref):
    n = out_ref.shape[-1]
    bits = jnp.concatenate([bits_ref[0], halo_ref[0][:HALO]], axis=-1)

    b0 = jax.lax.dynamic_slice_in_dim(bits, 0, n)
    b1 = jax.lax.dynamic_slice_in_dim(bits, 1, n)
    b2 = jax.lax.dynamic_slice_in_dim(bits, 2, n)
    ok = (b0 == 0) & (b1 == 0) & (b2 == 1)  # (1) + (2)

    hlit = _field(bits, 3, 5, n)
    ok &= hlit < 30  # (3)

    hclen = _field(bits, 13, 4, n)
    n_codes = hclen + 4

    # (4) Kraft completeness over the (up to 19) 3-bit precode code lengths.
    kraft = jnp.zeros((n,), jnp.int32)
    for k in range(19):
        cl = _field(bits, 17 + 3 * k, 3, n)
        active = (k < n_codes) & (cl > 0)
        term = jax.lax.shift_right_logical(jnp.int32(128), cl)
        kraft = kraft + jnp.where(active, term, 0)
    ok &= kraft == 128

    out_ref[0] = ok.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def precode_check_blocks(bits: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Candidate mask for every bit offset.

    bits: (n_blocks + 1, BLOCK) int32 0/1 bit plane — the final block is a
          zero-padded sentinel so the last real block has a halo neighbor.
    returns (n_blocks, BLOCK) int32 mask (1 = candidate for steps 5-7).
    """
    n_blocks = bits.shape[0] - 1
    return pl.pallas_call(
        _precode_check_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i + 1, 0)),  # halo neighbor
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.int32),
        interpret=interpret,
    )(bits, bits)
