"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships three layers (repo convention):
  * ``<name>.py`` — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling.
  * ``ops.py``    — jit'd wrappers (padding/reshape/dtype glue).
  * ``ref.py``    — pure-jnp oracle for allclose validation.

This container is CPU-only: kernels validate with ``interpret=True`` (kernel
bodies execute in Python); TPU v5e is the compile target.
"""

from .engine import DeviceDecodeEngine, EngineClosedError
from .ops import crc32_parallel, marker_replace, precode_candidates

__all__ = [
    "DeviceDecodeEngine",
    "EngineClosedError",
    "crc32_parallel",
    "marker_replace",
    "precode_candidates",
]
