from .serve_step import cache_shardings, make_serve_steps, prefill_to_decode_caches

__all__ = ["cache_shardings", "make_serve_steps", "prefill_to_decode_caches"]
