"""Serving steps: prefill + single-token decode with sharded KV caches.

``decode_32k`` / ``long_500k`` cells lower ``serve_step`` (one new token
against a seq_len cache) — never ``train_step``. Cache shardings follow
kv-head TP when the head count divides the model axis, otherwise the heads
stay replicated (gemma MQA) — the seq-sharded flash-decode alternative is
a §Perf hillclimb (distributed/flash_decode.py).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..distributed.sharding import ShardingRules
from ..models.model import Model
from ..models.transformer import ModelContext


def _dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def cache_shardings(cfg: ModelConfig, mesh, caches_abstract) -> Any:
    """PartitionSpecs for decode caches, keyed by leaf path names."""
    dp = _dp_axes(mesh)
    dp_part = dp if len(dp) > 1 else (dp[0] if dp else None)
    model_size = mesh.shape.get("model", 1)

    def spec_for(path, leaf) -> NamedSharding:
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1] if names else ""
        rank = len(leaf.shape)
        # Stacked caches have 1-2 leading layer dims; batch dim follows.
        parts = [None] * rank
        # find the batch dim: first dim equal to... we mark by position:
        # [L(, L2), B, ...] for attn/ssm/xlstm states.
        lead = 1 if rank >= 1 else 0
        if rank >= 2 and name in ("c", "n", "m") and leaf.shape[0] != leaf.shape[1]:
            lead = 1
        # heuristically: leading layer dims were added by stacking; batch is
        # the first non-layer dim. We rely on known layouts:
        if name in ("k", "v"):  # [L, B, S, K, Dh]
            parts = [None, dp_part, None, None, None]
            if leaf.shape[3] % model_size == 0:
                parts[3] = "model"
            elif leaf.shape[2] % model_size == 0:
                # kv heads can't shard (MQA/GQA<tp): shard the *sequence* dim
                # over the otherwise-idle model axis — GSPMD lowers the
                # attention as partial softmax + psum (flash-decode) and the
                # cache never moves (§Perf iteration 4).
                parts[2] = "model"
        elif name in ("c_kv", "k_rope"):  # [L, B, S, R] — MLA compressed cache
            parts = [None, dp_part, None, None]
            if leaf.shape[2] % model_size == 0:
                parts[2] = "model"
        elif name == "pos":  # [L, W]
            parts = [None, None]
        elif name == "h":  # ssm [L, B, D_in, N]
            parts = [None, dp_part, "model" if leaf.shape[2] % model_size == 0 else None, None]
        elif name == "conv":  # [L, B, K-1, D_in]
            parts = [None, dp_part, None, None]
        elif name in ("cross_k", "cross_v"):  # [L, B, T, K, Dh]
            parts = [None, dp_part, None, None, None]
        elif name == "c":  # xlstm matrix memory [G(, n_m), B, H, Dk, Dv]
            parts = [None] * (rank - 4) + [dp_part, None, "model" if leaf.shape[-2] % model_size == 0 else None, None]
        elif name == "n":
            parts = [None] * (rank - 3) + [dp_part, None, "model" if leaf.shape[-1] % model_size == 0 else None]
        elif name == "m":
            parts = [None] * (rank - 2) + [dp_part, None]
        else:  # xlstm slstm tuple leaves etc: [G, B, H, Dh]
            if rank >= 2:
                parts = [None] * rank
                parts[1] = dp_part
        from ..distributed.sharding import fit_spec

        return NamedSharding(mesh, fit_spec(P(*parts), leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec_for, caches_abstract)


def make_serve_steps(
    model: Model,
    mesh,
    rules: ShardingRules,
    *,
    batch: int,
    max_len: int,
):
    """Returns (prefill_fn, decode_fn, caches_abstract, shardings)."""
    ctx = ModelContext(mesh, rules)
    cfg = model.cfg

    from ..train.train_step import param_shardings
    from ..distributed.sharding import batch_partition

    caches_abstract = jax.eval_shape(lambda: model.init_decode_caches(batch, max_len))
    c_shard = cache_shardings(cfg, mesh, caches_abstract)
    p_shard = param_shardings(model, mesh, rules)
    tok_spec = batch_partition(mesh, batch)
    tok_shard = NamedSharding(mesh, P(*(list(tok_spec) + [None])))

    def prefill_fn(params, batch_inputs):
        return model.prefill(params, batch_inputs, ctx)

    def decode_fn(params, tokens, caches, cache_pos):
        logits, new_caches = model.decode_step(params, tokens, caches, cache_pos, ctx)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token[:, None], logits, new_caches

    jit_prefill = jax.jit(prefill_fn, in_shardings=(p_shard, None))
    jit_decode = jax.jit(
        decode_fn,
        in_shardings=(p_shard, tok_shard, c_shard, None),
        out_shardings=(tok_shard, None, c_shard),
        donate_argnums=(2,),
    )
    return jit_prefill, jit_decode, caches_abstract, {
        "params": p_shard,
        "caches": c_shard,
        "tokens": tok_shard,
    }


def prefill_to_decode_caches(
    cfg: ModelConfig, model: Model, prefill_caches: Any, batch: int, max_len: int, prefill_len: int
) -> Any:
    """Lay prefill cache tensors ([L,B,S,...]) into decode cache buffers."""
    decode_caches = model.init_decode_caches(batch, max_len)

    def place(dst, src_tree):
        def leaf(d, s):
            if d.shape == s.shape:
                return s.astype(d.dtype)
            # pad the sequence axis (axis 2 for [L,B,S,...] layouts)
            if d.ndim == s.ndim and d.shape[2] >= s.shape[2]:
                pads = [(0, d.shape[i] - s.shape[i]) for i in range(d.ndim)]
                return jnp.pad(s.astype(d.dtype), pads)
            raise ValueError(f"cannot place prefill cache {s.shape} into {d.shape}")

        return jax.tree.map(leaf, dst, src_tree)

    out = {}
    for k in decode_caches:
        if prefill_caches is not None and k in prefill_caches:
            src = prefill_caches[k]
            # attn prefill caches lack ring "pos" etc.; merge per sub-key.
            out[k] = _merge_cache_group(decode_caches[k], src, prefill_len)
        else:
            out[k] = decode_caches[k]
    return out


def _merge_cache_group(dst, src, prefill_len: int):
    import jax.numpy as jnp

    def merge(d, s):
        if not (hasattr(d, "shape") and hasattr(s, "shape")):
            return s if s is not None else d
        if d.shape == s.shape:
            return s.astype(d.dtype)
        # sequence axis is 2 for [L, B, S, ...] cache layouts
        s_src, s_dst = s.shape[2], d.shape[2]
        if s_dst >= s_src:
            pads = [(0, d.shape[i] - s.shape[i]) for i in range(d.ndim)]
            return jnp.pad(s.astype(d.dtype), pads)
        # ring buffer: keep the last W tokens, slot p % W holds position p
        tail = jax.lax.slice_in_dim(s, s_src - s_dst, s_src, axis=2)
        shift = s_src % s_dst
        return jnp.roll(tail, shift, axis=2).astype(d.dtype)

    def walk(d, s):
        if isinstance(d, dict):
            out = {}
            for k, dv in d.items():
                sv = s.get(k) if isinstance(s, dict) else None
                if k == "pos":
                    # ring positions for the prefix: slot p%W holds position p
                    W = dv.shape[-1]
                    pos = jnp.arange(W)
                    base = (prefill_len - 1) // W * W if prefill_len else 0
                    cand = jnp.where(base + pos < prefill_len, base + pos, base + pos - W)
                    out[k] = jnp.broadcast_to(
                        jnp.where(cand >= 0, cand, -1).astype(jnp.int32), dv.shape
                    )
                elif sv is None:
                    out[k] = dv
                else:
                    out[k] = walk(dv, sv)
            return out
        if s is None:
            return d
        return jax.tree.map(merge, d, s)

    return walk(dst, src)
