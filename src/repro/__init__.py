"""rapidgzip-JAX: parallel gzip decompression (Knespel & Brunst, HPDC'23) as
a first-class data substrate for a multi-pod JAX training/serving framework."""

__version__ = "0.1.0"
