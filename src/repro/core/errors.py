"""Exception hierarchy for the rapidgzip-JAX core."""


class RapidgzipError(Exception):
    """Base class for all core errors."""


class FormatError(RapidgzipError):
    """The byte stream does not conform to the gzip/deflate format."""


class DeflateError(FormatError):
    """Invalid deflate data (bad Huffman code, bad distance, truncated)."""


class GzipHeaderError(FormatError):
    """Invalid or truncated gzip member header."""


class GzipFooterError(FormatError):
    """CRC32 or ISIZE mismatch in a gzip member footer."""


class BlockNotFoundError(RapidgzipError):
    """No deflate block candidate could be confirmed inside a chunk."""


class IndexError_(RapidgzipError):
    """Seek-index import/export or consistency failure."""


class EndOfStream(RapidgzipError):
    """Ran out of compressed input mid-decode (not necessarily fatal for trials)."""


class RemoteIOError(RapidgzipError):
    """A remote range-GET failed after bounded retries (network/server fault)."""


class RemoteFileChangedError(RapidgzipError):
    """The remote object changed underneath us (ETag/Last-Modified/size
    mismatch between open-time validators and a later response). Never
    retried: serving a mix of old and new bytes would corrupt the stream."""
