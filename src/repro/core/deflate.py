"""Two-stage deflate chunk decoder (paper §2.2, §3.3, Fig 3).

A decompression thread starting at an arbitrary bit offset does not know the
preceding 32 KiB LZ77 window. Stage 1 decodes into a 16-bit intermediate
stream where values < 256 are resolved literals and values >= 256 are
*markers*: ``MARKER_BASE + w`` names byte ``w`` of the unknown initial window
(w = 0 is the oldest byte, 32767 the byte immediately before the chunk).
Stage 2 (``markers.py`` / ``kernels/marker_replace.py``) replaces markers once
the predecessor chunk has produced the real window — a pure gather that is an
order of magnitude faster than decoding (paper Table 2) and the part that maps
onto the TPU VPU.

When the window *is* known (seek-index hit, or stream start where the window
is empty) the decoder runs in conventional single-stage mode straight to
uint8. Mid-chunk, the decoder tracks the last marker position so callers can
see when output became marker-free (paper §3.3's fallback optimization).

The stop condition mirrors rapidgzip exactly: decoding continues until a
block that (a) starts at or after the stop offset, (b) is a Dynamic or
Non-Compressed block, and (c) is not final — i.e. a block the *block finder
of the next chunk could also have found*. Fixed and final blocks are decoded
past the nominal boundary (paper §3.3/§3.4.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from .bitreader import BitReader
from .errors import DeflateError, EndOfStream, GzipFooterError
from .gzip_format import parse_gzip_footer, parse_gzip_header
from .huffman import (
    DISTANCE_BASE,
    DISTANCE_EXTRA,
    FIXED_DISTANCE_LUT,
    FIXED_LITERAL_LUT,
    LENGTH_BASE,
    LENGTH_EXTRA,
    MAX_PRECODE_LEN,
    PRECODE_ORDER,
    HuffmanLUT,
    decode_code_lengths,
)

WINDOW_SIZE = 32768
MARKER_BASE = 256  # symbol value 256 + w refers to unknown-window byte w

BT_STORED = 0
BT_FIXED = 1
BT_DYNAMIC = 2


def canonical_stored_offset(block_start_bit: int) -> int:
    """Canonical bit offset for a Non-Compressed block (paper §3.4.1).

    The zero padding before a stored block's LEN field makes its true start
    ambiguous (final/type bits are zero, indistinguishable from padding), so
    both the block finder and the decoder's stop offset use the *latest*
    possible start: the 3 header bits flush against the LEN field at byte
    ``p``, i.e. ``8*p - 3``. Decoding from the canonical offset yields the
    identical block.
    """
    len_byte = (block_start_bit + 3 + 7) // 8
    return 8 * len_byte - 3


@dataclass
class BlockBoundary:
    bit_offset: int
    out_offset: int
    block_type: int
    is_final: bool


@dataclass
class MemberEnd:
    """A gzip member footer encountered inside the chunk."""

    out_offset: int  # chunk-local decompressed offset at which the member ends
    crc32: int
    isize: int
    footer_end_bit: int


@dataclass
class MemberStart:
    """A gzip member header encountered inside the chunk."""

    header_start_bit: int
    deflate_start_bit: int
    out_offset: int


@dataclass
class DecodeResult:
    start_bit: int
    end_bit: int
    data: np.ndarray  # uint16 (marker mode) or uint8 (window mode)
    marker_mode: bool
    blocks: List[BlockBoundary] = field(default_factory=list)
    member_ends: List[MemberEnd] = field(default_factory=list)
    member_starts: List[MemberStart] = field(default_factory=list)
    ended_at_eos: bool = False  # reached end of the whole file
    first_marker: int = -1  # chunk-local offset of first marker symbol (-1: none)
    last_marker: int = -1  # conservative last position that may hold a marker

    @property
    def size(self) -> int:
        return int(self.data.shape[0])

    def contains_markers(self) -> bool:
        return self.marker_mode and self.first_marker >= 0


class DeflateChunkDecoder:
    """Decodes one chunk of a (possibly multi-member) gzip/deflate byte stream."""

    def __init__(self, data, *, framing: str = "gzip"):
        if framing not in ("gzip", "raw"):
            raise ValueError("framing must be 'gzip' or 'raw'")
        self.data = data if isinstance(data, (bytes, memoryview)) else bytes(data)
        self.framing = framing

    # -- public API ---------------------------------------------------------

    def decode_chunk(
        self,
        start_bit: int,
        stop_bit: Optional[int] = None,
        *,
        window: Optional[bytes] = None,
        max_out: Optional[int] = None,
        initial_capacity: int = 1 << 17,
    ) -> DecodeResult:
        """Decode deflate blocks from ``start_bit`` until the stop condition.

        window=None  -> two-stage marker mode (unknown window).
        window=bytes -> single-stage mode; b"" means known-empty (stream start).
        """
        total_bits = len(self.data) * 8
        if stop_bit is None:
            stop_bit = total_bits
        br = BitReader(self.data, start_bit)

        marker_mode = window is None
        dtype = np.uint16 if marker_mode else np.uint8
        out = np.empty(max(initial_capacity, 1024), dtype=dtype)
        if window:
            win_arr = np.frombuffer(window, dtype=np.uint8)
        else:
            win_arr = np.empty(0, dtype=np.uint8)
        win_len = int(win_arr.shape[0])

        state = _DecodeState(out, marker_mode, win_arr, win_len, max_out)
        result = DecodeResult(start_bit=start_bit, end_bit=start_bit, data=out, marker_mode=marker_mode)

        while True:
            block_start = br.bit_pos
            # +7: a stored block's canonical offset can sit up to 7 bits
            # after its true start, and the canonical offset is what must be
            # compared against the stop offset.
            if result.blocks and block_start + 7 >= stop_bit:
                # Stop only at a block the next chunk's finder could find:
                # non-final Dynamic or Non-Compressed (paper §3.3).
                probe = br.peek(3)
                is_final = probe & 1
                btype = (probe >> 1) & 3
                if not is_final and btype in (BT_STORED, BT_DYNAMIC):
                    # Compare (and record) the canonical offset for stored
                    # blocks so stop offsets always match finder candidates
                    # and index seek points (padding ambiguity, §3.4.1).
                    effective = (
                        canonical_stored_offset(block_start)
                        if btype == BT_STORED
                        else block_start
                    )
                    if effective >= stop_bit:
                        result.end_bit = effective
                        break
            if br.bits_left() < 3:
                raise EndOfStream("chunk ran out of bits at block boundary")

            is_final = br.read(1)
            btype = br.read(2)
            result.blocks.append(
                BlockBoundary(block_start, state.n, btype, bool(is_final))
            )
            if btype == BT_STORED:
                self._decode_stored(br, state)
            elif btype == BT_FIXED:
                self._decode_huffman(br, state, FIXED_LITERAL_LUT, FIXED_DISTANCE_LUT)
            elif btype == BT_DYNAMIC:
                lit_lut, dist_lut = read_dynamic_header(br)
                self._decode_huffman(br, state, lit_lut, dist_lut)
            else:
                raise DeflateError("reserved block type 11")

            if is_final:
                if self.framing == "raw":
                    result.end_bit = br.bit_pos
                    result.ended_at_eos = True
                    break
                # gzip footer: byte-align, CRC32 + ISIZE (paper Fig 1).
                br.align_to_byte()
                footer = parse_gzip_footer(br)
                result.member_ends.append(
                    MemberEnd(state.n, footer.crc32, footer.isize, br.bit_pos)
                )
                if br.bits_left() < 8:
                    result.end_bit = br.bit_pos
                    result.ended_at_eos = True
                    break
                header_start = br.bit_pos
                hdr = parse_gzip_header(br)
                result.member_starts.append(
                    MemberStart(header_start, br.bit_pos, state.n)
                )
                # Next member's first block continues the loop; the stop
                # check at the top applies to it like any other boundary.

        result.data = state.out[: state.n]
        result.first_marker = state.first_marker
        result.last_marker = state.last_marker
        if not result.blocks:
            raise DeflateError("no blocks decoded")
        return result

    # -- block bodies ---------------------------------------------------------

    def _decode_stored(self, br: BitReader, state: "_DecodeState") -> None:
        br.align_to_byte()
        length = br.read(16)
        nlen = br.read(16)
        if length != (~nlen & 0xFFFF):
            raise DeflateError("stored block LEN/NLEN mismatch")
        raw = br.read_bytes(length)
        state.append_literal_bytes(raw)

    def _decode_huffman(
        self,
        br: BitReader,
        state: "_DecodeState",
        lit_lut: HuffmanLUT,
        dist_lut: HuffmanLUT,
    ) -> None:
        # Local bindings for speed in the hot loop.
        lit_table = lit_lut.table
        lit_bits = lit_lut.max_len
        dist_table = dist_lut.table
        dist_bits = dist_lut.max_len
        peek = br.peek
        skip = br.skip
        read = br.read
        lb, le = LENGTH_BASE, LENGTH_EXTRA
        db, de = DISTANCE_BASE, DISTANCE_EXTRA

        while True:
            entry = int(lit_table[peek(lit_bits)])
            if entry < 0:
                raise DeflateError("invalid literal/length code")
            skip(entry >> 16)
            sym = entry & 0xFFFF
            if sym < 256:
                state.append_literal(sym)
                continue
            if sym == 256:
                return
            if sym > 285:
                raise DeflateError("invalid length symbol %d" % sym)
            li = sym - 257
            length = int(lb[li])
            extra = int(le[li])
            if extra:
                length += read(extra)

            entry = int(dist_table[peek(dist_bits)])
            if entry < 0:
                raise DeflateError("invalid distance code")
            skip(entry >> 16)
            dsym = entry & 0xFFFF
            if dsym > 29:
                raise DeflateError("invalid distance symbol %d" % dsym)
            dist = int(db[dsym])
            extra = int(de[dsym])
            if extra:
                dist += read(extra)
            state.copy_match(dist, length)


def read_dynamic_header(br: BitReader, *, strict: bool = False) -> Tuple[HuffmanLUT, HuffmanLUT]:
    """Parse a Dynamic Block header into (literal LUT, distance LUT).

    ``strict=True`` applies block-finder semantics: all three Huffman codes
    must be valid AND complete (paper §3.4.2 steps 4-7). ``strict=False``
    applies decoder semantics (zlib-compatible leniency for incomplete
    distance codes).
    """
    hlit = br.read(5)
    if strict and hlit > 29:
        raise DeflateError("invalid HLIT")
    hdist = br.read(5)
    hclen = br.read(4)
    n_lit = hlit + 257
    n_dist = hdist + 1
    if n_lit > 286 or n_dist > 30:
        raise DeflateError("code count out of range (HLIT=%d HDIST=%d)" % (hlit, hdist))

    precode_lengths = np.zeros(19, dtype=np.int64)
    for i in range(hclen + 4):
        precode_lengths[PRECODE_ORDER[i]] = br.read(3)
    precode_lut = HuffmanLUT.from_lengths(precode_lengths, strict=strict, allow_incomplete=False)

    try:
        all_lengths = decode_code_lengths(br, precode_lut, n_lit + n_dist, strict=strict)
    except DeflateError as exc:
        raise DeflateError("precode data: %s" % exc) from exc
    lit_lengths = all_lengths[:n_lit]
    dist_lengths = all_lengths[n_lit:]

    if strict:
        # Paper §3.4.2 order: distance code (6) is checked BEFORE the literal
        # code (7) — it is the cheaper check and filters 40x more often
        # (Table 1). LUTs are only built after both pass.
        from .huffman import check_code_lengths

        dstatus = check_code_lengths(dist_lengths, 15)
        if dstatus != 0:
            raise DeflateError("distance code: status %d" % dstatus)
        lstatus = check_code_lengths(lit_lengths, 15)
        if lstatus != 0:
            raise DeflateError("literal code: status %d" % lstatus)
        if lit_lengths[256] == 0:
            raise DeflateError("literal code: no end-of-block symbol")

    lit_lut = HuffmanLUT.from_lengths(lit_lengths, strict=strict, allow_incomplete=False)
    # Distance code: zlib permits an incomplete code (e.g. a single code or
    # none at all, for blocks without matches).
    if dist_lengths.max() == 0:
        # No distance codes: any match attempt must fail. Use an all-invalid
        # 1-bit table.
        dist_lut = HuffmanLUT(np.full(2, -1, dtype=np.int32), 1, 0)
    else:
        dist_lut = HuffmanLUT.from_lengths(dist_lengths, strict=strict, allow_incomplete=True)
    return lit_lut, dist_lut


class _DecodeState:
    """Mutable output buffer + LZ77 window bookkeeping for one chunk."""

    __slots__ = (
        "out",
        "n",
        "marker_mode",
        "win_arr",
        "win_len",
        "max_out",
        "first_marker",
        "last_marker",
    )

    def __init__(self, out, marker_mode, win_arr, win_len, max_out):
        self.out = out
        self.n = 0
        self.marker_mode = marker_mode
        self.win_arr = win_arr
        self.win_len = win_len
        self.max_out = max_out
        self.first_marker = -1
        self.last_marker = -1

    # -- capacity -----------------------------------------------------------

    def _ensure(self, extra: int) -> None:
        need = self.n + extra
        cap = self.out.shape[0]
        if need <= cap:
            return
        if self.max_out is not None and need > self.max_out:
            raise DeflateError(
                "chunk output exceeds max_out=%d (suspected false positive or "
                "extreme compression ratio)" % self.max_out
            )
        new_cap = cap
        while new_cap < need:
            new_cap *= 2
        grown = np.empty(new_cap, dtype=self.out.dtype)
        grown[: self.n] = self.out[: self.n]
        self.out = grown

    # -- emission -----------------------------------------------------------

    def append_literal(self, value: int) -> None:
        self._ensure(1)
        self.out[self.n] = value
        self.n += 1

    def append_literal_bytes(self, raw: bytes) -> None:
        if not raw:
            return
        self._ensure(len(raw))
        arr = np.frombuffer(raw, dtype=np.uint8)
        if self.marker_mode:
            self.out[self.n : self.n + len(raw)] = arr  # widens to uint16
        else:
            self.out[self.n : self.n + len(raw)] = arr
        self.n += len(raw)

    def copy_match(self, dist: int, length: int) -> None:
        if dist > WINDOW_SIZE:
            raise DeflateError("distance %d exceeds window" % dist)
        n = self.n
        src = n - dist
        if src < 0 and not self.marker_mode:
            # Known window: the reference must fit inside it.
            if -src > self.win_len:
                raise DeflateError("distance reaches before stream start")
        self._ensure(length)
        out = self.out
        end = n + length

        if src < 0:
            # Part (or all) of the match comes from the initial window.
            from_window = min(length, -src)
            if self.marker_mode:
                # Markers name absolute positions in the unknown window:
                # window index w = WINDOW_SIZE + src + i (paper §2.2 step 2).
                w0 = WINDOW_SIZE + src
                out[n : n + from_window] = np.arange(
                    MARKER_BASE + w0, MARKER_BASE + w0 + from_window, dtype=np.uint16
                )
                if self.first_marker < 0:
                    self.first_marker = n
                self.last_marker = n + from_window - 1
            else:
                w0 = self.win_len + src
                out[n : n + from_window] = self.win_arr[w0 : w0 + from_window]
            n += from_window
            length -= from_window
            src = 0  # remainder copies from the chunk's own start

        # Remaining copy is chunk-internal; handle overlap by periodic copy
        # with doubling (classic LZ77 overlap expansion).
        while length > 0:
            avail = n - src
            take = min(length, avail)
            seg = out[src : src + take]
            out[n : n + take] = seg
            if self.marker_mode and self.last_marker >= src:
                # Conservative: copied region may contain markers.
                self.first_marker = self.first_marker if self.first_marker >= 0 else n
                self.last_marker = n + take - 1
            n += take
            length -= take
        self.n = n


# ---------------------------------------------------------------------------
# Convenience sequential API (used by tests and as the single-thread baseline)
# ---------------------------------------------------------------------------

def inflate_raw(data: bytes, max_out: Optional[int] = None) -> bytes:
    """Sequentially inflate a raw deflate stream from bit 0."""
    dec = DeflateChunkDecoder(data, framing="raw")
    res = dec.decode_chunk(0, len(data) * 8, window=b"", max_out=max_out)
    return res.data.tobytes()


def gzip_decompress_sequential(data: bytes, *, verify: bool = True) -> bytes:
    """Sequentially decompress a (multi-member) gzip byte stream.

    This is the paper's single-threaded baseline path ("rapidgzip -P 1"): the
    same custom deflate decoder, no speculation, known-empty window.
    """
    import zlib as _zlib

    br = BitReader(data)
    hdr = parse_gzip_header(br)
    dec = DeflateChunkDecoder(data, framing="gzip")
    res = dec.decode_chunk(br.bit_pos, len(data) * 8, window=b"")
    out = res.data.tobytes()
    if verify:
        prev = 0
        for me in res.member_ends:
            segment = out[prev : me.out_offset]
            if (_zlib.crc32(segment) & 0xFFFFFFFF) != me.crc32:
                raise GzipFooterError("CRC32 mismatch in gzip member")
            if (len(segment) & 0xFFFFFFFF) != me.isize:
                raise GzipFooterError("ISIZE mismatch in gzip member")
            prev = me.out_offset
    return out
